"""Flash-decode: one query token vs an arbitrarily large KV cache.

This is the paper's headline capability ("compute with data sets of
arbitrarily large size") in kernel form: the KV cache lives in HBM (or, at
the framework level, host memory — see ``core.memkind``) and is **passed by
reference** (``pl.ANY``).  The kernel walks it block-by-block through a VMEM
ring buffer with explicit ``make_async_copy`` DMAs:

  ring depth  = ``PrefetchSpec.buffer_size``
  block rows  = ``block_kv``  (the paper's elements-per-fetch)
  lookahead   = ``PrefetchSpec.distance`` (0 = the paper's on-demand mode)

Only ``ceil(length / block_kv)`` blocks are fetched (dynamic trip count), so
per-token work is proportional to the *valid* context, not the allocated
cache.  Online softmax keeps the VMEM working set at
``2 * slots * block_kv * H`` bytes regardless of context length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jaxcompat import tpu_compiler_params

from repro.core.engine import static_auto_distance
from repro.core.refspec import PrefetchSpec

NEG_INF = -1e30
LANES = 128


def _decode_kernel(
    len_ref,  # (1,) int32 SMEM — valid cache length for this (b, kh) program
    q_ref,  # (1, G, H) VMEM
    k_hbm,  # (BKH, T, H) ANY — by reference
    v_hbm,  # (BKH, T, H) ANY
    o_ref,  # (1, G, H) VMEM
    ring_k,  # (slots, block_kv, H) VMEM
    ring_v,  # (slots, block_kv, H) VMEM
    sem_k,  # (slots,) DMA
    sem_v,  # (slots,) DMA
    *,
    block_kv: int,
    n_t: int,
    distance: int,
    slots: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    g, h = q_ref.shape[1], q_ref.shape[2]
    length = len_ref[0]
    needed = (length + block_kv - 1) // block_kv  # dynamic trip count

    def copy_block(i, slot):
        ck = pltpu.make_async_copy(
            k_hbm.at[b, pl.ds(i * block_kv, block_kv), :], ring_k.at[slot], sem_k.at[slot]
        )
        cv = pltpu.make_async_copy(
            v_hbm.at[b, pl.ds(i * block_kv, block_kv), :], ring_v.at[slot], sem_v.at[slot]
        )
        return ck, cv

    if distance > 0:
        def warm(t, _):
            @pl.when(t < needed)
            def _():
                ck, cv = copy_block(t, jax.lax.rem(t, slots))
                ck.start()
                cv.start()
            return ()
        jax.lax.fori_loop(0, distance, warm, (), unroll=True)

    q = q_ref[0]  # (G, H)

    def body(i, carry):
        m_prev, l_prev, acc = carry
        slot = jax.lax.rem(i, slots)
        if distance == 0:
            # on-demand: blocking fetch in the critical path (paper baseline)
            ck, cv = copy_block(i, slot)
            ck.start(); cv.start()
            ck.wait(); cv.wait()
        else:
            nxt = i + distance
            @pl.when(nxt < needed)
            def _():
                ck, cv = copy_block(nxt, jax.lax.rem(nxt, slots))
                ck.start()
                cv.start()
            ck, cv = copy_block(i, slot)
            ck.wait(); cv.wait()

        kb = ring_k[slot]  # (bkv, H)
        vb = ring_v[slot]
        s = jax.lax.dot_general(
            q, kb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (G, bkv)
        kpos = i * block_kv + jax.lax.broadcasted_iota(jnp.int32, (g, block_kv), 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p.astype(vb.dtype), vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc

    init = (
        jnp.full((g, 1), NEG_INF, jnp.float32),
        jnp.zeros((g, 1), jnp.float32),
        jnp.zeros((g, h), jnp.float32),
    )
    _, l, acc = jax.lax.fori_loop(0, needed, body, init)
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def decode_attention_p(
    q: jax.Array,  # (BKH, G, H)
    k: jax.Array,  # (BKH, T, H)
    v: jax.Array,  # (BKH, T, H)
    lengths: jax.Array,  # (BKH,) int32
    *,
    spec: PrefetchSpec,
    block_kv: int,
    interpret: bool,
) -> jax.Array:
    bkh, g, h = q.shape
    t = k.shape[1]
    assert t % block_kv == 0, (t, block_kv)
    n_t = t // block_kv
    # static VMEM ring: "auto" resolves to a fixed head start at trace time
    distance = spec.numeric_distance(static_auto_distance(n_t))
    slots = max(spec.buffer_size, distance + 1, 1)

    kernel = functools.partial(
        _decode_kernel,
        block_kv=block_kv,
        n_t=n_t,
        distance=distance,
        slots=slots,
        sm_scale=h ** -0.5,
    )
    # lengths are delivered per program via an SMEM BlockSpec.
    return pl.pallas_call(
        kernel,
        grid=(bkh,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, h), lambda b: (b, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, g, h), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bkh, g, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((slots, block_kv, h), k.dtype),
            pltpu.VMEM((slots, block_kv, h), v.dtype),
            pltpu.SemaphoreType.DMA((slots,)),
            pltpu.SemaphoreType.DMA((slots,)),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
    )(lengths, q, k, v)

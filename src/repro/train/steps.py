"""Step functions: train / prefill / decode, built per (config, optimizer).

These are the functions the launcher jits with the sharding plan's
in/out-shardings and that the dry-run lowers for every (arch x shape x mesh)
cell.  All of them are pure: ``(state..., batch) -> (state..., outputs)``.

The streamed-optimizer path (``make_streamed_opt_updater`` /
``make_streamed_train_step``) is the paper's flagship pattern applied to the
largest state group of training: AdamW moments + f32 master live at the
*host* kind between steps and stream through the
:class:`~repro.core.engine.TransferEngine` group-wise during the update —
coalesced H2D, ``rw`` write-back pipelined off the compute path, prefetch
distance adaptive when ``PrefetchSpec(distance="auto")``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import EngineConfig, TransferEngine
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import PrefetchSpec
from repro.core.residency import ResidencyCache
from repro.core.schedcheck import analyze_train_schedule, verify_schedule
from repro.core.weightstream import WeightStreamPlan, merge_expert_slice
from repro.models import moe, transformer
from repro.optim.adamw import (
    AdamWConfig,
    adamw_globals,
    adamw_globals_from_norm,
    adamw_init,
    adamw_leaf_update,
    adamw_update,
)

Pytree = Any


def make_train_step(
    cfg: ModelConfig, opt_cfg: AdamWConfig, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree, dict]]:
    """``(params, opt_state, batch) -> (params, opt_state, metrics)``."""

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(transformer.lm_loss, argnums=1, has_aux=True)(
            cfg, params, batch, mesh, sharder
        )
        if sharder is not None:
            grads = sharder.grads(grads)  # ZeRO grad layout (see Sharder.grads)
        new_params, new_opt, om = adamw_update(
            opt_cfg, grads, opt_state, compute_dtype=cfg.compute_dtype
        )
        metrics = {"loss": loss, **aux, **om}
        return new_params, new_opt, metrics

    return train_step


def make_grad_step(
    cfg: ModelConfig, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree], tuple[jax.Array, dict, Pytree]]:
    """``(params, batch) -> (loss, aux, grads)`` — the forward/backward half
    of the train step, split out so the optimizer half can run through the
    host-streaming engine."""

    def grad_step(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            transformer.lm_loss, argnums=1, has_aux=True
        )(cfg, params, batch, mesh, sharder)
        if sharder is not None:
            grads = sharder.grads(grads)
        return loss, aux, grads

    return grad_step


# ---------------------------------------------------------------------------
# streamed optimizer update (host-resident AdamW state, paper 'rw' streaming)
# ---------------------------------------------------------------------------


def _to_host(x):
    """numpy view of a concrete array; abstract values pass through so the
    driver's ``jax.eval_shape(init_state)`` restore template still works."""
    return x if isinstance(x, jax.core.Tracer) else np.asarray(x)


def host_opt_state(params: Pytree) -> dict:
    """Fresh AdamW state resident at the host kind (numpy leaves).

    This is the home representation the streamed updater maintains: the
    moments never hold device memory between steps.
    """
    dev = adamw_init(params)
    return {
        "leaves": jax.tree.map(_to_host, dev["leaves"]),
        "step": _to_host(dev["step"]),
    }


def _group_bounds(n: int, n_groups: int) -> np.ndarray:
    """Contiguous leaf-group boundaries — shared by the streamed updater and
    the spill partitioner so both see the same groups."""
    return np.linspace(0, n, min(n_groups, n) + 1).astype(int)


def _opt_group_key(i: int) -> str:
    return f"opt_g{i:04d}"


def spill_opt_state(
    host_state: dict,
    store,
    *,
    n_groups: int = 4,
    host_budget_bytes: Optional[int] = None,
) -> dict:
    """Move trailing moment groups to the ``DiskHost`` tier under a host-RAM
    budget.

    Groups (the same contiguous leaf groups the streamed updater transfers)
    are kept in host RAM front-to-back while they fit ``host_budget_bytes``;
    the rest are written to ``store`` (one chunk per group — one disk
    request per group when streamed) and replaced by memory-mapped views.
    ``host_budget_bytes=None`` or 0 spills everything.  Abstract leaves
    (``jax.eval_shape`` templates, driver restore) pass through untouched.
    """
    flat_s, treedef = jax.tree.flatten(
        host_state["leaves"],
        is_leaf=lambda x: isinstance(x, dict) and {"master", "m", "v"} <= set(x),
    )
    if not all(
        isinstance(v, np.ndarray) for s in flat_s for v in jax.tree.leaves(s)
    ):
        return host_state  # abstract template (eval_shape) — nothing to spill
    bounds = _group_bounds(len(flat_s), n_groups)
    budget = host_budget_bytes or 0
    used = 0
    out: list = []
    for i in range(len(bounds) - 1):
        chunk = tuple(flat_s[bounds[i] : bounds[i + 1]])
        nbytes = sum(v.nbytes for s in chunk for v in jax.tree.leaves(s))
        if used + nbytes <= budget:
            used += nbytes
            out.extend(chunk)
        else:
            store.put(_opt_group_key(i), chunk)
            out.extend(store.get(_opt_group_key(i)))
    return {
        "leaves": jax.tree.unflatten(treedef, out),
        "step": host_state["step"],
    }


def make_streamed_opt_updater(
    opt_cfg: AdamWConfig,
    *,
    compute_dtype=jnp.bfloat16,
    n_groups: int = 4,
    prefetch: Optional[PrefetchSpec] = None,
    mode: str = "prefetch",
    engine: Optional[TransferEngine] = None,
    spill_store=None,
    state_shardings: Optional[Pytree] = None,
) -> Callable[..., tuple[Pytree, dict, dict]]:
    """Build ``update(grads, host_state, stats=None) -> (new_params,
    new_host_state, metrics)`` with host-resident optimizer state.

    Parameter leaves are partitioned into ``n_groups`` contiguous groups.
    Per group, the state leaves stream H2D through the engine (coalesced:
    one request per group) while the previous group's update computes;
    gradients are already device-resident and pass through by reference.
    New moments stream back D2H asynchronously (``rw`` write-back) and the
    new master-derived params stay on device.  The math is exactly
    :func:`repro.optim.adamw.adamw_update` (same leaf function, same
    globals); results agree to float32 rounding (the group-wise jit fuses
    differently than a whole-tree program), and the transfer schedule is
    the only structural difference.

    Groups whose ``host_state`` leaves live at the ``DiskHost`` tier
    (memory-mapped spill-store chunks — see :func:`spill_opt_state`) stream
    in through the engine's two-stage disk->host->device pipeline, and
    their updated moments are written back to ``spill_store`` after the
    D2H drain, so the state never occupies more host RAM than the budgeted
    groups plus the engine's staging pools.

    ``state_shardings`` (a pytree congruent with ``host_state["leaves"]``:
    one device ``NamedSharding`` per master/m/v leaf — the sharding plan's
    opt-state specs) places each streamed moment group at its planned
    multi-device layout instead of default single-device placement, via
    the engine's sharding-aware coalescing (one H2D request per
    addressable device per group).
    """
    prefetch = prefetch or PrefetchSpec(buffer_size=n_groups, distance=1)

    @jax.jit
    def _globals(grads, step):
        return adamw_globals(opt_cfg, grads, step)

    @jax.jit
    def _group_update(glob, gs, ss):
        out = [adamw_leaf_update(opt_cfg, glob, g, s) for g, s in zip(gs, ss)]
        new_p = tuple(p.astype(compute_dtype) for p, _ in out)
        new_s = tuple(s for _, s in out)
        return new_p, new_s

    own_engine = engine
    executor_box: list = []  # lazily built so the updater is picklable-ish
    #: per-group sharding lists, keyed by the grads treedef (static across
    #: steps — rebuilt only when the param structure changes)
    group_shardings_cache: dict = {}

    def _executor() -> HostStreamExecutor:
        if not executor_box:
            new_params_box: list = []

            def apply(glob, group):
                new_p, new_s = _group_update(glob, group["g"], group["s"])
                new_params_box.append(new_p)
                return glob, new_s

            ex = HostStreamExecutor(apply, writeback=True, engine=own_engine)
            executor_box.append((ex, new_params_box))
        return executor_box[0]

    def update(grads, host_state, stats: Optional[StreamStats] = None):
        from repro.core.spillstore import is_disk_leaf

        ex, new_params_box = _executor()
        new_params_box.clear()
        step = int(host_state["step"]) + 1
        glob = _globals(grads, step)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(host_state["leaves"])
        n = len(flat_g)
        bounds = _group_bounds(n, n_groups)
        groups = [
            {
                "g": tuple(flat_g[bounds[i] : bounds[i + 1]]),
                "s": tuple(flat_s[bounds[i] : bounds[i + 1]]),
            }
            for i in range(len(bounds) - 1)
        ]
        group_shardings = None
        if state_shardings is not None:
            # per-group layouts mirroring the group partition: grads are
            # device-resident (pass-by-reference; None = no placement),
            # moments stage at the plan's opt specs
            group_shardings = group_shardings_cache.get(treedef)
            if group_shardings is None:
                flat_sh = treedef.flatten_up_to(state_shardings)
                group_shardings = [
                    {
                        "g": tuple([None] * (bounds[i + 1] - bounds[i])),
                        "s": tuple(flat_sh[bounds[i] : bounds[i + 1]]),
                    }
                    for i in range(len(bounds) - 1)
                ]
                group_shardings_cache[treedef] = group_shardings

        _, state_outs = ex.run(
            glob,
            groups,
            mode=mode,
            prefetch=prefetch,
            stats=stats,
            group_shardings=group_shardings,
            group_keys=[f"opt/{i}" for i in range(len(groups))],
        )

        # disk-homed groups go back to their home tier: write the updated
        # moments to the spill store and keep only the memmap views
        for i, grp in enumerate(groups):
            if any(is_disk_leaf(v) for s in grp["s"] for v in jax.tree.leaves(s)):
                if spill_store is None:
                    raise RuntimeError(
                        "optimizer state group streamed from the DiskHost "
                        "tier but no spill_store was given to write it back"
                    )
                spill_store.put(_opt_group_key(i), state_outs[i])
                state_outs[i] = spill_store.get(_opt_group_key(i))

        flat_new_p = [p for chunk in new_params_box for p in chunk]
        flat_new_s = [s for chunk in state_outs for s in chunk]
        new_params = treedef.unflatten(flat_new_p)
        new_state = {
            "leaves": treedef.unflatten(flat_new_s),
            "step": np.asarray(step, np.int32),
        }
        metrics = {"grad_norm": glob["grad_norm"], "lr": glob["lr"]}
        return new_params, new_state, metrics

    update.close = lambda: executor_box and executor_box[0][0].close()  # type: ignore[attr-defined]
    return update


def make_streamed_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh=None,
    sharder=None,
    *,
    n_groups: int = 4,
    prefetch: Optional[PrefetchSpec] = None,
    engine: Optional[TransferEngine] = None,
    stats: Optional[StreamStats] = None,
    spill_store=None,
    state_shardings: Optional[Pytree] = None,
) -> Callable[[dict, Pytree], tuple[dict, dict]]:
    """``(state, batch) -> (state, metrics)`` with host-resident optimizer.

    ``state = {"params": device pytree, "opt": host_opt_state(...)}``.  The
    forward/backward half is jitted; the AdamW half streams the host-kind
    moments through the transfer engine (see ``make_streamed_opt_updater``).
    With ``spill_store``, moment groups spilled to the ``DiskHost`` tier
    (see :func:`spill_opt_state`) stream disk->host->device and write back
    to disk.  ``state_shardings`` places the streamed moment groups at the
    sharding plan's opt specs (one coalesced H2D request per device per
    group under a mesh).
    """
    grad_fn = jax.jit(make_grad_step(cfg, mesh, sharder))
    updater = make_streamed_opt_updater(
        opt_cfg,
        compute_dtype=cfg.compute_dtype,
        n_groups=n_groups,
        prefetch=prefetch,
        engine=engine,
        spill_store=spill_store,
        state_shardings=state_shardings,
    )

    def step_fn(state, batch):
        loss, aux, grads = grad_fn(state["params"], batch)
        new_params, new_opt, om = updater(grads, state["opt"], stats=stats)
        metrics = {"loss": loss, **aux, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    step_fn.close = updater.close  # type: ignore[attr-defined]
    return step_fn


# ---------------------------------------------------------------------------
# weight-streamed training / serving (host- or disk-homed model parameters)
# ---------------------------------------------------------------------------


def _abstract_leaf(x) -> bool:
    return isinstance(x, (jax.core.Tracer, jax.ShapeDtypeStruct))


def _opt_state_leaf(p):
    """AdamW state for one host-homed parameter leaf (numpy between steps;
    tracer-safe for the driver's ``eval_shape`` restore template)."""
    if _abstract_leaf(p):
        z = jnp.zeros(jnp.shape(p), jnp.float32)
        return {"master": p.astype(jnp.float32), "m": z, "v": z}
    a = np.asarray(p)
    return {
        "master": np.asarray(a, np.float32),
        "m": np.zeros(a.shape, np.float32),
        "v": np.zeros(a.shape, np.float32),
    }


def _init_group_f32(key: jax.Array, cfg: ModelConfig, plan: WeightStreamPlan, g, shell_box: dict):
    """One home group's f32 init leaves — exactly :func:`transformer.init_model`'s
    values for those leaves, computed without materializing any other layer
    (the group-wise init: at most one layer slice is device-resident at a
    time — ``shell_box`` carries a one-entry slice cache so an expert-split
    layer's E + 1 groups share one init of its slice)."""
    if g.kind in ("layers", "block", "expert"):
        ck = ("slice", g.lo, g.hi)
        if shell_box.get("slice_key") != ck:
            shell_box["slice_key"] = ck
            shell_box["slice"] = transformer.init_model_slice(key, cfg, g.lo, g.hi)
        sl = shell_box["slice"]
        if g.kind == "expert":
            return {n: sl["moe"][n][:, g.expert] for n in plan.expert_names}
        if g.kind == "layers" and plan.expert_stream:
            return plan._strip_experts(sl)
        return sl
    if g.kind == "period":
        p = cfg.scan_period
        return transformer.init_model_period_slice(key, cfg, g.lo // p, g.hi // p)
    if "shell" not in shell_box:
        shell_box["shell"] = transformer.init_model_shell(key, cfg)
    keys = plan.embed_keys if g.kind == "embed" else plan.head_home_keys
    return {k: shell_box["shell"][k] for k in keys}


def init_weight_streamed_params(
    key: jax.Array, cfg: ModelConfig, plan: WeightStreamPlan
) -> dict:
    """Parameter home (compute-dtype, host-numpy leaves) initialized
    group-wise: bitwise-identical to homing ``init_train_state(key, cfg)``
    but only ever one transfer group device-resident — arbitrarily large
    models initialize under the device budget."""
    dt = cfg.compute_dtype
    shell_box: dict = {}
    groups = {}
    for g in plan.groups:
        f32 = _init_group_f32(key, cfg, plan, g, shell_box)
        groups[g.key] = jax.tree.map(
            lambda p: _to_host(p.astype(dt)), f32
        )
    return {"groups": groups}


def init_weight_streamed_state(key: jax.Array, cfg: ModelConfig, plan: WeightStreamPlan) -> dict:
    """``{"params": home, "opt": grouped state}`` with host-numpy leaves
    (the ``pinned_host`` home; callers spill/place for disk/device kinds).

    Initialization is group-wise (see :func:`init_weight_streamed_params`),
    and the AdamW masters come from the **f32** init values — the same
    fidelity as :func:`init_train_state`, whose master is taken before the
    compute-dtype cast."""
    dt = cfg.compute_dtype
    shell_box: dict = {}
    p_groups = {}
    o_groups = {}
    for g in plan.groups:
        f32 = _init_group_f32(key, cfg, plan, g, shell_box)
        p_groups[g.key] = jax.tree.map(lambda p: _to_host(p.astype(dt)), f32)
        o_groups[g.key] = jax.tree.map(_opt_state_leaf, f32)
    step = (
        jnp.zeros((), jnp.int32)
        if any(_abstract_leaf(x) for x in jax.tree.leaves(p_groups))
        else np.zeros((), np.int32)
    )
    return {
        "params": {"groups": p_groups},
        "opt": {"groups": o_groups, "step": step},
    }


def spill_weight_streamed_state(
    plan: WeightStreamPlan, state: dict, store
) -> dict:
    """Re-home a weight-streamed train state at the ``DiskHost`` tier: one
    spill chunk per param group (``wp/<key>``) and one per moment group
    (``wopt/<key>``).  Abstract templates and already-spilled groups pass
    through — the trainer calls this after checkpoint restore to re-impose
    the disk home on the plain host arrays restore hands back."""
    from repro.core.spillstore import is_disk_leaf

    home = plan.spill_home(state["params"], store)
    opt_groups = {}
    for g in plan.groups:
        tree = state["opt"]["groups"][g.key]
        leaves = jax.tree.leaves(tree)
        if any(_abstract_leaf(x) for x in leaves):
            return {"params": home, "opt": state["opt"]}
        if not all(is_disk_leaf(x) for x in leaves):
            store.put(f"wopt/{g.key}", tree)
            tree = store.get(f"wopt/{g.key}")
        opt_groups[g.key] = tree
    return {
        "params": home,
        "opt": {"groups": opt_groups, "step": state["opt"]["step"]},
    }


def _leaf_sqsums(tree: Pytree) -> tuple:
    """Per-leaf squared sums (f32) — the partial terms of
    :func:`repro.optim.adamw.global_norm`, computed group-wise so the full
    gradient tree never has to co-reside."""
    return tuple(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    )


def make_weight_streamed_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh=None,
    sharder=None,
    *,
    plan: WeightStreamPlan,
    prefetch: Optional[PrefetchSpec] = None,
    engine: Optional[TransferEngine] = None,
    stats: Optional[StreamStats] = None,
    opt_stats: Optional[StreamStats] = None,
    spill_store=None,
    param_shardings: Optional[Pytree] = None,
    param_kind: str = "pinned_host",
    residency: Optional[ResidencyCache] = None,
) -> Callable[[dict, Pytree], tuple[dict, dict]]:
    """``(state, batch) -> (state, metrics)`` with host/disk-homed weights.

    ``state = {"params": ..., "opt": ...}`` as built by
    :func:`init_weight_streamed_state` (grouped homes + grouped moments).
    One step runs three streamed passes over the plan's transfer groups:

    forward
        fetch order ``embed, L0..Ln, head``; each group applies its jitted
        stage while the next groups stream in behind it.  The head stage
        computes the loss **and** the head/trunk cotangents (its params are
        in hand, so the head group is fetched exactly once).
    backward
        **reverse** fetch order ``Ln..L0, embed``: each layer group is
        re-fetched and its vjp recomputes the group forward from the saved
        boundary activation (group-granular activation checkpointing), so
        the backward peak residency equals the forward's.  Per-group
        gradients stream back D2H through the engine's pipelined writeback;
        per-leaf squared sums stay on device for the global norm.
    optimizer
        reverse home order (head first — its gradients were born on device
        and are released immediately): each group streams
        ``{grads, moments}`` H2D and its updated ``{params, moments}`` ride
        ONE pipelined D2H drain back to the home kind — the params
        writeback shares the drain with the streamed-AdamW moments.

    ``param_kind`` names the home tier (``pinned_host`` | ``disk_host`` |
    ``device`` — the bitwise baseline: fetch groups pass through the
    engine by reference and updated groups are re-placed on device).  The
    math per group is exactly :func:`repro.optim.adamw.adamw_leaf_update`
    with globals from the streamed norm, and every kind runs the same
    jitted programs on the same values — streamed runs are bitwise-equal
    to the device-resident run (gated in ``benchmarks/weight_stream.py``).

    ``stats`` accounts the parameter fetch passes (forward + backward) —
    its ``peak_inflight_bytes`` is what ``--device-budget-mb`` bounds;
    ``opt_stats`` accounts the optimizer phase separately.

    ``residency`` is the weight-residency group cache (default: one sized
    to the plan's budget slack — see
    :meth:`WeightStreamPlan.residency_capacity_bytes`; inert at zero
    slack).  Landed fetch groups stay device-resident up to its capacity,
    the last K layer groups are PINNED across the forward→backward
    turnaround so the reverse-order backward's first K groups are hits
    instead of re-fetches, and the optimizer phase REFRESHES every cached
    group in place with the post-update device values (the same bits its
    D2H drain writes to the home) — a stale cached group after the
    group-wise optimizer update would silently train on old weights, so a
    step that fails mid-update clears the cache outright.
    """
    if param_kind == "disk_host" and spill_store is None:
        raise ValueError("param_kind='disk_host' requires a spill_store")
    prefetch = prefetch or PrefetchSpec(
        buffer_size=plan.n_groups + 2, distance="auto"
    )
    mode = "on_demand" if prefetch.on_demand else "prefetch"
    pf = None if mode == "on_demand" else prefetch
    if residency is None and param_kind != "device":
        residency = ResidencyCache(plan.residency_capacity_bytes())
    #: device-kind homes already pass through at zero requests — caching
    #: them would only alias the home groups
    cache = residency if param_kind != "device" else None
    cache_reserved = (
        cache.capacity_bytes or 0
    ) if cache is not None and plan.device_budget_bytes is not None else 0
    own_engine = engine is None
    if engine is None:
        engine = TransferEngine(
            EngineConfig(
                max_distance=plan.max_distance_for_budget(
                    cached_bytes=cache_reserved
                )
            )
        )
    elif (
        plan.device_budget_bytes is not None
        and engine.config.max_distance
        > plan.max_distance_for_budget(cached_bytes=cache_reserved)
    ):
        raise ValueError(
            f"engine max_distance={engine.config.max_distance} exceeds the "
            f"device budget's window cap "
            f"{plan.max_distance_for_budget(cached_bytes=cache_reserved)} "
            "(prefetch window + residency cache share the budget); "
            "configure the engine from the plan"
        )
    # static schedule verification at construction: symbolically execute
    # the three phases at the engine's widest window and fail fast on any
    # budget/hazard/pin violation — a schedule bug surfaces here, not 40
    # minutes into a streamed run (see repro.core.schedcheck)
    verify_schedule(
        analyze_train_schedule(
            plan,
            distance=engine.config.max_distance,
            cached=cache is not None,
            cache_capacity=cache.capacity_bytes if cache is not None else None,
            spill=param_kind == "disk_host",
        )
    )
    stats = stats if stats is not None else StreamStats()
    opt_stats = opt_stats if opt_stats is not None else StreamStats()
    f32 = jnp.float32

    # -- group program maps: the step walks plan.units, one jitted stage per
    # unit (a "moe" unit's groups are buffered until its last group lands)
    units = plan.units
    head_idx = plan.n_groups - 1
    #: group index -> (unit position, True when this group completes its
    #: unit in FORWARD fetch order)
    unit_pos: dict = {}
    for u_i, u in enumerate(units):
        for j, gi in enumerate(u.gidx):
            unit_pos[gi] = (u_i, j == len(u.gidx) - 1)
    #: backward program: strict reverse of the middle groups, then embed
    bwd_order = [plan.groups[i] for i in range(head_idx - 1, 0, -1)] + [
        plan.groups[0]
    ]
    #: backward position -> (unit position, True when this group completes
    #: its unit in REVERSE order — i.e. the unit's forward-first group)
    bwd_map: dict = {}
    for pos, g in enumerate(bwd_order[:-1]):
        u_i, _ = unit_pos[g.index]
        bwd_map[pos] = (u_i, g.index == units[u_i].gidx[0])

    #: the forward→backward turnaround pin set: backward consumes groups in
    #: reverse fetch order, so the LAST groups forward fetched are the FIRST
    #: backward wants — pin as many of them as the cache can hold so they
    #: cannot be evicted between the passes (the double-fetch this PR kills)
    pin_keys: frozenset = frozenset()
    if cache is not None:
        picked: list = []
        total = 0
        for g in bwd_order:
            nb = plan.group_bytes(g, fetch=False)
            if cache.capacity_bytes is not None and total + nb > cache.capacity_bytes:
                break
            picked.append(g.key)
            total += nb
        pin_keys = frozenset(picked)

    def _store(g, fetched, *, pinned: bool = False) -> None:
        """Retain a landed fetch group in the residency cache (home part
        only — the tied head's borrowed embed leaf stays with group 0)."""
        if cache is not None:
            cache.put(
                g.key,
                plan.cache_home_tree(g, fetched),
                plan.group_bytes(g, fetch=False),
                pinned=pinned,
            )

    # -- jitted stage programs (identical for every param kind) -------------
    @jax.jit
    def embed_fwd(group, batch):
        x = transformer.embed_stage(cfg, group, batch, sharder=sharder)
        return x, transformer.stage_angles(cfg, batch, x.shape[1])

    @jax.jit
    def group_fwd(group, x, aux, angles):
        return transformer.block_group_train(cfg, group, x, aux, angles, mesh, sharder)

    def _head_loss(group, x, aux, batch):
        return transformer.head_stage_loss(cfg, group, x, aux, batch)

    @jax.jit
    def head_grad(group, x, aux, batch):
        (loss, metrics), (dp, dx) = jax.value_and_grad(
            _head_loss, argnums=(0, 1), has_aux=True
        )(group, x, aux, batch)
        dp_home, dp_embed = plan.split_head_grads(dp)
        return loss, metrics, dp_home, dp_embed, dx, _leaf_sqsums(dp_home)

    @jax.jit
    def group_bwd(group, x_in, angles, ct_x):
        def f(p, x):
            return transformer.block_group_train(
                cfg, p, x, jnp.zeros((), f32), angles, mesh, sharder
            )

        _, vjp = jax.vjp(f, group, x_in)
        dp, dx = vjp((ct_x, jnp.ones((), f32)))
        return dp, dx, _leaf_sqsums(dp)

    # -- per-unit-kind stages beyond the uniform "layers" pair: the moe unit
    # re-merges its expert groups device-side (bitwise-identical to the
    # unsplit slice), period/block units run the hetero scan/unrolled bodies
    @jax.jit
    def moe_fwd(ne, experts, x, aux, angles):
        merged = merge_expert_slice(ne, experts)
        return transformer.block_group_train(cfg, merged, x, aux, angles, mesh, sharder)

    @jax.jit
    def moe_bwd(ne, experts, x_in, angles, ct_x):
        def f(ne_, ex_, x):
            merged = merge_expert_slice(ne_, ex_)
            return transformer.block_group_train(
                cfg, merged, x, jnp.zeros((), f32), angles, mesh, sharder
            )

        _, vjp = jax.vjp(f, ne, experts, x_in)
        dp_ne, dp_ex, dx = vjp((ct_x, jnp.ones((), f32)))
        return dp_ne, dp_ex, dx, _leaf_sqsums((dp_ne, dp_ex))

    @jax.jit
    def period_fwd(group, x, aux, angles):
        return transformer.period_group_train(cfg, group, x, aux, angles, sharder)

    @jax.jit
    def period_bwd(group, x_in, angles, ct_x):
        def f(p, x):
            return transformer.period_group_train(
                cfg, p, x, jnp.zeros((), f32), angles, sharder
            )

        _, vjp = jax.vjp(f, group, x_in)
        dp, dx = vjp((ct_x, jnp.ones((), f32)))
        return dp, dx, _leaf_sqsums(dp)

    def _make_block_fns(g):
        kinds = tuple(
            (name, cfg.block_kind(l))
            for name, l in zip(plan.block_names(g), range(g.lo, g.hi))
        )

        @jax.jit
        def fwd(group, x, aux, angles):
            return transformer.hetero_group_train(
                cfg, kinds, group, x, aux, angles, sharder
            )

        @jax.jit
        def bwd(group, x_in, angles, ct_x):
            def f(p, x):
                return transformer.hetero_group_train(
                    cfg, kinds, p, x, jnp.zeros((), f32), angles, sharder
                )

            _, vjp = jax.vjp(f, group, x_in)
            dp, dx = vjp((ct_x, jnp.ones((), f32)))
            return dp, dx, _leaf_sqsums(dp)

        return fwd, bwd

    unit_fwd: list = []
    unit_bwd: list = []
    for u in units:
        if u.kind == "layers":
            unit_fwd.append(group_fwd)
            unit_bwd.append(group_bwd)
        elif u.kind == "moe":
            unit_fwd.append(moe_fwd)
            unit_bwd.append(moe_bwd)
        elif u.kind == "period":
            unit_fwd.append(period_fwd)
            unit_bwd.append(period_bwd)
        else:  # "block": kinds are static per group, so one jit per unit
            fwd, bwd = _make_block_fns(plan.groups[u.gidx[0]])
            unit_fwd.append(fwd)
            unit_bwd.append(bwd)

    @jax.jit
    def embed_bwd(group, batch, ct_x, extra):
        def f(p):
            return transformer.embed_stage(cfg, p, batch, sharder=sharder)

        _, vjp = jax.vjp(f, group)
        (dp,) = vjp(ct_x)
        if extra is not None:
            # tied/codebook head: the embedding table's gradient is the sum
            # of the gather path and the head path (autodiff would have
            # summed them in the monolithic graph)
            dp = dict(dp)
            dp["embed"] = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype), dp["embed"], extra
            )
        return dp, _leaf_sqsums(dp)

    @jax.jit
    def globals_fn(sq_chunks, step):
        gnorm = jnp.sqrt(
            jnp.sum(jnp.stack([s for chunk in sq_chunks for s in chunk]))
        )
        return adamw_globals_from_norm(opt_cfg, gnorm, step)

    @jax.jit
    def opt_group(glob, grads_tree, state_tree):
        flat_g, treedef = jax.tree.flatten(grads_tree)
        flat_s = treedef.flatten_up_to(state_tree)
        out = [adamw_leaf_update(opt_cfg, glob, g, s) for g, s in zip(flat_g, flat_s)]
        new_p = treedef.unflatten([p.astype(cfg.compute_dtype) for p, _ in out])
        new_s = treedef.unflatten([s for _, s in out])
        return new_p, new_s

    # -- streamed phase drivers ---------------------------------------------
    box: dict = {}

    def apply_f(i, carry, group):
        g = plan.groups[i]
        _store(g, group, pinned=g.key in pin_keys)
        if i == 0:
            box["x"], box["angles"] = embed_fwd(group, box["batch"])
            box["aux"] = jnp.zeros((), f32)
            box["acts"] = []
            box["parts"] = []
            return box["x"]
        if i == head_idx:
            loss, metrics, dp_home, dp_embed, dx, sq = head_grad(
                group, box["x"], box["aux"], box["batch"]
            )
            box.update(
                loss=loss, metrics=metrics, dp_head_home=dp_home,
                dp_head_embed=dp_embed, ct=dx, sq=[sq],
            )
            return loss
        u_i, last = unit_pos[i]
        box["parts"].append(group)
        if not last:  # moe unit: buffer until every group of the unit landed
            return box["x"]
        parts, box["parts"] = box["parts"], []
        box["acts"].append(box["x"])  # unit-boundary activation checkpoint
        if units[u_i].kind == "moe":
            box["x"], box["aux"] = unit_fwd[u_i](
                parts[0], tuple(parts[1:]), box["x"], box["aux"], box["angles"]
            )
        else:
            box["x"], box["aux"] = unit_fwd[u_i](
                parts[0], box["x"], box["aux"], box["angles"]
            )
        return box["x"]

    def apply_b(i, carry, group):
        g = bwd_order[i]
        _store(g, group)
        if i == len(bwd_order) - 1:  # embed, last in backward order
            dp, sq = embed_bwd(group, box["batch"], box["ct"], box["dp_head_embed"])
            box["sq"].append(sq)
            return box["ct"], dp
        u_i, trigger = bwd_map[i]
        if not trigger:
            # moe unit: experts arrive (reversed) before the non-expert
            # trigger group; their grads drain at the trigger position, so
            # a scalar placeholder keeps the writeback stream aligned
            box["parts"].append(group)
            return box["ct"], jnp.zeros((), f32)
        x_in = box["acts"][u_i]  # reverse fetch order: unit u_i's boundary
        if units[u_i].kind == "moe":
            experts = tuple(reversed(box["parts"]))
            box["parts"] = []
            dp_ne, dp_ex, dx, sq = unit_bwd[u_i](
                group, experts, x_in, box["angles"], box["ct"]
            )
            box["ct"] = dx
            box["sq"].append(sq)
            return dx, {"ne": dp_ne, "ex": dp_ex}
        dp, dx, sq = unit_bwd[u_i](group, x_in, box["angles"], box["ct"])
        box["ct"] = dx
        box["sq"].append(sq)
        return dx, dp

    def apply_o(i, carry, group):
        new_p, new_s = opt_group(box["glob"], group["g"], group["s"])
        if cache is not None:
            # writeback invalidation, done as an update-in-place: the
            # optimizer just made every cached copy of this group stale,
            # and ``new_p`` here is the exact device value whose D2H drain
            # becomes the new home bytes — refreshing with it keeps the
            # cache bitwise-identical to a re-fetch of the new home
            g = o_order[i]
            cache.refresh(
                g.key,
                plan.cache_home_tree(g, new_p),
                plan.group_bytes(g, fetch=False),
            )
        return carry, {"p": new_p, "s": new_s}

    ex_f = HostStreamExecutor(apply_f, indexed=True, engine=engine)
    ex_b = HostStreamExecutor(apply_b, indexed=True, writeback=True, engine=engine)
    ex_o = HostStreamExecutor(apply_o, indexed=True, writeback=True, engine=engine)

    sh_fwd = plan.group_shardings(param_shardings)
    sh_home = plan.home_group_shardings(param_shardings)
    sh_bwd = None
    sh_o = None
    if param_shardings is not None:
        sh_bwd = [sh_fwd[g.index] for g in bwd_order]
        opt_sh = [
            jax.tree.map(
                lambda s: {"master": s, "m": s, "v": s},
                h,
                is_leaf=lambda s: isinstance(s, jax.sharding.NamedSharding),
            )
            for h in sh_home
        ]
        order = [head_idx] + [g.index for g in bwd_order]
        sh_o = [{"g": sh_home[j], "s": opt_sh[j]} for j in order]

    #: phase-O group order: head first (its grads were born on device at the
    #: head stage and pass by reference — consumed and released immediately)
    o_order = [plan.groups[-1]] + bwd_order

    def _rehome(g, p_new, s_new, idx):
        if param_kind == "disk_host":
            spill_store.put(plan.spill_key(g), p_new)
            spill_store.put(f"wopt/{g.key}", s_new)
            return spill_store.get(plan.spill_key(g)), spill_store.get(f"wopt/{g.key}")
        if param_kind == "device":
            sh = sh_home[idx] if sh_home is not None else None
            if sh is None:
                return jax.device_put(p_new), jax.device_put(s_new)
            opt_sh = jax.tree.map(
                lambda s: {"master": s, "m": s, "v": s},
                sh,
                is_leaf=lambda s: isinstance(s, jax.sharding.NamedSharding),
            )
            return jax.device_put(p_new, sh), jax.device_put(s_new, opt_sh)
        return p_new, s_new  # pinned_host: the drained numpy IS the home

    def _step_body(state, batch):
        home, opt = state["params"], state["opt"]
        box.clear()
        box["batch"] = batch

        # phase F: forward fetch order [embed, L0..Ln, head].  With a cache
        # the fetch sequence is thunks resolved at submit time, so each
        # submit sees the residency state the moment the transfer would go
        # out (e.g. the embed group landed two submits ago → the tied head's
        # table leaf is borrowed instead of re-read over the link).
        if cache is not None:
            fwd_groups = plan.fetch_thunks_forward(home, cache)
        else:
            fwd_groups = plan.fetch_groups_forward(home)
        ex_f.run(
            jnp.zeros(()), fwd_groups, mode=mode, prefetch=pf, stats=stats,
            group_shardings=sh_fwd,
            group_keys=[g.key for g in plan.groups],
        )

        # phase B: reverse fetch order [middle reversed, embed]; grads drain
        # D2H.  The pinned turnaround set makes the first fetches cache hits.
        if cache is not None:
            bwd_groups = [
                (lambda g=g: plan.fetch_group(home, g, cache)) for g in bwd_order
            ]
        else:
            bwd_groups = [fwd_groups[g.index] for g in bwd_order]
        _, grad_outs = ex_b.run(
            box["ct"], bwd_groups, mode=mode, prefetch=pf, stats=stats,
            group_shardings=sh_bwd,
            group_keys=[g.key for g in bwd_order],
        )

        step_no = int(np.asarray(opt["step"])) + 1
        box["glob"] = globals_fn(tuple(box["sq"]), step_no)

        # phase O: {grads, moments} H2D, {params, moments} one D2H drain.
        # A moe unit drained all its grads at its trigger position — split
        # them back out so every group (experts included) updates on its own
        grads_by_key = {plan.groups[-1].key: box["dp_head_home"]}
        grads_by_key[plan.groups[0].key] = grad_outs[-1]
        for pos, g in enumerate(bwd_order[:-1]):
            u_i, trigger = bwd_map[pos]
            if not trigger:
                continue
            u = units[u_i]
            if u.kind == "moe":
                out = grad_outs[pos]
                grads_by_key[plan.groups[u.gidx[0]].key] = out["ne"]
                for e_j, gi in enumerate(u.gidx[1:]):
                    grads_by_key[plan.groups[gi].key] = out["ex"][e_j]
            else:
                grads_by_key[g.key] = grad_outs[pos]
        o_groups = [
            {"g": grads_by_key[g.key], "s": opt["groups"][g.key]} for g in o_order
        ]
        _, o_outs = ex_o.run(
            jnp.zeros(()), o_groups, mode=mode, prefetch=pf, stats=opt_stats,
            group_shardings=sh_o,
            group_keys=[g.key for g in o_order],
        )

        new_home: dict = {}
        new_opt: dict = {}
        for g, out in zip(o_order, o_outs):
            p_new, s_new = _rehome(g, out["p"], out["s"], g.index)
            new_home[g.key] = p_new
            new_opt[g.key] = s_new

        glob = box["glob"]
        metrics = {
            "loss": box["loss"], **box["metrics"],
            "grad_norm": glob["grad_norm"], "lr": glob["lr"],
        }
        new_state = {
            "params": {"groups": new_home},
            "opt": {"groups": new_opt, "step": np.asarray(step_no, np.int32)},
        }
        # release the step's device scratch (boundary activations, head
        # grads, cotangents) — it must not outlive the step into the
        # checkpoint/data gap, where the residency model doesn't count it
        box.clear()
        return new_state, metrics

    def step_fn(state, batch):
        if cache is None:
            return _step_body(state, batch)
        try:
            return _step_body(state, batch)
        except BaseException:
            # a step that died mid-optimizer leaves some cached groups
            # refreshed and some stale — indistinguishable from outside, so
            # the only safe cache is an empty one
            cache.clear()
            raise
        finally:
            cache.unpin_all()

    def close():
        for ex in (ex_f, ex_b, ex_o):
            ex.close()
        if own_engine:
            engine.close()
        if cache is not None:
            cache.clear()  # release the resident device copies

    step_fn.close = close  # type: ignore[attr-defined]
    step_fn.param_stats = stats  # type: ignore[attr-defined]
    step_fn.opt_stats = opt_stats  # type: ignore[attr-defined]
    step_fn.engine = engine  # type: ignore[attr-defined]
    step_fn.residency = cache  # type: ignore[attr-defined]
    return step_fn


def make_weight_streamed_prefill_step(
    cfg: ModelConfig,
    plan: WeightStreamPlan,
    batch_size: int,
    seq_len: int,
    mesh=None,
    sharder=None,
    *,
    engine: TransferEngine,
    prefetch: Optional[PrefetchSpec] = None,
    stats: Optional[StreamStats] = None,
    param_shardings: Optional[Pytree] = None,
    residency: Optional[ResidencyCache] = None,
) -> Callable[[dict, Pytree], tuple[jax.Array, Pytree]]:
    """``(home, batch) -> (last-token logits, caches)`` with the params
    streamed group-wise; each layer group fills its stacked cache slice and
    the full cache is concatenated once at the end.

    ``residency`` keeps landed groups device-resident across calls: serve
    params are immutable, so there is no invalidation — a resident group
    passes through the engine at zero requests on every later prefill or
    decode step until the LRU evicts it."""
    prefetch = prefetch or PrefetchSpec(
        buffer_size=plan.n_groups + 2, distance="auto"
    )
    mode = "on_demand" if prefetch.on_demand else "prefetch"
    pf = None if mode == "on_demand" else prefetch
    head_idx = plan.n_groups - 1
    unit_pos = {}
    for u_i, u in enumerate(plan.units):
        for j, gi in enumerate(u.gidx):
            unit_pos[gi] = (u_i, j == len(u.gidx) - 1)

    @jax.jit
    def embed_fwd(group, batch):
        x = transformer.embed_stage(cfg, group, batch, sharder=sharder)
        return x, transformer.stage_angles(cfg, batch, x.shape[1])

    @jax.jit
    def group_prefill(group, x, angles):
        n = jax.tree.leaves(group)[0].shape[0]
        cache = transformer.init_cache_group(
            cfg, n, batch_size, seq_len, cfg.compute_dtype
        )
        return transformer.block_group_prefill(cfg, group, cache, x, angles, sharder)

    @jax.jit
    def moe_prefill(ne, experts, x, angles):
        # prefill overlaps the all-expert fetch with compute: the merged
        # slice is bitwise-identical to the unsplit layer group's
        merged = merge_expert_slice(ne, experts)
        n = jax.tree.leaves(merged)[0].shape[0]
        cache = transformer.init_cache_group(
            cfg, n, batch_size, seq_len, cfg.compute_dtype
        )
        return transformer.block_group_prefill(cfg, merged, cache, x, angles, sharder)

    @jax.jit
    def head_fwd(group, x, last_pos):
        xl = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
        return transformer.head_stage_logits(cfg, group, xl)

    @jax.jit
    def concat0(slices):
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *slices)

    box: dict = {}

    def apply(i, carry, group):
        if residency is not None:
            g = plan.groups[i]
            residency.put(
                g.key, plan.cache_home_tree(g, group),
                plan.group_bytes(g, fetch=False),
            )
        if i == 0:
            box["x"], box["angles"] = embed_fwd(group, box["batch"])
            box["slices"] = []
            box["parts"] = []
            return box["x"]
        if i == head_idx:
            box["logits"] = head_fwd(group, box["x"], box["last_pos"])
            return box["logits"]
        u_i, last = unit_pos[i]
        box["parts"].append(group)
        if not last:
            return box["x"]
        parts, box["parts"] = box["parts"], []
        if plan.units[u_i].kind == "moe":
            box["x"], sl = moe_prefill(
                parts[0], tuple(parts[1:]), box["x"], box["angles"]
            )
        else:
            box["x"], sl = group_prefill(parts[0], box["x"], box["angles"])
        box["slices"].append(sl)
        return box["x"]

    ex = HostStreamExecutor(apply, indexed=True, engine=engine)
    sh_fwd = plan.group_shardings(param_shardings)

    def prefill(home, batch, last_pos=None):
        box.clear()
        box["batch"] = batch
        if last_pos is None:
            # static last position == the batch's sequence length - 1
            # (exact-length prompts; bitwise-identical to the x[:, -1:]
            # slice this path used before bucketed prefill existed)
            seq = jax.tree.leaves(batch)[0].shape[-1]
            last_pos = jnp.asarray(seq - 1, jnp.int32)
        box["last_pos"] = last_pos
        groups = (
            plan.fetch_thunks_forward(home, residency)
            if residency is not None
            else plan.fetch_groups_forward(home)
        )
        ex.run(
            jnp.zeros(()), groups, mode=mode,
            prefetch=pf, stats=stats, group_shardings=sh_fwd,
            group_keys=[g.key for g in plan.groups],
        )
        logits, caches = box["logits"], concat0(tuple(box["slices"]))
        box.clear()  # don't retain the per-group cache slices between calls
        return logits, caches

    prefill.close = ex.close  # type: ignore[attr-defined]
    prefill.residency = residency  # type: ignore[attr-defined]
    return prefill


def make_weight_streamed_decode_step(
    cfg: ModelConfig,
    plan: WeightStreamPlan,
    mesh=None,
    sharder=None,
    *,
    engine: TransferEngine,
    prefetch: Optional[PrefetchSpec] = None,
    stats: Optional[StreamStats] = None,
    param_shardings: Optional[Pytree] = None,
    paged: bool = True,
    residency: Optional[ResidencyCache] = None,
    route_experts: bool = True,
    expert_stats: Optional[StreamStats] = None,
) -> Callable[..., tuple[jax.Array, Pytree]]:
    """Streamed-params decode step.

    ``paged=True``: ``(home, view, batch, pos) -> (logits, caches)`` over a
    pager page view (assembly is the same separate jit as
    :func:`make_paged_decode_step`, so paging composes unchanged).
    ``paged=False``: ``(home, caches, batch, pos)`` over a dense cache.
    Per step the fetch groups stream in forward order while each layer
    group decodes against its static cache slice; the updated slices are
    concatenated back into the dense cache.

    Route-aware expert streaming (``plan.expert_stream``): the pipeline
    fetches only each MoE layer's non-expert group; the stage runs the
    router first (:func:`transformer.block_decode_pre_moe`), then only the
    routed experts' groups are fetched through the engine — resident
    experts (the expert-granular LRU in ``residency``) pass through at zero
    link bytes.  ``route_experts=False`` fetches all E experts through the
    SAME path (the bench's all-expert baseline).  Expert fetch traffic is
    accounted in ``expert_stats`` (its per-tier
    ``requests_per_fetched_device_group`` stays 1.0: one coalesced request
    per fetched expert group per device); the jitted apply re-traces per
    distinct routed-subset size, which is bounded by ``moe_top_k``·batch.
    The routed output is bitwise-equal to the all-expert and
    device-resident runs: the gather of the routed rows happens before any
    arithmetic, so the subset stage computes on the exact same values.
    """
    from repro.core import kvpager

    prefetch = prefetch or PrefetchSpec(
        buffer_size=plan.n_groups + 2, distance="auto"
    )
    mode = "on_demand" if prefetch.on_demand else "prefetch"
    pf = None if mode == "on_demand" else prefetch
    if plan.expert_stream and expert_stats is None:
        expert_stats = StreamStats()
    #: the pipeline program: expert groups are fetched on demand AFTER the
    #: router runs, so only each unit's first group rides the fetch pipeline
    #: (identical to plan.groups when no unit spans multiple groups)
    prog = (
        [plan.groups[0]]
        + [plan.groups[u.gidx[0]] for u in plan.units]
        + [plan.groups[-1]]
    )
    head_pos = len(prog) - 1
    bounds = [(u.lo, u.hi) for u in plan.units]

    @jax.jit
    def split(caches):
        return tuple(
            jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, lo, hi, axis=0), caches
            )
            for lo, hi in bounds
        )

    @jax.jit
    def embed_dec(group, batch, pos):
        x = transformer.embed_stage(cfg, group, batch, pos=pos, sharder=sharder)
        return x, transformer.stage_angles(cfg, batch, 1, pos=pos)

    @jax.jit
    def group_dec(group, cache_slice, x, angles, pos):
        return transformer.block_group_decode(
            cfg, group, cache_slice, x, angles, pos, sharder
        )

    @jax.jit
    def ne_dec(group, cache_slice, x, angles, pos):
        return transformer.block_decode_pre_moe(
            cfg, group, cache_slice, x, angles, pos, sharder
        )

    @jax.jit
    def moe_apply(parts, ids, top_w, top_i, x_attn, h2):
        # gather-then-cast over the fetched subset: the stacked rows are the
        # same bytes the full (L, E, d, f) home holds, so this is bitwise-
        # equal to moe.moe_decode over the unsplit layer group
        stack = {
            n: jnp.concatenate([t[n] for t in parts], axis=0)
            for n in plan.expert_names
        }
        local = jnp.searchsorted(ids, top_i)
        y = moe.decode_apply(cfg, stack, top_w, local, h2)
        x = x_attn + y
        if sharder is not None:
            x = sharder.acts(x)
        return x

    @jax.jit
    def head_dec(group, x):
        return transformer.head_stage_logits(cfg, group, x)

    @jax.jit
    def concat0(slices):
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *slices)

    assemble = jax.jit(kvpager.assemble_view)
    sh_all = plan.group_shardings(param_shardings)
    sh_prog = [sh_all[g.index] for g in prog] if sh_all is not None else None
    box: dict = {}

    def _fetch_experts(home, gs):
        """Fetch routed expert groups through the engine (submit-all, then
        wait in order), with the executor's submit/wait accounting mirrored
        into ``expert_stats``; landed groups enter the residency LRU."""
        st = expert_stats
        futs = []
        live = 0
        for g in gs:
            tree = residency.lookup(g.key) if residency is not None else None
            if residency is not None and getattr(residency, "sanitize", False):
                residency.sanitize_home(
                    g.key, home["groups"][g.key], hit=tree is not None
                )
            if tree is None:
                tree = home["groups"][g.key]
            sh = sh_all[g.index] if sh_all is not None else None
            fut = engine.submit_group(
                g.index, tree, device_shardings=sh, key=g.key
            )
            if st is not None:
                st.n_transfers += 1
                st.n_groups += 1
                st.h2d_requests += fut.n_requests
                st.bytes_h2d += fut.nbytes
                st.disk_requests += fut.disk_requests
                st.bytes_disk += fut.disk_nbytes
                st.n_devices = max(st.n_devices, fut.n_devices)
                st.n_device_groups += fut.n_devices
                if fut.is_resident:
                    st.cache_hits += 1
                else:
                    st.cache_misses += 1
                    st.unique_group_fetches += 1
                    st.fetched_device_groups += fut.n_devices
                live += fut.nbytes
                st.peak_inflight_bytes = max(st.peak_inflight_bytes, live)
            futs.append((g, fut))
        parts = []
        for g, fut in futs:
            try:
                w = fut.wait()
            except BaseException:
                if st is not None:
                    st.retries += fut.retries
                    st.give_ups += 1
                raise
            if st is not None:
                st.retries += fut.retries
                st.transfer_wait_s += w
                st.wait_per_group.append(w)
                st.disk_wait_s += fut.disk_wait_s
                st.disk_wait_per_group.append(fut.disk_wait_s)
            landed = fut.group()
            if residency is not None:
                residency.put(
                    g.key, landed, plan.group_bytes(g, fetch=False)
                )
            parts.append(landed)
        return parts

    def apply(i, carry, group):
        g = prog[i]
        if residency is not None:
            residency.put(
                g.key, plan.cache_home_tree(g, group),
                plan.group_bytes(g, fetch=False),
            )
        if i == 0:
            box["x"], box["angles"] = embed_dec(group, box["batch"], box["pos"])
            box["new_slices"] = []
            return box["x"]
        if i == head_pos:
            box["logits"] = head_dec(group, box["x"])
            return box["logits"]
        u = plan.units[i - 1]
        if u.kind == "moe":
            x_attn, h2, top_w, top_i, sl = ne_dec(
                group, box["slices"][i - 1], box["x"], box["angles"], box["pos"]
            )
            if route_experts:
                ids = np.unique(np.asarray(jax.device_get(top_i))).astype(
                    np.int32
                )
            else:
                ids = np.arange(cfg.n_experts, dtype=np.int32)
            eg = plan.experts_for_layer(u.lo)
            parts = _fetch_experts(box["home"], [eg[e] for e in ids])
            box["x"] = moe_apply(
                tuple(parts), jnp.asarray(ids), top_w, top_i, x_attn, h2
            )
            box["new_slices"].append(sl)
            return box["x"]
        box["x"], sl = group_dec(
            group, box["slices"][i - 1], box["x"], box["angles"], box["pos"]
        )
        box["new_slices"].append(sl)
        return box["x"]

    ex = HostStreamExecutor(apply, indexed=True, engine=engine)

    def decode(home, caches, batch, pos):
        box.clear()
        box["batch"] = batch
        box["pos"] = pos
        box["home"] = home
        box["slices"] = split(caches)
        if residency is not None:
            groups = [
                (lambda g=g: plan.fetch_group(home, g, residency)) for g in prog
            ]
        else:
            groups = [plan.fetch_group(home, g) for g in prog]
        ex.run(
            jnp.zeros(()), groups, mode=mode,
            prefetch=pf, stats=stats, group_shardings=sh_prog,
            group_keys=[g.key for g in prog],
        )
        logits, new_caches = box["logits"], concat0(tuple(box["new_slices"]))
        # a serving session calls this every step: dropping the old/new
        # slice views here keeps cross-step cache residency at ONE dense
        # cache, not three, while the pager prefetches the next cold set
        box.clear()
        return logits, new_caches

    if paged:
        def paged_decode(home, view, batch, pos):
            return decode(home, assemble(view), batch, pos)

        paged_decode.close = ex.close  # type: ignore[attr-defined]
        paged_decode.dense = decode  # type: ignore[attr-defined]
        paged_decode.residency = residency  # type: ignore[attr-defined]
        paged_decode.expert_stats = expert_stats  # type: ignore[attr-defined]
        return paged_decode
    decode.close = ex.close  # type: ignore[attr-defined]
    decode.residency = residency  # type: ignore[attr-defined]
    decode.expert_stats = expert_stats  # type: ignore[attr-defined]
    return decode


def make_prefill_step(
    cfg: ModelConfig, batch_size: int, seq_len: int, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree], tuple[jax.Array, Pytree]]:
    """``(params, batch) -> (last-token logits, caches)``.

    Caches are created inside the step (zeros) so the step's out-shardings
    place them; context length is the shape's ``seq_len``.

    ``last_pos`` (optional traced int32 scalar): the last *real* prompt
    position when the batch is right-padded into a length bucket — the
    serve path's bounded-compile prefill returns that position's logits
    instead of the pad tail's.
    """

    def prefill_step(params, batch, last_pos=None):
        caches = transformer.init_caches(cfg, batch_size, seq_len, cfg.compute_dtype)
        return transformer.prefill(
            cfg, params, batch, caches, mesh, sharder, last_pos=last_pos
        )

    return prefill_step


def make_decode_step(
    cfg: ModelConfig, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]]:
    """``(params, caches, batch, pos) -> (logits, caches)`` — one new token
    against a populated decode state (KV cache / recurrent state)."""

    def decode_step(params, caches, batch, pos):
        return transformer.decode_step(cfg, params, batch, caches, pos, sharder)

    return decode_step


def make_paged_decode_step(
    cfg: ModelConfig, mesh=None, sharder=None, *, donate_cache: bool = True
) -> Callable[[Pytree, Any, Pytree, jax.Array], tuple[jax.Array, Pytree]]:
    """``(params, view, batch, pos) -> (logits, caches)`` over a paged KV
    cache (see :mod:`repro.core.kvpager`).

    ``view`` is the pager's per-slot tuple of page pytrees; ``pos`` is the
    (B,) vector of per-slot context positions.  Assembly (pure page
    concatenation) is a *separate* jit from the decode executable, so the
    paged step runs the exact same decode program as
    :func:`make_decode_step` on the exact same cache values — paged and
    unpaged decode are bitwise-equal by construction.  The assembled dense
    view is donated into the step (``donate_cache``): it is a per-step
    transient, never the pager's retained hot pages (concatenation always
    produces a fresh buffer).
    """
    from repro.core import kvpager

    decode_fn = jax.jit(
        make_decode_step(cfg, mesh, sharder),
        donate_argnums=(1,) if donate_cache else (),
    )
    assemble = jax.jit(kvpager.assemble_view)

    def paged_decode_step(params, view, batch, pos):
        return decode_fn(params, assemble(view), batch, pos)

    paged_decode_step.decode_fn = decode_fn  # type: ignore[attr-defined]
    paged_decode_step.assemble = assemble  # type: ignore[attr-defined]
    return paged_decode_step


def init_train_state(
    key: jax.Array, cfg: ModelConfig
) -> tuple[Pytree, Pytree]:
    """(bf16 params, AdamW state with f32 master) for a fresh run."""
    params_f32 = transformer.init_model(key, cfg)
    opt_state = adamw_init(params_f32)
    params = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), params_f32)
    return params, opt_state


def abstract_train_state(cfg: ModelConfig) -> tuple[Pytree, Pytree]:
    """ShapeDtypeStruct pytrees of (params, opt_state) — no allocation."""
    def build():
        return init_train_state(jax.random.PRNGKey(0), cfg)

    return jax.eval_shape(build)


def abstract_params(cfg: ModelConfig) -> Pytree:
    def build():
        p = transformer.init_model(jax.random.PRNGKey(0), cfg)
        return jax.tree.map(lambda x: x.astype(cfg.compute_dtype), p)

    return jax.eval_shape(build)


def abstract_caches(cfg: ModelConfig, batch: int, seq_len: int) -> Pytree:
    return jax.eval_shape(
        lambda: transformer.init_caches(cfg, batch, seq_len, cfg.compute_dtype)
    )

"""Public jit'd wrapper for flash-decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.refspec import PrefetchSpec
from repro.kernels.decode_attention.kernel import decode_attention_p

_DEFAULT_SPEC = PrefetchSpec(buffer_size=2, elements_per_fetch=1, distance=1)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("spec", "block_kv", "interpret"))
def decode_attention(
    q: jax.Array,  # (B, N, H)
    k: jax.Array,  # (B, T, KH, H)
    v: jax.Array,  # (B, T, KH, H)
    lengths: jax.Array,  # (B,) int32 — valid prefix per sequence
    *,
    spec: PrefetchSpec = _DEFAULT_SPEC,
    block_kv: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """One-token GQA attention vs a large KV cache streamed from HBM.

    Matches ``ref.decode_attention_ref``; the PrefetchSpec only changes the
    DMA schedule, never the value (property-tested).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, n, h = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = n // kh

    bkv = min(block_kv, _ceil_to(t, 128))
    tp = _ceil_to(t, bkv)

    qg = q.reshape(b, kh, g, h).reshape(b * kh, g, h)
    kg = k.transpose(0, 2, 1, 3).reshape(b * kh, t, h)
    vg = v.transpose(0, 2, 1, 3).reshape(b * kh, t, h)
    kg = jnp.pad(kg, ((0, 0), (0, tp - t), (0, 0)))
    vg = jnp.pad(vg, ((0, 0), (0, tp - t), (0, 0)))
    lens = jnp.repeat(lengths.astype(jnp.int32), kh)

    out = decode_attention_p(
        qg, kg, vg, lens, spec=spec, block_kv=bkv, interpret=interpret
    )
    return out.reshape(b, kh, g, h).reshape(b, n, h)

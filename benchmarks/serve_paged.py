"""Paged KV-cache serving study: decode through the hierarchy vs per-step placement.

The pre-pager serving path round-tripped the ENTIRE KV cache through host
memory synchronously on every decode step.  This suite serves the same
requests four ways under the modeled Epiphany link (the paper's §5.1
constants — request cost + serial bandwidth):

  * ``sync``        unpaged, host-homed: whole-cache D2H + H2D, blocking,
                    per decode step (the seed schedule, fixed bugs only),
  * ``paged d=1``   cold pages streamed with a fixed window of 1,
  * ``paged auto``  per-request ``AdaptiveDistance`` window,
  * ``paged disk``  cold pages homed at the DiskHost tier (second link),

plus an all-device paged reference run.  Pass gates (the PR acceptance):

  * every schedule generates bitwise-identical tokens,
  * steady-state per-step decode ``transfer_wait`` at ``auto`` is >= 2x
    lower than the synchronous per-step placement,
  * coalescing: exactly 1 H2D request per fetched page group,
  * host/disk-homed decode retains less device memory than the full cache
    (contexts larger than the device budget).

Emits ``results/bench/BENCH_serve.json``.  ``REPRO_BENCH_SMOKE=1`` (set by
``benchmarks/run.py --smoke``) shrinks the workload for CI.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import common as C
from repro.configs import get_smoke_config
from repro.core.engine import EngineConfig, LinkModel, TransferEngine
from repro.core.refspec import AUTO
from repro.launch import serve as sv
from repro.launch.mesh import make_local_mesh

SMOKE = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))

#: page length trades request count against overlap headroom: on this
#: container a decode step is only ~10 ms of wall compute, so the per-step
#: cold set is kept to a few large groups (the paper's "elements per
#: pre-fetch" lever — coalescing beats fine-grained pages when the compute
#: window is short)
BATCH = 2
PAGE_LEN = 32
PROMPT = 64 if SMOKE else 96
GEN = 16 if SMOKE else 32

#: the paper's Epiphany-class link (per-request service cost + serial
#: bandwidth), slowed to 40 MB/s so the modeled cost dominates scheduler
#: noise on shared CI runners
HOST_LINK = LinkModel(request_s=0.3e-3, bandwidth_Bps=40e6, latency_s=0.0)
#: disk tier: slower per request, high overlappable latency
DISK_LINK = LinkModel(request_s=0.5e-3, bandwidth_Bps=40e6, latency_s=2e-3)


def _tail(xs, frac=0.5):
    """Median per-step wait over the steady-state tail (median, not mean:
    wall-clock spikes from CPU contention with the XLA threadpool would
    otherwise dominate the deterministic link-model signal)."""
    xs = list(xs)
    if not xs:
        return 0.0
    tail = sorted(xs[int(len(xs) * frac):])
    return tail[len(tail) // 2]


def _row(name, kind, distance, res) -> dict:
    st = res["stats"]
    row = {
        "schedule": name,
        "kv_kind": kind,
        "distance": str(distance),
        "paged": res["paged"],
        "decode_s": res["decode_s"],
        "tokens_per_s": res["tokens_per_s"],
        "transfer_wait_s": st.transfer_wait_s,
        "tail_step_wait_s": _tail(res["step_waits"]),
        "h2d_requests": st.h2d_requests,
        "d2h_requests": st.d2h_requests,
        "n_groups": st.n_groups,
        "requests_per_group": st.requests_per_group,
        "per_tier": st.per_tier(),
        "final_distance": st.distance_trace[-1] if st.distance_trace else None,
    }
    if res["paged"]:
        row.update(
            peak_resident_bytes=res["peak_resident_bytes"],
            total_cache_bytes=res["total_cache_bytes"],
            demoted_groups=res["demoted_groups"],
            stale_drops=res["stale_drops"],
        )
    return row


def run(tag: str = "BENCH_serve") -> list[dict]:
    cfg = get_smoke_config("smollm-360m")
    mesh = make_local_mesh()
    kw = dict(batch=BATCH, prompt_len=PROMPT, gen=GEN, seed=0)

    cases = [
        ("sync", "pinned_host", 0, "-"),
        ("paged", "device", PAGE_LEN, AUTO),
        ("paged", "pinned_host", PAGE_LEN, 1),
        ("paged", "pinned_host", PAGE_LEN, AUTO),
        ("paged", "disk_host", PAGE_LEN, AUTO),
    ]
    rows, gens = [], {}
    for name, kind, page_len, dist in cases:
        engine = TransferEngine(EngineConfig(link=HOST_LINK, disk_link=DISK_LINK))
        try:
            res = sv.serve(
                cfg,
                mesh,
                kv_kind=kind,
                kv_page_len=page_len,
                distance=dist if dist != "-" else AUTO,
                engine=engine,
                **kw,
            )
        finally:
            engine.close()
        label = f"{name}:{kind}:{dist}"
        gens[label] = res["generated"]
        rows.append(_row(name, kind, dist, res))

    C.print_table(
        "paged KV-cache serving (modeled Epiphany link)",
        rows,
        ["schedule", "kv_kind", "distance", "decode_s", "transfer_wait_s",
         "tail_step_wait_s", "h2d_requests", "requests_per_group",
         "final_distance"],
    )
    # every schedule must decode the same tokens, bitwise
    ref = gens["paged:device:auto"]
    for label, g in gens.items():
        assert np.array_equal(g, ref), f"{label} diverged from the device run"
    C.save_rows(tag, rows)
    return rows


def main() -> int:
    rows = run()
    by = {(r["schedule"], r["kv_kind"], r["distance"]): r for r in rows}
    sync = by[("sync", "pinned_host", "-")]
    d1 = by[("paged", "pinned_host", "1")]
    auto = by[("paged", "pinned_host", str(AUTO))]
    disk = by[("paged", "disk_host", str(AUTO))]
    dev = by[("paged", "device", str(AUTO))]

    # >= 2x: the PR acceptance gate (steady-state per-step compute wait)
    beats_sync = auto["tail_step_wait_s"] * 2.0 <= sync["tail_step_wait_s"]
    # adaptive window at least matches the fixed minimal window (0.1 ms
    # slack: when the window covers the whole cold set both are ~zero)
    beats_d1 = auto["tail_step_wait_s"] <= d1["tail_step_wait_s"] + 1e-4
    # coalescing: one H2D request per fetched page group; none for device
    one_req = (
        auto["h2d_requests"] == auto["n_groups"]
        and disk["h2d_requests"] == disk["n_groups"]
        and disk["per_tier"]["disk"]["requests"] == disk["n_groups"]
        and dev["h2d_requests"] == 0
    )
    # the hierarchy buys headroom: device retains less than the full cache
    bounded = all(
        r["peak_resident_bytes"] < r["total_cache_bytes"] for r in (auto, disk)
    )

    print(
        f"steady per-step wait: auto {auto['tail_step_wait_s']*1e3:.3f} ms vs "
        f"sync {sync['tail_step_wait_s']*1e3:.3f} ms "
        f"({sync['tail_step_wait_s']/max(auto['tail_step_wait_s'], 1e-9):.1f}x, "
        f"gate >= 2x) vs d=1 {d1['tail_step_wait_s']*1e3:.3f} ms; "
        f"requests/group {auto['requests_per_group']:.0f} (gate: 1); "
        f"resident {auto['peak_resident_bytes']}/{auto['total_cache_bytes']} B "
        f"(gate: < total); final window {auto['final_distance']}"
    )
    return 0 if (beats_sync and beats_d1 and one_req and bounded) else 1


if __name__ == "__main__":
    raise SystemExit(main())

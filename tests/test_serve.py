"""Serving-path tests: paged KV cache, continuous batching, bugfix pins.

Covers the PR's acceptance surface:
  * prefill+decode smoke on the smollm-360m smoke config,
  * paged vs unpaged bitwise equality for every kv kind x page length,
  * eviction + readmission of a request mid-decode (bitwise resume),
  * per-tier StreamStats accounting (1 H2D request per fetched page group,
    one disk request per disk-homed group, writebacks per demotion),
  * the seed bugfix pins: plan-spec placement under model parallelism
    (subprocess, 2-way mesh), no deleted-buffer error with host-kind
    caches, --seed plumbed through,
  * the paged flash-decode kernel view (bitwise vs the dense cache).
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memkind as mk
from repro.core.engine import TransferEngine
from repro.core.hoststream import StreamStats
from repro.core.kvpager import KVPager, KVPagerConfig, paged_cache_supported
from repro.core.refspec import AUTO
from repro.launch import serve as sv
from repro.launch.mesh import make_local_mesh
from repro.train import steps as st


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("smollm-360m")


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()


@pytest.fixture(scope="module")
def reference(cfg, mesh):
    """All-device unpaged run: the baseline every placement must match."""
    return sv.serve(
        cfg, mesh, batch=2, prompt_len=21, gen=8, kv_kind="device",
        kv_page_len=0, seed=7,
    )


def test_prefill_decode_smoke(reference):
    gen = reference["generated"]
    assert gen.shape == (2, 8)
    assert gen.dtype == np.int32
    assert (gen >= 0).all()
    assert reference["prefill_s"] > 0 and reference["decode_s"] > 0


@pytest.mark.parametrize("kv_kind", ["device", "pinned_host", "disk_host"])
@pytest.mark.parametrize("page_len", [4, 8])
def test_paged_bitwise_equals_unpaged(cfg, mesh, reference, kv_kind, page_len):
    res = sv.serve(
        cfg, mesh, batch=2, prompt_len=21, gen=8, kv_kind=kv_kind,
        kv_page_len=page_len, seed=7,
    )
    assert np.array_equal(res["generated"], reference["generated"])
    if kv_kind != "device":
        # the hierarchy must actually bound the device working set
        assert res["peak_resident_bytes"] < res["total_cache_bytes"]


def test_unpaged_host_kind_bitwise_and_no_deleted_buffer(cfg, mesh, reference):
    """Satellite bugfix pin: the host-kind unpaged path re-places the cache
    every step; with unconditional donation this raised a deleted-buffer
    error.  Must run clean and match the device run bitwise."""
    res = sv.serve(
        cfg, mesh, batch=2, prompt_len=21, gen=8, kv_kind="pinned_host",
        kv_page_len=0, seed=7,
    )
    assert np.array_equal(res["generated"], reference["generated"])
    assert res["stats"].d2h_requests > 0  # the round trip actually happened


def test_seed_is_plumbed(cfg, mesh):
    """Satellite bugfix pin: ``seed`` reaches param init (the seed repo
    dropped it between main() and serve())."""
    a = sv.serve(cfg, mesh, batch=1, prompt_len=9, gen=4, kv_page_len=4, seed=1)
    b = sv.serve(cfg, mesh, batch=1, prompt_len=9, gen=4, kv_page_len=4, seed=1)
    c = sv.serve(cfg, mesh, batch=1, prompt_len=9, gen=4, kv_page_len=4, seed=2)
    assert np.array_equal(a["generated"], b["generated"])
    assert not np.array_equal(a["generated"], c["generated"])


def test_gen1_request_retires_with_pending_demotions(cfg, mesh):
    """A gen==1 request finishes straight from admission, while its
    admission demotions are still in flight — retire must flush them
    before dropping the page records (regression: IndexError)."""
    res = sv.serve(
        cfg, mesh, batch=1, prompt_len=12, gen=1, kv_kind="pinned_host",
        kv_page_len=4, seed=0,
    )
    assert res["generated"].shape == (1, 1)


def test_failed_session_constructor_does_not_leak(mesh):
    """Bad pager knobs must be rejected before the engine thread / spill
    dir are allocated."""
    import threading

    mx = get_smoke_config("smollm-360m")
    n0 = threading.active_count()
    with pytest.raises(ValueError, match="hot_pages"):
        sv.ServeSession(
            mx, mesh, slots=1, max_len=16, kv_kind="pinned_host",
            page_len=4, hot_pages=-1,
        )
    assert threading.active_count() == n0  # no orphaned engine worker


def test_codebook_arch_serves_paged_and_unpaged(mesh):
    """Audio (codebook) archs: the prompt/step batches carry ``codes``; the
    paged and unpaged paths must both work and agree (regression: the serve
    rewrite briefly dropped the codes branch)."""
    mg = get_smoke_config("musicgen-medium")
    u = sv.serve(mg, mesh, batch=2, prompt_len=9, gen=5, kv_kind="device",
                 kv_page_len=0, seed=0)
    p = sv.serve(mg, mesh, batch=2, prompt_len=9, gen=5,
                 kv_kind="pinned_host", kv_page_len=4, seed=0)
    assert u["generated"].shape == (2, 5)
    assert np.array_equal(u["generated"], p["generated"])


def test_ring_cache_arch_serves_unpaged(mesh):
    """SWA ring caches cannot page (shared slot_pos) but the unpaged
    lock-step path must still serve them (regression: the vector-pos
    rewrite briefly broke it)."""
    mx = get_smoke_config("mixtral-8x7b")
    r = sv.serve(mx, mesh, batch=2, prompt_len=9, gen=4,
                 kv_kind="pinned_host", kv_page_len=0, seed=0)
    assert r["generated"].shape == (2, 4)
    with pytest.raises(ValueError, match="not pageable"):
        sv.serve(mx, mesh, batch=2, prompt_len=9, gen=4,
                 kv_kind="pinned_host", kv_page_len=4, seed=0)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def test_slot_reuse_and_late_admission(cfg, mesh):
    """More requests than slots: finished requests retire and their slot is
    reused; every request still matches its dedicated-run tokens."""
    prompts = {
        0: np.arange(1, 10, dtype=np.int32),         # 9 tokens
        1: np.arange(3, 16, dtype=np.int32),         # 13 tokens (pad-free)
        2: np.arange(5, 12, dtype=np.int32),
    }
    gens = {0: 6, 1: 3, 2: 5}

    def solo(rid):
        with sv.ServeSession(
            cfg, mesh, slots=1, max_len=24, kv_kind="pinned_host",
            page_len=4, seed=11,
        ) as s:
            s.submit(prompts[rid], gens[rid])
            return s.run()[0]

    expected = {rid: solo(rid) for rid in prompts}

    with sv.ServeSession(
        cfg, mesh, slots=2, max_len=24, kv_kind="pinned_host", page_len=4,
        seed=11,
    ) as s:
        rids = {rid: s.submit(prompts[rid], gens[rid]) for rid in prompts}
        out = s.run()
    for rid in prompts:
        assert np.array_equal(out[rids[rid]], expected[rid]), rid


@pytest.mark.parametrize("kv_kind", ["pinned_host", "disk_host"])
def test_evict_readmit_mid_decode(cfg, mesh, kv_kind):
    """A request parked at the host mid-decode and readmitted later must
    finish with exactly the tokens of an uninterrupted run."""
    prompt = np.arange(1, 14, dtype=np.int32)
    other = np.arange(2, 11, dtype=np.int32)

    def run(interrupt):
        with sv.ServeSession(
            cfg, mesh, slots=2, max_len=32, kv_kind=kv_kind, page_len=4,
            hot_pages=1, seed=5,
        ) as s:
            rid = s.submit(prompt, 10)
            s.submit(other, 12)
            s.admit_pending()
            for _ in range(3):
                s.step()
            if interrupt:
                s.evict(rid)
                assert rid not in s.active
                s.step()  # the other request decodes on without it
                s.readmit(rid)
            while s.pending_work():
                s.step()
            return np.asarray(s.requests[rid].emitted, np.int32)

    assert np.array_equal(run(True), run(False))


# ---------------------------------------------------------------------------
# stats accounting
# ---------------------------------------------------------------------------


def test_stream_stats_per_tier_accounting(cfg, mesh):
    """1 H2D request per fetched page group; disk groups add exactly one
    disk request each; demotions drain through D2H."""
    res = sv.serve(
        cfg, mesh, batch=2, prompt_len=16, gen=9, kv_kind="pinned_host",
        kv_page_len=4, hot_pages=1, seed=3,
    )
    stats = res["stats"]
    assert stats.n_groups > 0
    assert stats.h2d_requests == stats.n_groups  # coalesced: 1 req/group
    assert stats.disk_requests == 0
    assert res["demoted_groups"] > 0
    # each demoted page group drains k+v leaves through the D2H pipeline
    assert stats.d2h_requests == 2 * res["demoted_groups"]

    resd = sv.serve(
        cfg, mesh, batch=2, prompt_len=16, gen=9, kv_kind="disk_host",
        kv_page_len=4, hot_pages=1, seed=3,
    )
    sd = resd["stats"]
    assert sd.h2d_requests == sd.n_groups
    assert sd.disk_requests == sd.n_groups  # one chunk file per page group
    per = sd.per_tier()
    assert per["disk"]["requests"] == sd.disk_requests
    assert per["h2d"]["bytes"] == sd.bytes_h2d > 0


def test_device_kind_never_transfers(cfg, mesh):
    res = sv.serve(
        cfg, mesh, batch=2, prompt_len=16, gen=6, kv_kind="device",
        kv_page_len=4, seed=3,
    )
    stats = res["stats"]
    assert stats.h2d_requests == 0
    assert stats.d2h_requests == 0
    assert stats.transfer_wait_s == 0.0


def test_adaptive_distance_grows_under_modeled_link(cfg, mesh):
    from repro.core.engine import EngineConfig, LinkModel

    engine = TransferEngine(
        EngineConfig(link=LinkModel(request_s=0.2e-3, bandwidth_Bps=88e6))
    )
    try:
        res = sv.serve(
            cfg, mesh, batch=1, prompt_len=24, gen=10, kv_kind="pinned_host",
            kv_page_len=4, distance=AUTO, engine=engine, seed=3,
        )
    finally:
        engine.close()
    assert res["stats"].distance_trace[-1] > 1  # the window actually grew


# ---------------------------------------------------------------------------
# pager unit coverage
# ---------------------------------------------------------------------------


def test_pager_rejects_unpageable_cache():
    rg = get_smoke_config("recurrentgemma-2b")
    template = st.abstract_caches(rg, 1, 16)
    assert not paged_cache_supported(template)
    engine = TransferEngine()
    try:
        with pytest.raises(ValueError, match="full-attention"):
            KVPager(
                template, KVPagerConfig(page_len=4), slots=1, engine=engine
            )
    finally:
        engine.close()


def test_pager_requires_page_aligned_length(cfg):
    template = st.abstract_caches(cfg, 1, 18)
    engine = TransferEngine()
    try:
        with pytest.raises(ValueError, match="multiple"):
            KVPager(
                template, KVPagerConfig(page_len=4), slots=1, engine=engine
            )
    finally:
        engine.close()


def test_disk_kind_requires_store(cfg):
    template = st.abstract_caches(cfg, 1, 16)
    engine = TransferEngine()
    try:
        with pytest.raises(ValueError, match="SpillStore"):
            KVPager(
                template,
                KVPagerConfig(page_len=4, kind=mk.DISK_HOST),
                slots=1,
                engine=engine,
            )
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# paged flash-decode kernel view
# ---------------------------------------------------------------------------


def test_decode_attention_paged_matches_dense():
    from repro.kernels.decode_attention import (
        decode_attention,
        decode_attention_paged,
    )

    b, n, kh, h, t, page = 2, 4, 2, 16, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, n, h), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kh, h), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kh, h), jnp.float32)
    lengths = jnp.array([t, t - 7], jnp.int32)

    dense = decode_attention(q, k, v, lengths, block_kv=page, interpret=True)
    k_pages = [k[:, i : i + page] for i in range(0, t, page)]
    v_pages = [v[:, i : i + page] for i in range(0, t, page)]
    paged = decode_attention_paged(
        q, k_pages, v_pages, lengths, block_kv=page, interpret=True
    )
    assert jnp.array_equal(dense, paged)  # bitwise: the view is a reference


# ---------------------------------------------------------------------------
# model-parallel placement regression (satellite bugfix, 2-way mesh)
# ---------------------------------------------------------------------------

_MP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
from jax.sharding import PartitionSpec as P
from repro.configs import get_smoke_config
from repro.core import memkind as mk
from repro.jaxcompat import make_mesh
from repro.models import transformer
from repro.parallel import sharding as sh

cfg = get_smoke_config("smollm-360m")
mesh = make_mesh((1, 2), ("data", "model"))
plan = sh.make_plan(mesh, mode="serve")
batch = 2
caches = jax.jit(lambda: transformer.init_caches(cfg, batch, 16))()
specs = sh.cache_specs_tree(plan, caches, batch)
placed = mk.place(caches, mesh, specs, mk.as_kind("pinned_host"))
back = mk.place(placed, mesh, specs, mk.DEVICE)
flat_b = jax.tree.leaves(back)
flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
# regression: the seed placed with a bare P() and silently dropped the plan;
# round-tripped caches must keep the plan's spec (head dim sharded 2-way)
assert any(any(ax is not None for ax in s) for s in flat_s), flat_s
for leaf, spec in zip(flat_b, flat_s):
    got = leaf.sharding.spec
    assert got == spec, (got, spec)
print("MP_PLACEMENT_OK")
"""


@pytest.mark.slow
def test_cache_placement_keeps_plan_specs_2way_mesh():
    proc = subprocess.run(
        [sys.executable, "-c", _MP_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MP_PLACEMENT_OK" in proc.stdout


# ---------------------------------------------------------------------------
# fault injection / shutdown hardening (ISSUE 5 satellites)
# ---------------------------------------------------------------------------


def test_session_close_with_inflight_prefetch_and_undrained_demotions(cfg, mesh, tmp_path):
    """close() while page prefetches are in flight and demotion writebacks
    are undrained must shut down cleanly: workers drain and join, the
    read-ahead window frees, staging pools stay bounded, and the ephemeral
    spill dir is removed."""
    from test_engine_faults import run_with_timeout

    def body():
        import os

        s = sv.ServeSession(
            cfg, mesh, slots=2, max_len=24, kv_kind="disk_host",
            page_len=4, hot_pages=0, seed=3,
        )
        spill_dir = s._store.dir
        rids = [s.submit(np.arange(1, 10 + i, dtype=np.int32), 8) for i in range(2)]
        s.admit_pending()
        for _ in range(2):
            s.step()
        # leave demotions undrained and prefetches in flight, then close
        for rid in rids:
            table = s.pager.tables[rid]
            p = s.pager.current_page(table)
            if table.records[p].state == "device":
                s.pager._demote(table, p)
        assert s.pager._pending_demotions  # undrained by construction
        s.pager.prefetch()  # in-flight page fetches at close time
        s.close()
        assert s._engine._worker is None and s._engine._disk_worker is None
        assert s._engine._disk_in_use == 0
        for free in s._engine._staging_free.values():
            assert len(free) <= max(1, s._engine.config.staging_slots)
        assert not os.path.exists(spill_dir)  # ephemeral store removed

    run_with_timeout(body)


@pytest.mark.parametrize("kv_kind", ["pinned_host", "disk_host"])
def test_readmit_after_fault_resumes_bitwise(cfg, mesh, kv_kind, monkeypatch, tmp_path):
    """A fetch fault on the step right after readmission must not corrupt
    the parked pages: the faulted step re-raises, the retry re-fetches from
    the intact cold copies, and the request finishes with exactly the
    tokens of an uninterrupted run."""
    import jax as _jax
    from test_engine_faults import run_with_timeout

    prompt = np.arange(1, 14, dtype=np.int32)
    other = np.arange(2, 11, dtype=np.int32)

    def run(fault: bool):
        real_put = _jax.device_put
        armed = {"on": False, "fired": 0}

        def flaky_put(x, *a, **kw):
            if armed["on"]:
                armed["on"] = False
                armed["fired"] += 1
                raise RuntimeError("injected readmit fetch fault")
            return real_put(x, *a, **kw)

        with sv.ServeSession(
            cfg, mesh, slots=2, max_len=32, kv_kind=kv_kind, page_len=4,
            hot_pages=1, seed=5,
            spill_dir=str(tmp_path / f"{kv_kind}-{fault}") if kv_kind == "disk_host" else None,
        ) as s:
            rid = s.submit(prompt, 10)
            s.submit(other, 12)
            s.admit_pending()
            for _ in range(3):
                s.step()
            s.evict(rid)
            s.step()
            s.readmit(rid)
            if fault:
                # the next step's view() must fetch the readmitted request's
                # cold pages through the engine — fail that H2D once
                monkeypatch.setattr(_jax, "device_put", flaky_put)
                armed["on"] = True
                with pytest.raises(RuntimeError, match="injected readmit"):
                    while s.pending_work():
                        s.step()
                monkeypatch.setattr(_jax, "device_put", real_put)
                assert armed["fired"] == 1
            while s.pending_work():
                s.step()
            assert s._engine._disk_in_use == 0
            return np.asarray(s.requests[rid].emitted, np.int32)

    clean = run_with_timeout(lambda: run(False))
    faulted = run_with_timeout(lambda: run(True))
    np.testing.assert_array_equal(faulted, clean)


# ---------------------------------------------------------------------------
# streamed model parameters (ISSUE 5 tentpole, serve side)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("param_kind", ["pinned_host", "disk_host"])
def test_streamed_params_serve_matches_device_run(cfg, mesh, reference, param_kind):
    """Host/disk-homed weights streamed per prefill/decode step produce
    exactly the device-resident run's tokens, at one coalesced H2D request
    per fetched (device, group), with streamed residency bounded while the
    cache stays paged as before."""
    res = sv.serve(
        cfg, mesh, batch=2, prompt_len=21, gen=8, kv_kind="pinned_host",
        kv_page_len=4, seed=7, param_kind=param_kind, device_budget_mb=2.0,
    )
    assert np.array_equal(res["generated"], reference["generated"])
    ps = res["param_stats"]
    assert ps.n_groups > 0
    # groups that did cross the link cost ONE coalesced request each; the
    # residency cache turns repeat visits into zero-request pass-throughs
    assert ps.per_tier()["h2d"]["requests_per_fetched_device_group"] == 1.0
    assert ps.unique_group_fetches > 0
    assert ps.peak_inflight_bytes > 0
    if param_kind == "disk_host":
        assert ps.disk_requests > 0
    # KV paging unaffected: pages still fetched/demoted through their own
    # accounting
    assert res["stats"].n_groups > 0
    assert res["peak_resident_bytes"] < res["total_cache_bytes"]


def test_streamed_params_on_unpaged_path_bitwise(cfg, mesh):
    """The unpaged schedule carries streamed params too (the route for
    archs whose cache is not pageable): tokens bitwise vs device-resident."""
    kw = dict(batch=2, prompt_len=9, gen=4, kv_page_len=0, warmup=False)
    ref = sv.serve(cfg, mesh, **kw)
    res = sv.serve(cfg, mesh, **kw, param_kind="pinned_host")
    np.testing.assert_array_equal(res["generated"], ref["generated"])
    ps = res["param_stats"]
    assert ps.per_tier()["h2d"]["requests_per_fetched_device_group"] == 1.0

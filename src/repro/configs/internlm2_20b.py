"""InternLM2-20B [arXiv:2403.17297; hf:internlm/internlm2-20b].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544 — GQA, SwiGLU,
RMSNorm, RoPE (theta 1e6).  Large enough that FSDP is on by default.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    pos_type="rope",
    rope_theta=1_000_000.0,
    fsdp=True,
    source="arXiv:2403.17297; hf",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=256, fsdp=False, remat="none",
    )

"""Rotary position embeddings: standard RoPE, Qwen2-VL M-RoPE, sinusoidal.

Conventions: rotate-half layout (x1 = x[..., :H/2], x2 = x[..., H/2:]), f32
trig, applied per head.  M-RoPE (arXiv:2409.12191) splits the head_dim
frequency bands into three sections (temporal, height, width) driven by 3-D
position ids.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions (..., S) int -> angles (..., S, head_dim/2) f32."""
    inv = rope_freqs(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x (B, S, N, H), angles (B, S, H/2) or (S, H/2) -> rotated x."""
    if angles.ndim == 2:  # (S, H/2) -> broadcast batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)  # (B,S,1,H/2)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    h = x.shape[-1] // 2
    x1, x2 = x[..., :h], x[..., h:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mrope_angles(
    positions_3d: jax.Array, head_dim: int, theta: float, sections: Sequence[int]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions_3d: (B, 3, S) int — (temporal, height, width) ids; text tokens
    carry identical ids in all three planes, image patches use their grid
    coordinates.  ``sections`` gives the number of *frequency pairs* per plane
    (sums to head_dim/2; Qwen2-VL: [16, 24, 24] for head_dim 128).
    Returns (B, S, head_dim/2) angles.
    """
    if sum(sections) != head_dim // 2:
        raise ValueError(f"sections {sections} must sum to head_dim/2={head_dim // 2}")
    inv = rope_freqs(head_dim, theta)  # (H/2,)
    # angles per plane: (B, 3, S, H/2)
    ang = positions_3d.astype(jnp.float32)[..., None] * inv
    # select plane per frequency band
    plane = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=head_dim // 2
    )  # (H/2,) in {0,1,2}
    onehot = jax.nn.one_hot(plane, 3, dtype=jnp.float32)  # (H/2, 3)
    return jnp.einsum("bpsh,hp->bsh", ang, onehot)


def sinusoidal_embedding(positions: jax.Array, d_model: int, max_scale: float = 10000.0) -> jax.Array:
    """Classic transformer sinusoidal absolute embedding: (..., S) -> (..., S, D)."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(max_scale) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)

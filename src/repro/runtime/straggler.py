"""Straggler detection + step watchdog.

On a pod, a straggling host shows up as a slowly-creeping step time (its
collectives gate everyone).  The monitor keeps a rolling window of step
durations; a step exceeding ``z_threshold`` robust z-scores (median/MAD) is
flagged, and ``deadline_s`` bounds any single step (hang detection) — the
driver's restart loop treats a tripped deadline as a node failure and
restarts from the last checkpoint.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from collections import deque
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float
    z: float


class StragglerMonitor:
    def __init__(
        self,
        *,
        window: int = 50,
        z_threshold: float = 6.0,
        deadline_s: Optional[float] = None,
        on_event: Optional[Callable[[StragglerEvent], None]] = None,
    ) -> None:
        self.window: deque[float] = deque(maxlen=window)
        self.z_threshold = z_threshold
        self.deadline_s = deadline_s
        #: called with each flagged event — the driver wires this to
        #: ``TransferEngine.widen`` so a straggling step buys the stream
        #: more prefetch headroom instead of just a log line
        self.on_event = on_event
        self.events: list[StragglerEvent] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.perf_counter()

    def check_deadline(self) -> bool:
        """True if the in-flight step blew its deadline (hang)."""
        if self._t0 is None or self.deadline_s is None:
            return False
        return (time.perf_counter() - self._t0) > self.deadline_s

    def end_step(self) -> Optional[StragglerEvent]:
        assert self._t0 is not None, "end_step without start_step"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        ev = None
        if len(self.window) >= 8:
            med = statistics.median(self.window)
            mad = statistics.median(abs(x - med) for x in self.window)
            # floor the MAD at 1% of the median: a window of near-identical
            # step times has MAD ~ 0, and the raw z-score then flags
            # microsecond jitter as a straggler (found by the unit sweep)
            mad = max(mad, 0.01 * med, 1e-9)
            z = 0.6745 * (dt - med) / mad
            if z > self.z_threshold:
                ev = StragglerEvent(self._step, dt, med, z)
                self.events.append(ev)
                if self.on_event is not None:
                    self.on_event(ev)
        self.window.append(dt)
        return ev

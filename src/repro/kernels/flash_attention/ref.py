"""Pure-jnp oracle for blockwise (flash) attention."""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, S, N, H)
    k: jnp.ndarray,  # (B, T, KH, H)
    v: jnp.ndarray,  # (B, T, KH, H)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Full-materialization GQA attention with f32 softmax.

    ``window > 0`` restricts key position ``t`` to ``qpos - window < t``
    (sliding-window / local attention).  ``q_offset`` places query 0 at
    absolute position ``q_offset`` (prefill-continuation / decode).
    """
    b, s, n, h = q.shape
    kh = k.shape[2]
    g = n // kh
    qg = q.reshape(b, s, kh, g, h)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (h ** -0.5)
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, n, h)

"""ModelConfig — the single source of truth a model is built from.

Every assigned architecture is a ``ModelConfig`` instance in its own file in
this package (exact hyperparameters from the assignment table), plus a
``smoke()`` reduced config of the same family for CPU tests and an
``input_specs(shape)`` providing ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax.numpy as jnp


SHAPES = {
    # name: (seq_len, global_batch, step kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # ---- variant knobs -----------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu|geglu|gelu|relu2|none
    norm_type: str = "rmsnorm"  # rmsnorm|layernorm|layernorm_nonparam
    pos_type: str = "rope"  # rope|mrope|sinusoidal|none
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    attn_type: str = "full"  # full | swa (sliding window)
    window: int = 0
    attn_impl: str = "xla"  # xla | chunked (q-block scan) | pallas (flash kernel)
    attn_chunk_q: int = 512  # q-block size for attn_impl="chunked"
    scale_embeddings: bool = False  # gemma-style sqrt(d) embed scale
    logit_softcap: float = 0.0
    # ---- MoE ----------------------------------------------------------------
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048
    moe_impl: str = "dispatch"  # dispatch (GShard einsum) | sorted_ep (shard_map)
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # ---- hybrid (RecurrentGemma / Griffin) -----------------------------------
    block_pattern: tuple[str, ...] = ()  # cycled per layer: "rec" | "attn"
    lru_width: int = 0
    conv_width: int = 4
    # ---- ssm (xLSTM) ----------------------------------------------------------
    slstm_every: int = 0  # one sLSTM block every N (0 = pure mLSTM)
    proj_factor: float = 2.0
    mlstm_chunk: int = 128
    # ---- audio (MusicGen) ------------------------------------------------------
    n_codebooks: int = 0
    # ---- vlm (Qwen2-VL) ---------------------------------------------------------
    vision_embed: bool = False
    # ---- execution ---------------------------------------------------------------
    use_scan: bool = True
    remat: str = "full"  # none | full | dots
    loss_chunk: int = 512  # seq-chunked CE (0 = whole-sequence logits)
    # decode scan carries the stacked cache and updates layer i in place
    # (single aliased buffer) instead of passing caches as scan xs/ys
    # (3 live copies measured) — §Perf knob
    decode_cache_in_carry: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    max_seq: int = 8192
    fsdp: bool = False
    source: str = ""  # provenance note

    # ------------------------------------------------------------------ helpers
    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def gqa_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    def block_kind(self, i: int) -> str:
        """Temporal-mixing kind of layer i."""
        if self.family == "hybrid":
            return self.block_pattern[i % len(self.block_pattern)]
        if self.family == "ssm":
            if self.slstm_every and (i % self.slstm_every == self.slstm_every - 1):
                return "slstm"
            return "mlstm"
        return "attn"

    @property
    def uniform_blocks(self) -> bool:
        return self.family not in ("hybrid", "ssm")

    @property
    def scan_period(self) -> int:
        """Layers per scan step: 1 for uniform stacks; the block-pattern
        period for heterogeneous archs (hybrid/ssm), whose layers repeat
        with this period so a scan over period-groups is exact."""
        if self.family == "hybrid" and self.block_pattern:
            return len(self.block_pattern)
        if self.family == "ssm" and self.slstm_every:
            return self.slstm_every
        return 1

    @property
    def period_scan(self) -> bool:
        """True when the hetero stack is executed as a scan over stacked
        period-groups (plus an unrolled tail of n_layers % period)."""
        p = self.scan_period
        return (
            self.use_scan
            and not self.uniform_blocks
            and p > 1
            and self.n_layers // p >= 2
        )

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode with O(1)/O(window) state (long_500k eligible)?"""
        if self.family in ("hybrid", "ssm"):
            return True
        return self.attn_type == "swa" and self.window > 0

    def cache_len(self, seq_len: int) -> int:
        """KV-cache slots needed to decode with a context of ``seq_len``."""
        if self.family == "ssm":
            return 0  # constant-size recurrent state only
        if self.attn_type == "swa" and self.window:
            return min(self.window, seq_len)
        return seq_len

    # ---------------------------------------------------------- param counting
    def _attn_params(self) -> int:
        d, n, k, h = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        p = d * n * h + 2 * d * k * h + n * h * d
        if self.qkv_bias:
            p += n * h + 2 * k * h
        if self.qk_norm:
            p += 2 * h
        return p

    def _mlp_params(self) -> int:
        if self.mlp_type == "none" or self.d_ff == 0:
            return 0
        gated = self.mlp_type in ("swiglu", "geglu")
        return self.d_model * self.d_ff * (3 if gated else 2)

    def _moe_params_per_layer(self) -> tuple[int, int]:
        """(total, active) routed-FFN params per MoE layer."""
        e, k = self.n_experts, self.moe_top_k
        per_exp = self._mlp_params()
        router = self.d_model * e
        return e * per_exp + router, k * per_exp + router

    def _xlstm_params_per_block(self, kind: str) -> int:
        d = self.d_model
        di = int(self.proj_factor * d)
        nh = self.n_heads
        if kind == "mlstm":
            up = d * 2 * di  # two branches (inner, gate)
            conv = self.conv_width * di
            qkv = 3 * di * (di // nh)  # block-diagonal per head: nh blocks of (di/nh, dh)
            gates = 3 * di  # i, f, o scalar-per-head projections from di
            down = di * d
            return up + conv + qkv + gates + down
        # slstm: 4 gates x (input proj + per-head recurrent) + post-MLP (pf 4/3)
        fi = int(4 * d / 3)
        return 4 * (d * d + d * (d // nh)) + d * fi * 2

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts (embeddings included once)."""
        d, v = self.d_model, self.vocab_size
        embed = v * d
        if self.n_codebooks:
            embed = self.n_codebooks * v * d
        head = 0 if self.tie_embeddings else d * v * (self.n_codebooks or 1)
        total = embed + head
        active = embed + head
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind == "attn":
                t = self._attn_params()
                if self.n_experts:
                    moe_t, moe_a = self._moe_params_per_layer()
                    total += t + moe_t
                    active += t + moe_a
                else:
                    m = self._mlp_params()
                    total += t + m
                    active += t + m
            elif kind == "rec":
                w = self.lru_width
                t = 2 * d * w + self.conv_width * w + 2 * w + w + w * d + self._mlp_params()
                total += t
                active += t
            elif kind in ("mlstm", "slstm"):
                t = self._xlstm_params_per_block(kind)
                total += t
                active += t
        return total, active

"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jnp.ndarray,  # (B, N, H) — one new token per sequence
    k: jnp.ndarray,  # (B, T, KH, H) — cache
    v: jnp.ndarray,  # (B, T, KH, H)
    length: jnp.ndarray,  # (B,) int32 — valid cache prefix per sequence
) -> jnp.ndarray:
    """GQA decode attention over the valid prefix ``[0, length)`` of the cache."""
    b, n, h = q.shape
    kh = k.shape[2]
    g = n // kh
    qg = q.reshape(b, kh, g, h)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, k).astype(jnp.float32)
    scores = scores * (h ** -0.5)
    valid = jnp.arange(k.shape[1])[None] < length[:, None]  # (B, T)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, v)
    return out.reshape(b, n, h)

"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic restore.

Design (what a 1000-node deployment needs):
  * **atomicity** — each checkpoint is written to ``step_XXXX.tmp`` and
    renamed only after every leaf + metadata has been fsync'd; a crash
    mid-write can never corrupt the latest checkpoint.
  * **async** — ``save()`` snapshots to host memory (device_get) and hands
    the serialization to a background thread; the train loop blocks only for
    the D2H copy (and ``wait()`` joins before the next save).
  * **keep-k** — old checkpoints are pruned after a successful commit.
  * **elastic restore** — leaves are saved UNSHARDED (gathered to host) with
    their logical tree paths; ``restore(..., shardings=...)`` re-places them
    under *any* mesh, so a job can resume on a different data-axis size
    (node loss) or a different pod count.  This is the paper's
    pass-by-reference story applied to job state: the checkpoint is the
    home location, devices hold views.

Format: one ``.npy`` per leaf (path-encoded filename) + ``meta.json``
(step, tree structure, dtypes/shapes) — no external deps, streams leaf by
leaf so peak host memory is one leaf.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "__"

# 8+ digits: f"{step:08d}" pads but never truncates, so steps >= 10^8
# produce wider names that must stay visible to restore/prune
_STEP_RE = re.compile(r"step_(\d{8,})$")


def _fsync_dir(path: Path) -> None:
    """fsync a directory so a rename committed into it survives power loss
    (best-effort: some filesystems refuse O_RDONLY dir fsync)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic fs
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - exotic fs
        pass
    finally:
        os.close(fd)


def _flatten(tree: Pytree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            key = getattr(k, "key", getattr(k, "name", getattr(k, "idx", None)))
            parts.append(str(key))
        out.append((_SEP.join(parts), leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, *, keep: int = 3) -> None:
        """``keep``: checkpoints retained after each commit.  ``keep=0``
        explicitly means *keep all* (no pruning); negative values are
        rejected rather than silently keeping everything.

        Construction sweeps crash leftovers (partial ``.tmp`` dirs are
        deleted, an orphaned ``.old`` is recovered as its step) so a
        restart restores the right step BEFORE its first save.  The
        manager therefore assumes a single writer per directory — the
        driver's model; constructing a second manager against a directory
        another process is actively checkpointing into may sweep that
        writer's in-progress ``.tmp``."""
        if keep < 0:
            raise ValueError(f"keep must be >= 0 (0 = keep all), got {keep}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._sweep_stale()

    def _sweep_stale(self) -> None:
        """Remove leftovers of a crashed save: ``.tmp`` dirs are always
        partial (pre-commit) and are deleted; a ``.old`` dir is the
        previous copy of a step that was mid-overwrite — restore it when
        the crash hit before the commit rename, drop it otherwise."""
        for p in self.dir.glob("step_*.tmp"):
            shutil.rmtree(p, ignore_errors=True)
        for p in self.dir.glob("step_*.old"):
            final = self.dir / p.name[: -len(".old")]
            if final.exists():
                shutil.rmtree(p, ignore_errors=True)
            else:
                p.rename(final)

    # ------------------------------------------------------------------ save
    def save(
        self,
        step: int,
        tree: Pytree,
        *,
        blocking: bool = False,
        extra_meta: Optional[dict] = None,
    ) -> None:
        """Snapshot ``tree`` at ``step``.  D2H happens here (synchronous);
        file I/O happens on a background thread unless ``blocking``.

        ``extra_meta`` is recorded verbatim under ``meta.json``'s ``extra``
        key (the driver stores its run identity there — mesh fingerprint,
        weight grouping — which the elastic resharder reads on resume).

        Device leaves are copied to host now (they may be donated into the
        next step).  Host- and disk-homed leaves (numpy / spill-store
        memmaps — the weight-streamed trainer's home representation) are
        snapshotted **by reference** and serialized leaf-by-leaf on the
        writer thread, so saving a host/disk-homed state never materializes
        the full tree in host RAM (or on device) at once.  This assumes
        homes are *replaced*, not mutated in place, between steps — true
        for every streamed trainer (drained writebacks are fresh arrays,
        and spill-store overwrites are atomic tmp+rename, which keeps an
        old mapping valid)."""
        self.wait()

        def _host_leaf(x):
            if isinstance(x, jax.Array):
                return np.asarray(jax.device_get(x))
            # numpy/memmap home leaves: keep the reference (no copy);
            # anything else (python scalars) still snapshots eagerly
            return x if isinstance(x, np.ndarray) else np.asarray(x)

        host = [(name, _host_leaf(x)) for name, x in _flatten(tree)]
        treedef = jax.tree.structure(tree)
        meta = {
            "step": int(step),
            "treedef": str(treedef),
            "leaves": [
                {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                for n, a in host
            ],
            "time": time.time(),
        }
        if extra_meta:
            meta["extra"] = dict(extra_meta)

        def write() -> None:
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                for name, arr in host:
                    self._write_leaf(tmp, name, arr)
                with open(tmp / "meta.json", "w") as f:
                    json.dump(meta, f)
                    f.flush()
                    os.fsync(f.fileno())
                self._commit(step, tmp)
            except BaseException as e:  # noqa: BLE001 — surfaced via wait()
                self._error = e

        if blocking:
            write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    @staticmethod
    def _write_leaf(tmp: Path, name: str, arr: np.ndarray) -> None:
        with open(tmp / f"{name}.npy", "wb") as f:
            np.save(f, np.asarray(arr))
            f.flush()
            os.fsync(f.fileno())

    def _commit(self, step: int, tmp: Path) -> None:
        """Atomically promote a fully-written ``.tmp`` dir to the step dir.

        Overwrite without a crash window: the previous copy moves aside and
        is deleted only AFTER the rename commits — a crash between the two
        never loses the only copy of a step."""
        final = self.dir / f"step_{step:08d}"
        old = None
        if final.exists():
            old = self.dir / f"step_{step:08d}.old"
            if old.exists():
                shutil.rmtree(old)
            final.rename(old)
        try:
            tmp.rename(final)  # the atomic commit
        except BaseException:
            if old is not None and not final.exists():
                old.rename(final)  # roll back: old copy stays latest
            raise
        _fsync_dir(self.dir)  # the rename itself must be durable
        if old is not None:
            shutil.rmtree(old, ignore_errors=True)
        self._prune()

    def save_streamed(
        self,
        step: int,
        leaves,
        *,
        extra_meta: Optional[dict] = None,
        treedef: str = "streamed",
    ) -> None:
        """Write a checkpoint from an *iterator* of ``(name, array)`` pairs,
        holding one leaf in memory at a time — the elastic resharder's write
        path: the new grouping is produced group-wise from memmapped old
        leaves and the full tree must never co-reside.

        Synchronous; commits with the same atomic tmp → rename (+ ``.old``
        crash window) as :meth:`save`.  ``restore`` imposes structure from
        its *template* (the stored treedef string is informational), so
        ``treedef`` may be a placeholder."""
        self.wait()
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaf_meta = []
        for name, arr in leaves:
            arr = np.asarray(arr)
            self._write_leaf(tmp, name, arr)
            leaf_meta.append(
                {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        meta = {
            "step": int(step),
            "treedef": treedef,
            "leaves": leaf_meta,
            "time": time.time(),
        }
        if extra_meta:
            meta["extra"] = dict(extra_meta)
        with open(tmp / "meta.json", "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        self._commit(step, tmp)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _prune(self) -> None:
        steps = self.all_steps()
        # keep=0 means keep all (see __init__)
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        return sorted(
            int(m.group(1))
            for p in self.dir.glob("step_*")
            if p.is_dir() and (m := _STEP_RE.fullmatch(p.name))
        )

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_meta(self, step: Optional[int] = None) -> dict:
        """The stored ``meta.json`` of ``step`` (default: latest) — leaf
        names/shapes/dtypes plus any ``extra`` the writer recorded."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return json.loads(
            (self.dir / f"step_{step:08d}" / "meta.json").read_text()
        )

    def load_leaf(
        self,
        step: int,
        name: str,
        *,
        dtype: Optional[str] = None,
        mmap: bool = False,
    ) -> np.ndarray:
        """One stored leaf by name.  ``mmap=True`` maps it read-only — the
        resharder's bounded-memory read path.  ``dtype`` (from
        :meth:`load_meta`) re-views extension dtypes the way
        :meth:`restore` does."""
        arr = np.load(
            self.dir / f"step_{step:08d}" / f"{name}.npy",
            mmap_mode="r" if mmap else None,
        )
        if arr.dtype.kind == "V" and dtype is not None:
            import jax.numpy as jnp

            arr = arr.view(jnp.dtype(dtype))
        return arr

    def restore(
        self,
        template: Pytree,
        *,
        step: Optional[int] = None,
        shardings: Optional[Pytree] = None,
    ) -> tuple[int, Pytree]:
        """Load into the structure of ``template``; re-shard onto
        ``shardings`` (elastic resume) or leave as host numpy."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "meta.json").read_text())
        dtypes = {leaf["name"]: leaf["dtype"] for leaf in meta["leaves"]}
        names = [n for n, _ in _flatten(template)]
        leaves = []
        for name in names:
            arr = np.load(d / f"{name}.npy")
            if arr.dtype.kind == "V":
                # extension dtypes (bfloat16, fp8) serialize as raw void in
                # npy; re-view them through the dtype recorded in meta.json
                import jax.numpy as jnp

                arr = arr.view(jnp.dtype(dtypes[name]))
            leaves.append(arr)
        tree = jax.tree.unflatten(jax.tree.structure(template), leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                tree,
                shardings,
                is_leaf=lambda x: x is None,
            )
        return step, tree

"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152 — llama-arch small.
15 heads do not divide the 16-way model axis: attention runs data-parallel
with MLP/vocab tensor-parallel (see parallel/sharding.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    pos_type="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
        d_ff=96, vocab_size=256, remat="none",
    )

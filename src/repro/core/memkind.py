"""Memory kinds for hierarchical memory placement (paper §3.2, TPU-native).

The paper introduces ``Host`` / ``Shared`` / ``Microcore`` *kind* objects that
declare where in the memory hierarchy a tensor lives; kernels receive
references regardless of kind, and the kind encapsulates transfer mechanics.
Crucially (§3.2), adding a level is *just a new ``Kind`` subclass* — the level
does not have to be addressable by the accelerator, or even by XLA.

The hierarchy modelled here is three levels deep (see
``docs/memory_hierarchy.md``):

  level 0  ``Device``       HBM / device memory — XLA-addressable.
  level 2  ``PinnedHost``   host DRAM, DMA-reachable, not compute-addressable.
  level 3  ``UnpinnedHost`` pageable host DRAM (staging tier).
  level 4  ``DiskHost``     disk/NVMe spill store — *not* a JAX memory at
                            all; data reaches the device via a two-stage
                            disk -> host-staging -> device pipeline run by
                            :class:`repro.core.engine.TransferEngine`, with
                            :class:`repro.core.spillstore.SpillStore` as the
                            home representation (memory-mapped chunk files).

JAX exposes the host tiers as sharding *memory kinds* (``pinned_host`` /
``device``); the VMEM level is managed inside Pallas kernels (see
``repro.kernels``).  Kinds that XLA cannot address (``DiskHost``) resolve to
their *staging kind* for program placement — the compiled program only ever
sees the staging tier, while the runtime streams the data up the extra level.
This module provides:

  * ``MemKind`` subclasses mirroring (and extending) the paper's kinds,
  * ``PlacementPolicy`` — per-state-group kind assignment (params / optimizer
    moments / KV cache / activations), the "one-line change moves your data"
    property of the paper — including ``DISK_OPT`` / ``DISK_PARAMS`` presets
    for the disk tier,
  * a backend capability probe with graceful fallback: backends whose runtime
    cannot execute host-placed buffers (the CPU runtime in this container)
    transparently map host kinds onto device memory while keeping the program
    topology (slice + copy + double-buffer) identical.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "MemKind",
    "Device",
    "PinnedHost",
    "UnpinnedHost",
    "DiskHost",
    "PlacementPolicy",
    "ALL_DEVICE",
    "HOST_OPT",
    "HOST_PARAMS",
    "HOST_ALL",
    "DISK_OPT",
    "DISK_PARAMS",
    "all_kinds",
    "backend_memory_kinds",
    "backend_kind_string",
    "default_memory_kind",
    "host_offload_supported",
    "resolve_kind",
    "sharding_for",
    "place",
]


class MemKind:
    """A level of the memory hierarchy.  Subclass to add a level (paper §3.2:
    'To create a kind representing a new level in the memory hierarchy
    requires a new Python class, inheriting from the Kind class')."""

    #: the JAX memory-kind string this level maps to (a logical name for
    #: levels XLA cannot address, see ``jax_addressable``)
    jax_kind: str = "device"
    #: ordering in the hierarchy; higher = further from the compute units
    level: int = 0
    #: can the accelerator's compute units load/store this level directly?
    directly_addressable: bool = True
    #: can XLA place a buffer at this level at all?  ``False`` means the
    #: level exists only to the runtime (disk): program placement uses
    #: ``staging_jax_kind`` and the transfer engine bridges the gap.
    jax_addressable: bool = True
    #: the jax memory kind data from this level is staged through on its way
    #: to the device (only meaningful when ``jax_addressable`` is False)
    staging_jax_kind: str = "pinned_host"

    def __repr__(self) -> str:  # pragma: no cover - trivial
        return f"{type(self).__name__}(jax_kind={self.jax_kind!r}, level={self.level})"

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, MemKind) and self.jax_kind == other.jax_kind

    def __hash__(self) -> int:
        return hash(self.jax_kind)


class Device(MemKind):
    """HBM — the paper's ``Microcore``/``Shared`` analogue (fast, bounded)."""

    jax_kind = "device"
    level = 0
    directly_addressable = True


class PinnedHost(MemKind):
    """Host DRAM, DMA-reachable but not addressable by compute — the paper's
    ``Host`` kind ('allocates the data in the large host memory, not
    accessible directly by the micro-cores')."""

    jax_kind = "pinned_host"
    level = 2
    directly_addressable = False


class UnpinnedHost(MemKind):
    """Pageable host DRAM (slowest RAM tier; staging only)."""

    jax_kind = "unpinned_host"
    level = 3
    directly_addressable = False


class DiskHost(MemKind):
    """Disk/NVMe spill tier — a hierarchy level the accelerator (and XLA)
    cannot address at all, demonstrating the paper's §3.2 claim that a new
    level is just a new ``Kind`` subclass.

    Home representation: memory-mapped chunk files in a
    :class:`repro.core.spillstore.SpillStore`.  The transfer engine streams
    chunks disk -> host staging -> device in a two-stage pipeline, hiding
    disk latency behind host->device latency exactly as host latency is
    hidden behind compute (``PrefetchSpec(distance="auto")`` per stage).
    """

    jax_kind = "disk_host"
    level = 4
    directly_addressable = False
    jax_addressable = False
    staging_jax_kind = "pinned_host"


DEVICE = Device()
PINNED_HOST = PinnedHost()
UNPINNED_HOST = UnpinnedHost()
DISK_HOST = DiskHost()

_KIND_BY_NAME = {
    "device": DEVICE,
    "pinned_host": PINNED_HOST,
    "unpinned_host": UNPINNED_HOST,
    "disk_host": DISK_HOST,
}


def all_kinds() -> tuple[MemKind, ...]:
    """Every registered hierarchy level, nearest-to-compute first (the
    cross-kind conformance matrix iterates this)."""
    return tuple(sorted(_KIND_BY_NAME.values(), key=lambda k: k.level))


def as_kind(kind: "MemKind | str | None") -> MemKind:
    if isinstance(kind, MemKind):
        return kind
    if kind is None:  # backend-default placement reads back as no kind
        return DEVICE
    try:
        return _KIND_BY_NAME[kind]
    except KeyError:
        raise ValueError(
            f"unknown memory kind {kind!r}; expected one of {sorted(_KIND_BY_NAME)}"
        ) from None


@functools.cache
def backend_memory_kinds() -> tuple[str, ...]:
    """Memory kinds the current backend *enumerates*."""
    dev = jax.devices()[0]
    try:
        return tuple(m.kind for m in dev.addressable_memories())
    except Exception:  # pragma: no cover - very old backends
        return ("device",)


@functools.cache
def default_memory_kind() -> Optional[str]:
    """The backend's default memory kind string (None if unqueryable)."""
    try:
        return jax.devices()[0].default_memory().kind
    except Exception:  # pragma: no cover
        return None


_warned_kinds: set = set()


def backend_kind_string(jax_kind: str) -> Optional[str]:
    """Map a logical jax memory-kind string onto one this backend accepts.

    Backends differ in what they enumerate (TPU: ``device`` + ``pinned_host``;
    some CPU builds: only ``unpinned_host``).  A kind the backend does not
    enumerate maps to ``None`` — the backend default memory — which is the
    physically correct tier on a single-memory backend (it *is* its own host
    and device tier).  Mapping a *host* kind to the default on a multi-tier
    backend loses the placement, so that case warns once per kind.
    """
    if jax_kind in backend_memory_kinds():
        return jax_kind
    if jax_kind != "device" and jax_kind not in _warned_kinds:
        _warned_kinds.add(jax_kind)
        import warnings

        warnings.warn(
            f"memory kind {jax_kind!r} is not enumerated by this backend "
            f"({backend_memory_kinds()}); placing at the backend default "
            "memory instead",
            stacklevel=3,
        )
    return None


@functools.cache
def host_offload_supported() -> bool:
    """True iff the backend can *compile and execute* host-placed buffers.

    The CPU runtime enumerates ``pinned_host`` but lacks the
    ``annotate_device_placement`` custom-call implementation, so we probe by
    compiling a tiny host->device copy.
    """
    if "pinned_host" not in backend_memory_kinds():
        return False
    try:
        import jax.numpy as jnp

        dev = jax.devices()[0]
        host_s = jax.sharding.SingleDeviceSharding(dev, memory_kind="pinned_host")
        dev_s = jax.sharding.SingleDeviceSharding(dev, memory_kind="device")

        def f(x):
            return jax.device_put(x, dev_s) * 2.0

        jax.jit(f, in_shardings=(host_s,), out_shardings=dev_s).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32)
        ).compile()
        return True
    except Exception:
        return False


def resolve_kind(kind: "MemKind | str", *, allow_fallback: bool = True) -> MemKind:
    """Map a requested kind to one the backend can execute.

    Kinds XLA cannot address (``DiskHost``) first resolve to their *staging*
    kind — the compiled program only ever sees the staging tier; the extra
    level is the runtime's business (spill store + transfer engine).  On
    backends without host-offload execution support, host kinds then fall
    back to ``Device`` (identical program topology, both tiers physically in
    the same memory).  Lowering-only paths (the dry-run) may pass
    ``allow_fallback=False`` to keep the true placement in the StableHLO.
    """
    kind = as_kind(kind)
    if not kind.jax_addressable:
        kind = as_kind(kind.staging_jax_kind)
    if kind.jax_kind == "device":
        return kind
    if not allow_fallback or host_offload_supported():
        return kind
    return DEVICE


def sharding_for(
    mesh: Mesh,
    spec: PartitionSpec,
    kind: "MemKind | str" = DEVICE,
    *,
    allow_fallback: bool = True,
) -> NamedSharding:
    """NamedSharding at a given hierarchy level.

    ``allow_fallback=False`` (lowering-only paths, e.g. the dry-run) keeps
    the requested kind string verbatim so the true placement reaches the
    StableHLO — and fails loudly if the backend cannot express it.
    """
    kind = resolve_kind(kind, allow_fallback=allow_fallback)
    mk_str = backend_kind_string(kind.jax_kind) if allow_fallback else kind.jax_kind
    return NamedSharding(mesh, spec, memory_kind=mk_str)


def place(tree: Any, mesh: Mesh, specs: Any, kind: "MemKind | str" = DEVICE) -> Any:
    """``device_put`` a pytree at a hierarchy level.  ``specs`` is a matching
    pytree of PartitionSpec (or a single spec broadcast over leaves)."""
    kind = resolve_kind(kind)
    if isinstance(specs, PartitionSpec):
        specs = jax.tree.map(lambda _: specs, tree)
    shardings = jax.tree.map(
        lambda s: sharding_for(mesh, s, kind),
        specs,
        is_leaf=lambda s: isinstance(s, PartitionSpec),
    )
    return jax.device_put(tree, shardings)


# ---------------------------------------------------------------------------
# Placement policies — the paper's "swap the kind, everything else unchanged"
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlacementPolicy:
    """Where each state group lives in the hierarchy.

    Mirrors the paper's memory-kind declarations at the granularity that
    matters for a training/serving framework.  ``params_stream`` /
    ``opt_stream`` toggle per-layer streaming (pass-by-reference + prefetch)
    for host-resident groups; non-streamed host groups are bulk-copied at the
    step boundary (the paper's "eager" mode).
    """

    name: str = "all_device"
    params: MemKind = DEVICE
    opt_state: MemKind = DEVICE
    kv_cache: MemKind = DEVICE
    #: prefetch distance (layers ahead) when params are host-resident
    params_prefetch_distance: int = 1
    #: layers fetched per transfer ("elements per pre-fetch" of paper §3.1)
    params_layers_per_fetch: int = 1

    def with_(self, **kw: Any) -> "PlacementPolicy":
        return dataclasses.replace(self, **kw)

    def requires_host(self) -> bool:
        return any(
            k.jax_kind != "device" for k in (self.params, self.opt_state, self.kv_cache)
        )

    def requires_spill(self) -> bool:
        """True if any state group lives at a non-XLA level (disk)."""
        return any(
            not k.jax_addressable
            for k in (self.params, self.opt_state, self.kv_cache)
        )


ALL_DEVICE = PlacementPolicy(name="all_device")
#: Adam moments + f32 master on host — the biggest win for large dense models
HOST_OPT = PlacementPolicy(name="host_opt", opt_state=PINNED_HOST)
#: weights live on host, streamed per layer with prefetch (paper's flagship mode)
HOST_PARAMS = PlacementPolicy(name="host_params", params=PINNED_HOST)
HOST_ALL = PlacementPolicy(
    name="host_all", params=PINNED_HOST, opt_state=PINNED_HOST, kv_cache=PINNED_HOST
)
#: Adam moments + f32 master spill to disk (larger-than-host-RAM training)
DISK_OPT = PlacementPolicy(name="disk_opt", opt_state=DISK_HOST)
#: weights live on disk, streamed disk->host->device (larger-than-RAM models)
DISK_PARAMS = PlacementPolicy(name="disk_params", params=DISK_HOST)

POLICIES = {
    p.name: p
    for p in (ALL_DEVICE, HOST_OPT, HOST_PARAMS, HOST_ALL, DISK_OPT, DISK_PARAMS)
}


def get_policy(name: str) -> PlacementPolicy:
    try:
        return POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown placement policy {name!r}; have {sorted(POLICIES)}") from None

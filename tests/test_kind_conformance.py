"""Cross-kind conformance matrix: the uniform-semantics guarantee.

The paper's abstraction promises that *where* data lives never changes
*what* a kernel computes — a kind swap is a one-line change (§3.2), valid
for levels the accelerator cannot address directly (host) or at all
(disk).  This suite runs one streamed workload over every registered
``MemKind`` x access mode (``ro``/``rw``) x prefetch distance
(``0``/``1``/``"auto"``) and asserts:

  * bitwise equality with the eager (bulk-copy) path at the same kind and
    with the all-device reference,
  * correct per-tier ``StreamStats`` request accounting (device leaves are
    never re-sent; host groups coalesce to 1 H2D request; disk groups add
    exactly 1 disk request each).

Also here: the ``DiskHost`` acceptance tests (data + optimizer state
larger than the host budget, sourced from disk, same values) and the
``stream_host`` executor-cache regression (cache must key on policy/kinds,
not just the streamed-arg set).
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import memkind as mk
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.offload import offload
from repro.core.refspec import AUTO, OffloadRef, PrefetchSpec
from repro.core.spillstore import SpillStore, is_disk_leaf

N_GROUPS = 5


def _host_groups(rng):
    return [
        {
            "w": rng.standard_normal((4, 4)).astype(np.float32),
            "b": np.asarray(
                jnp.asarray(rng.standard_normal((4,)), jnp.bfloat16)
            ),
        }
        for _ in range(N_GROUPS)
    ]


def _groups_at_kind(kind: mk.MemKind, groups_host, tmp_path):
    """The home representation of the groups at a hierarchy level."""
    if kind.jax_kind == "device":
        return [jax.tree.map(jnp.asarray, g) for g in groups_host]
    if not kind.jax_addressable:
        store = SpillStore(tmp_path / "spill")
        out = []
        for i, g in enumerate(groups_host):
            store.put(f"g{i}", g)
            out.append(store.get(f"g{i}"))
        return out
    # pinned/unpinned host: host-resident numpy is the home representation
    # the stream engine serves (the backend fallback story is memkind's)
    return groups_host


@jax.jit
def _apply_ro(carry, g):
    return carry + jnp.sum(g["w"]) * 2.0 + jnp.sum(g["b"].astype(jnp.float32))


@jax.jit
def _apply_rw(carry, g):
    out = {"w": g["w"] * 2.0 + 1.0, "b": g["b"]}
    return carry + jnp.sum(g["w"]), out


@pytest.mark.parametrize("distance", [0, 1, AUTO], ids=["d0", "d1", "auto"])
@pytest.mark.parametrize("access", ["ro", "rw"])
@pytest.mark.parametrize(
    "kind", mk.all_kinds(), ids=[type(k).__name__ for k in mk.all_kinds()]
)
def test_kind_conformance_matrix(kind, access, distance, tmp_path):
    rng = np.random.default_rng(7)
    groups_host = _host_groups(rng)
    groups = _groups_at_kind(kind, groups_host, tmp_path)
    writeback = access == "rw"
    apply = _apply_rw if writeback else _apply_ro

    # the all-device reference: everything already at the fast tier
    dev_groups = [jax.tree.map(jnp.asarray, g) for g in groups_host]
    with HostStreamExecutor(apply, writeback=writeback) as ex:
        ref, ref_outs = ex.run(jnp.zeros(()), dev_groups, mode="eager")

    mode = "on_demand" if distance == 0 else "prefetch"
    prefetch = (
        None
        if distance == 0
        else PrefetchSpec(buffer_size=N_GROUPS + 2, distance=distance)
    )
    st = StreamStats()
    with HostStreamExecutor(apply, writeback=writeback) as ex:
        eager, eager_outs = ex.run(jnp.zeros(()), groups, mode="eager")
        out, outs = ex.run(
            jnp.zeros(()), groups, mode=mode, prefetch=prefetch, stats=st
        )

    # uniform semantics: same value at every kind, every schedule — bitwise
    assert float(out) == float(eager) == float(ref)
    if writeback:
        for o, eo, ro in zip(outs, eager_outs, ref_outs):
            for a, b, c in zip(
                jax.tree.leaves(o), jax.tree.leaves(eo), jax.tree.leaves(ro)
            ):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
                np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    # request accounting per tier
    assert st.n_groups == N_GROUPS
    if kind.jax_kind == "device":
        assert st.h2d_requests == 0  # pass-by-reference: nothing re-sent
        assert st.disk_requests == 0
    else:
        assert st.h2d_requests == N_GROUPS  # coalesced: 1 request per group
        if kind.jax_addressable:
            assert st.disk_requests == 0
        else:
            assert st.disk_requests == N_GROUPS  # 1 chunk file per group
            assert st.bytes_disk > 0
    if writeback:
        assert st.d2h_requests > 0


# ---------------------------------------------------------------------------
# DiskHost acceptance: data + optimizer state larger than the host budget
# ---------------------------------------------------------------------------


def test_streamed_kernel_from_disk_exceeds_host_budget(tmp_path):
    """An offloaded streamed kernel whose streamed data is sourced from the
    DiskHost tier, with total bytes far above the host-staging footprint
    (the engine holds at most O(window) chunks in RAM), bitwise-equal to
    the host-kind streamed run and to eager."""
    spec = PrefetchSpec(buffer_size=4, elements_per_fetch=4, distance=AUTO)

    @offload(refs=dict(
        a=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec),
        b=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec),
    ))
    def k(a, b):
        return a * 2.0 + b

    rng = np.random.default_rng(8)
    a = rng.standard_normal((64, 32)).astype(np.float32)
    b = rng.standard_normal((64, 32)).astype(np.float32)
    st_host, st_disk = StreamStats(), StreamStats()
    try:
        out_host = k.stream_host(a, b, stats=st_host)
        out_disk = k.stream_host(
            a, b, stats=st_disk, policy=mk.DISK_PARAMS,
            spill_dir=tmp_path / "spill",
        )
        eager = np.asarray(k.eager(a, b))
    finally:
        k.close()
    np.testing.assert_array_equal(out_disk, out_host)  # tier swap: bitwise
    np.testing.assert_allclose(out_disk, eager, rtol=1e-6)
    # every block came off disk, one chunk request each, still 1 H2D/group
    n_blocks = 64 // 4
    assert st_disk.disk_requests == n_blocks
    assert st_disk.requests_per_group == 1.0
    assert st_host.disk_requests == 0
    # the host-staging footprint is bounded by the engine pools, not the
    # data size: the store holds the full data set, RAM only a window
    total_bytes = a.nbytes + b.nbytes
    assert st_disk.bytes_disk == total_bytes


def test_streamed_adamw_spilled_beyond_budget_matches_host(tmp_path):
    """Streamed AdamW with moments spilled to disk under a host-RAM budget
    smaller than the state: bitwise-identical params and state trajectory
    to the all-host streamed run, disk groups stay disk-homed."""
    from repro.optim.adamw import AdamWConfig, opt_state_bytes
    from repro.train.steps import (
        host_opt_state,
        make_streamed_opt_updater,
        spill_opt_state,
    )

    key = jax.random.PRNGKey(0)
    params = {
        "a": jax.random.normal(key, (32, 8)),
        "b": {"w": jax.random.normal(key, (16,)),
              "u": jax.random.normal(key, (8, 8))},
    }
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=2, total_steps=20)
    pf = PrefetchSpec(buffer_size=6, distance=AUTO)
    store = SpillStore(tmp_path / "opt")

    total = opt_state_bytes(params)
    budget = total // 3  # forces most of the state below the budget to disk
    opt_host = host_opt_state(params)
    opt_disk = spill_opt_state(
        host_opt_state(params), store, n_groups=3, host_budget_bytes=budget
    )
    disk_leaves = [x for x in jax.tree.leaves(opt_disk["leaves"]) if is_disk_leaf(x)]
    ram_bytes = sum(
        x.nbytes for x in jax.tree.leaves(opt_disk["leaves"]) if not is_disk_leaf(x)
    )
    assert disk_leaves, "budget should force some groups to disk"
    assert ram_bytes <= budget

    upd_h = make_streamed_opt_updater(
        cfg, compute_dtype=jnp.float32, n_groups=3, prefetch=pf
    )
    upd_d = make_streamed_opt_updater(
        cfg, compute_dtype=jnp.float32, n_groups=3, prefetch=pf, spill_store=store
    )
    st = StreamStats()
    p_h, p_d = params, params
    try:
        for i in range(4):
            g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1 * (i + 1), params)
            p_h, opt_host, _ = upd_h(g, opt_host)
            p_d, opt_disk, _ = upd_d(g, opt_disk, stats=st)
    finally:
        upd_h.close()
        upd_d.close()
    for a, b in zip(jax.tree.leaves(p_h), jax.tree.leaves(p_d)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt_host["leaves"]), jax.tree.leaves(opt_disk["leaves"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # updated moments went back to their disk home, not host RAM
    assert any(is_disk_leaf(x) for x in jax.tree.leaves(opt_disk["leaves"]))
    assert st.disk_requests > 0 and st.requests_per_group == 1.0


@pytest.mark.slow
def test_disk_opt_trainer_end_to_end_and_restore_respills(tmp_path):
    """launch.train wiring: a DISK_OPT streamed-optimizer trainer runs,
    spills moments to the spill dir, produces finite losses — and a
    checkpoint-restored continuation re-imposes the disk budget (restored
    state is plain host numpy; it must not silently stay in RAM)."""
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.train import build_trainer
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.driver import DriverConfig

    cfg = get_smoke_config("smollm-360m")
    mesh = make_local_mesh()

    def make_driver(total_steps):
        return build_trainer(
            cfg,
            mesh,
            global_batch=2,
            seq_len=16,
            opt_cfg=AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=4),
            driver_cfg=DriverConfig(
                total_steps=total_steps,
                checkpoint_every=2,
                checkpoint_dir=str(tmp_path / "ckpt"),
                log_every=0,
            ),
            policy=mk.DISK_OPT,
            stream_opt=True,
            spill_dir=str(tmp_path / "spill"),
            host_budget_mb=0.0,  # spill everything
        )

    driver = make_driver(2)
    driver.run()
    losses = [h["loss"] for h in driver.history]
    assert len(losses) == 2 and all(np.isfinite(losses))
    assert driver.stream_stats.disk_requests > 0
    assert driver.spill_store is not None and driver.spill_store.total_bytes() > 0

    # resume from the checkpoint: restored moments are plain numpy, the
    # budget must be re-imposed so the disk tier keeps serving them
    driver2 = make_driver(4)
    driver2.run()
    assert [h["step"] for h in driver2.history] == [2, 3]
    assert driver2.stream_stats.disk_requests > 0


# ---------------------------------------------------------------------------
# sharded axis: the same matrix on a forced 2-device host mesh
# ---------------------------------------------------------------------------

_SHARDED_MATRIX_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import memkind as mk
from repro.core.engine import TransferEngine
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import AUTO, PrefetchSpec
from repro.core.spillstore import SpillStore
from repro.jaxcompat import make_mesh

N_GROUPS = 4
assert len(jax.devices()) == 2, jax.devices()
mesh = make_mesh((1, 2), ("data", "model"))
# one model-sharded leaf, one replicated bf16 leaf per group
shardings = {"w": NamedSharding(mesh, P(None, "model")),
             "b": NamedSharding(mesh, P())}
rng = np.random.default_rng(7)
groups_host = [
    {"w": rng.standard_normal((4, 4)).astype(np.float32),
     "b": np.asarray(jnp.asarray(rng.standard_normal((4,)), jnp.bfloat16))}
    for _ in range(N_GROUPS)
]

def groups_at(kind, tmp):
    if kind.jax_kind == "device":
        return [jax.device_put(g, shardings) for g in groups_host]
    if not kind.jax_addressable:
        store = SpillStore(tmp)
        out = []
        for i, g in enumerate(groups_host):
            store.put(f"g{i}", g)
            out.append(store.get(f"g{i}"))
        return out
    return groups_host

@jax.jit
def apply_ro(carry, g):
    return carry + jnp.sum(g["w"]) * 2.0 + jnp.sum(g["b"].astype(jnp.float32))

@jax.jit
def apply_rw(carry, g):
    return carry + jnp.sum(g["w"]), {"w": g["w"] * 2.0 + 1.0, "b": g["b"]}

# engine level: staged leaves carry the exact sharding AND bytes of eager
# sharded placement
eng = TransferEngine()
fut = eng.submit_group(0, groups_host[0], device_shardings=shardings)
fut.wait()
staged = fut.group()
eager0 = jax.device_put(groups_host[0], shardings)
for k in ("w", "b"):
    assert staged[k].sharding == eager0[k].sharding, (k, staged[k].sharding)
    np.testing.assert_array_equal(np.asarray(staged[k]), np.asarray(eager0[k]))
assert fut.n_requests == 2 and fut.n_devices == 2, (fut.n_requests, fut.n_devices)
eng.close()

eager_groups = [jax.device_put(g, shardings) for g in groups_host]
for access in ("ro", "rw"):
    wb = access == "rw"
    apply = apply_rw if wb else apply_ro
    with HostStreamExecutor(apply, writeback=wb, device_shardings=shardings) as ex:
        ref, ref_outs = ex.run(jnp.zeros(()), eager_groups, mode="eager")
    for kind in mk.all_kinds():
        for dist in (0, 1, AUTO):
            tmp = tempfile.mkdtemp(prefix=f"conf-{kind.jax_kind}-")
            groups = groups_at(kind, tmp)
            mode = "on_demand" if dist == 0 else "prefetch"
            pf = None if dist == 0 else PrefetchSpec(
                buffer_size=N_GROUPS + 2, distance=dist)
            st = StreamStats()
            with HostStreamExecutor(apply, writeback=wb,
                                    device_shardings=shardings) as ex:
                out, outs = ex.run(jnp.zeros(()), groups, mode=mode,
                                   prefetch=pf, stats=st)
            cell = (kind.jax_kind, access, dist)
            # bitwise vs eager sharded placement at every kind x schedule
            assert float(out) == float(ref), cell
            if wb:
                for o, ro in zip(outs, ref_outs):
                    for a, b in zip(jax.tree.leaves(o), jax.tree.leaves(ro)):
                        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            # per-device request accounting: one request per (device, group)
            assert st.n_groups == N_GROUPS, cell
            tier = st.per_tier()
            if kind.jax_kind == "device":
                assert st.h2d_requests == 0 and st.disk_requests == 0, cell
                assert st.n_devices == 1, cell
            else:
                assert st.n_devices == 2, cell
                assert st.h2d_requests == 2 * N_GROUPS, (cell, st.h2d_requests)
                assert st.requests_per_group == 2.0, cell
                assert tier["h2d"]["requests_per_device_group"] == 1.0, cell
                if kind.jax_addressable:
                    assert st.disk_requests == 0, cell
                else:
                    assert st.disk_requests == N_GROUPS, cell
                    assert st.bytes_disk > 0, cell
print("SHARDED_CONFORMANCE_OK")
"""


@pytest.mark.slow
def test_sharded_conformance_matrix_2way_mesh():
    """The tentpole pin: every MemKind x ro/rw x distance 0/1/auto on a
    forced 2-device host mesh — bitwise equal to eager sharded placement,
    exactly one H2D request per (device, group)."""
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_MATRIX_SCRIPT],
        capture_output=True,
        text=True,
        timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_CONFORMANCE_OK" in proc.stdout


# ---------------------------------------------------------------------------
# regression: stream_host executor cache must key on policy/kinds/engine
# ---------------------------------------------------------------------------


def test_stream_host_cache_keys_on_policy_and_engine(tmp_path):
    """Switching PlacementPolicy (or engine) between stream_host calls must
    build a fresh executor — the old cache keyed only on the streamed-arg
    set, so the second call silently reused the first call's tier/engine."""
    from repro.core.engine import TransferEngine

    spec = PrefetchSpec(buffer_size=4, elements_per_fetch=2, distance=1)

    @offload(refs=dict(x=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec)))
    def k(x):
        return x + 1.0

    rng = np.random.default_rng(9)
    x = rng.standard_normal((8, 3)).astype(np.float32)
    eng = TransferEngine()
    try:
        st_host, st_disk = StreamStats(), StreamStats()
        out1 = k.stream_host(x, stats=st_host)
        out2 = k.stream_host(
            x, stats=st_disk, policy=mk.DISK_PARAMS, spill_dir=tmp_path / "s"
        )
        out3 = k.stream_host(x, engine=eng)
        # three distinct (kinds, engine) bindings -> three executors
        assert len(k._stream_host_cache) == 3
        # the disk-policy call really went through the disk tier (a stale
        # host executor would leave disk_requests at 0)
        assert st_disk.disk_requests > 0 and st_host.disk_requests == 0
        for o in (out2, out3):
            np.testing.assert_array_equal(out1, o)
        # same binding twice -> cache hit, not a fourth executor
        k.stream_host(x)
        assert len(k._stream_host_cache) == 3
    finally:
        k.close()
        eng.close()


def test_stream_host_cache_keys_on_streamed_tree_structure():
    """The executor's broadcast device_shardings are derived from the first
    call's streamed pytree structure; a different structure for the same
    arg name must build a fresh executor instead of tripping a leaf-count
    mismatch (found in review of the sharded-coalescing change)."""
    spec = PrefetchSpec(buffer_size=4, elements_per_fetch=2, distance=1)

    @offload(refs=dict(x=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec)))
    def k(x):
        return jax.tree.map(lambda a: a + 1.0, x)

    rng = np.random.default_rng(11)
    a = rng.standard_normal((8, 3)).astype(np.float32)
    b = rng.standard_normal((8, 3)).astype(np.float32)
    try:
        out1 = k.stream_host({"a": a})
        out2 = k.stream_host({"a": a, "b": b})  # same arg, wider pytree
        assert len(k._stream_host_cache) == 2
        np.testing.assert_allclose(out1["a"], a + 1.0, rtol=1e-6)
        np.testing.assert_allclose(out2["b"], b + 1.0, rtol=1e-6)
    finally:
        k.close()

"""Architecture registry + dry-run input specs.

``get_config(arch)`` / ``get_smoke_config(arch)`` return the full / reduced
``ModelConfig``; ``input_specs(cfg, shape)`` returns ShapeDtypeStruct
stand-ins for every model input of that (arch x shape) cell — weak-type
correct, shardable, no device allocation.
"""
from __future__ import annotations

import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig

_MODULES = {
    "olmo-1b": "olmo_1b",
    "internlm2-20b": "internlm2_20b",
    "smollm-360m": "smollm_360m",
    "minitron-4b": "minitron_4b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "mixtral-8x7b": "mixtral_8x7b",
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCHS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; have {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def vision_prefix_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM cells dedicate 1/8 of the sequence to the (stub) vision prefix."""
    return seq_len // 8 if cfg.vision_embed else 0


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Batch ShapeDtypeStructs for one (arch x shape) cell.

    ``train``/``prefill`` shapes describe the full sequence; ``decode``
    shapes describe ONE new token against a ``seq_len`` context (the KV
    cache / recurrent state specs come from ``state_specs``).
    """
    if shape not in SHAPES:
        raise ValueError(f"unknown shape {shape!r}; have {list(SHAPES)}")
    seq, batch, step = SHAPES[shape]
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)

    if step in ("train", "prefill"):
        if cfg.n_codebooks:
            return {
                "codes": jax.ShapeDtypeStruct((batch, cfg.n_codebooks, seq), i32),
                "targets": jax.ShapeDtypeStruct((batch, cfg.n_codebooks, seq), i32),
            }
        specs = {}
        s_img = vision_prefix_len(cfg, seq)
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq - s_img), i32)
        specs["targets"] = jax.ShapeDtypeStruct((batch, seq - s_img), i32)
        if cfg.vision_embed:
            specs["vision_embeds"] = jax.ShapeDtypeStruct((batch, s_img, cfg.d_model), f)
        if cfg.pos_type == "mrope":
            specs["positions_3d"] = jax.ShapeDtypeStruct((batch, 3, seq), i32)
        return specs

    # decode: one new token
    if cfg.n_codebooks:
        return {"codes": jax.ShapeDtypeStruct((batch, cfg.n_codebooks, 1), i32)}
    specs = {"tokens": jax.ShapeDtypeStruct((batch, 1), i32)}
    if cfg.pos_type == "mrope":
        specs["positions_3d"] = jax.ShapeDtypeStruct((batch, 3, 1), i32)
    return specs


def cell_is_runnable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, (
            "pure full-attention arch: 500k-token decode needs sub-quadratic "
            "attention (see DESIGN.md §5 skip list)"
        )
    return True, ""

from repro.data.synthetic import synthetic_batch, SyntheticConfig
from repro.data.loader import PrefetchLoader

__all__ = ["synthetic_batch", "SyntheticConfig", "PrefetchLoader"]

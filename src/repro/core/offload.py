"""The ``@offload`` decorator — paper Listings 1-3, TPU-native.

Paper semantics: decorating a function with ``@offload`` makes calls execute
on the accelerator; arguments are passed **by reference** and the runtime
moves data according to each argument's memory kind and optional prefetch
annotation.

Here, "the accelerator" is the TPU mesh: ``@offload`` compiles the function
with per-argument shardings + memory kinds derived from ``OffloadRef``
annotations, and materializes arguments at their declared hierarchy level on
first use.  Host-kind arguments annotated with a ``PrefetchSpec`` are streamed
block-wise through the graph engine instead of bulk-copied.

Example (paper Listing 3 analogue)::

    from repro.core import offload, OffloadRef, PrefetchSpec, memkind as mk

    @offload(refs=dict(
        a=OffloadRef(kind=mk.PINNED_HOST,
                     prefetch=PrefetchSpec(buffer_size=10, elements_per_fetch=2,
                                           distance=4)),
        b=OffloadRef(kind=mk.PINNED_HOST,
                     prefetch=PrefetchSpec(buffer_size=10, elements_per_fetch=2,
                                           distance=4)),
    ))
    def mykernel(a, b):
        return a + b
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Mapping, Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro import jaxcompat
from repro.core import memkind as mk
from repro.core import prefetch as pf
from repro.core.refspec import OffloadRef

__all__ = ["offload"]


def _default_mesh() -> Mesh:
    dev = jax.devices()
    return jaxcompat.make_mesh((len(dev),), ("data",))


class OffloadedFunction:
    """Callable produced by ``@offload``.  Keeps the paper's behaviours:

    * ``__call__`` — execute on the mesh, honouring each ref's kind+prefetch.
    * ``.eager``   — force the paper's original bulk-copy invocation.
    * ``.place(name, value)`` — the paper's ``define_on_device`` /
      ``copy_to_device``: materialize an argument at its declared kind ahead
      of the call so repeated invocations skip the transfer.
    """

    def __init__(
        self,
        fn: Callable[..., Any],
        refs: Mapping[str, OffloadRef],
        mesh: Optional[Mesh],
        out_specs: Any,
        donate: tuple[str, ...] = (),
    ) -> None:
        functools.update_wrapper(self, fn)
        self._fn = fn
        self._refs = dict(refs)
        self._mesh = mesh
        self._out_specs = out_specs
        self._donate = donate
        self._signature = inspect.signature(fn)
        self._params = list(self._signature.parameters)
        unknown = set(refs) - set(self._params)
        if unknown:
            raise ValueError(f"refs for unknown arguments: {sorted(unknown)}")
        self._compiled: dict[Any, Callable] = {}
        #: host-stream executors, keyed on (streamed-arg set, per-arg memory
        #: kinds, engine identity) — see stream_host.  Keying on the arg set
        #: alone reused a stale executor (wrong engine / wrong tier) when the
        #: caller switched PlacementPolicy between calls.
        self._stream_host_cache: dict[tuple, "HostStreamExecutor"] = {}
        #: lazily-created spill store for DiskHost-kind streamed args
        self._spill_store: Any = None

    # -- placement helpers ---------------------------------------------------
    def mesh(self) -> Mesh:
        if self._mesh is None:
            self._mesh = _default_mesh()
        return self._mesh

    def _ref(self, name: str) -> OffloadRef:
        return self._refs.get(name, OffloadRef())

    def _home_sharding(self, name: str):
        r = self._ref(name)
        return mk.sharding_for(self.mesh(), r.spec, r.kind)

    def _device_sharding(self, name: str):
        r = self._ref(name)
        return mk.sharding_for(self.mesh(), r.spec, mk.DEVICE)

    def place(self, name: str, value: Any) -> jax.Array:
        """Materialize ``value`` at the argument's declared hierarchy level."""
        if name not in self._params:
            raise ValueError(f"{name!r} is not an argument of {self._fn.__name__}")
        return jax.device_put(value, self._home_sharding(name))

    # -- invocation ----------------------------------------------------------
    def _build(self, streamed: bool):
        names = self._params
        in_shardings = tuple(self._home_sharding(n) for n in names)
        donate_argnums = tuple(i for i, n in enumerate(names) if n in self._donate)

        stream_names = [
            n for n in names if self._ref(n).streamed and streamed
        ]

        if not stream_names:
            fn = self._fn
        else:
            # Streamed refs are processed block-wise over their stream axis
            # (all streamed args must agree on leading-axis length); the rest
            # are closed over.  fn must be a per-element map for this path —
            # the framework's layer streaming uses prefetch.streamed_scan
            # directly instead (see repro/train/steps.py).
            refs = {n: self._ref(n) for n in stream_names}
            spec = next(iter(refs.values())).prefetch
            base = self._fn

            def fn(*args):
                bound = dict(zip(names, args))
                streamed_args = tuple(bound[n] for n in stream_names)
                dev_sh = tuple(
                    jax.tree.map(lambda _: self._device_sharding(n), bound[n])
                    for n in stream_names
                )

                def block_fn(*blocks):
                    full = dict(bound)
                    full.update(dict(zip(stream_names, blocks)))
                    return base(**full)

                return pf.stream_blocks(
                    block_fn, streamed_args, prefetch=spec, dev_shardings=dev_sh
                )

        out_shardings = (
            None
            if self._out_specs is None
            else jax.tree.map(
                lambda s: mk.sharding_for(self.mesh(), s),
                self._out_specs,
                is_leaf=lambda s: isinstance(s, PartitionSpec),
            )
        )
        return jax.jit(
            fn,
            in_shardings=in_shardings,
            out_shardings=out_shardings,
            donate_argnums=donate_argnums,
        )

    def _call(self, streamed: bool, *args: Any, **kwargs: Any) -> Any:
        bound = self._signature.bind(*args, **kwargs)
        bound.apply_defaults()
        key = streamed
        if key not in self._compiled:
            self._compiled[key] = self._build(streamed)
        ordered = tuple(bound.arguments[n] for n in self._params)
        # materialize at home kinds (pass-by-reference: host args stay host)
        placed = tuple(
            v if isinstance(v, jax.Array) else self.place(n, v)
            for n, v in zip(self._params, ordered)
        )
        return self._compiled[key](*placed)

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        return self._call(True, *args, **kwargs)

    def eager(self, *args: Any, **kwargs: Any) -> Any:
        """Paper's original eager-copy invocation (bulk transfer, then run)."""
        return self._call(False, *args, **kwargs)

    def stream_host(
        self,
        *args: Any,
        mode: str = "prefetch",
        engine: Any = None,
        stats: Any = None,
        policy: Any = None,
        spill_dir: Any = None,
        **kwargs: Any,
    ) -> Any:
        """Run with streamed refs served by the *host-side* transfer engine.

        Where ``__call__`` streams inside the compiled program (the graph
        engine — static ring, fixed distance), this path is the paper's §4
        runtime architecture: streamed arguments stay host-resident numpy,
        a background :class:`~repro.core.engine.TransferEngine` coalesces
        and prefetches blocks ahead of the jitted per-block apply, and the
        block outputs write back to the host kind (``rw``).  It honours
        ``PrefetchSpec(distance="auto")`` (runtime-adaptive window) and is
        numerically identical to ``__call__``/``eager``.

        Under a multi-device mesh the streamed blocks are staged at each
        ref's *device* sharding through the engine's sharding-aware
        coalescing: one H2D request per (addressable device, block group)
        — the per-leaf fallback that re-introduced the request storm under
        ``--model-parallel`` is gone — and staged blocks are bitwise equal
        to eager sharded placement.

        ``policy`` (a :class:`~repro.core.memkind.PlacementPolicy`)
        overrides the home tier of the streamed arguments at call time —
        its ``params`` kind applies to every streamed ref.  A non-XLA kind
        (``DiskHost``) spills each block to a chunk-granular
        :class:`~repro.core.spillstore.SpillStore` (under ``spill_dir``, or
        a private temp dir) and streams it through the engine's two-stage
        disk->host->device pipeline — same values, one more hierarchy
        level.

        The executor (jitted per-block apply + engine worker) is cached per
        (streamed-arg set, per-arg memory kind, engine identity); switching
        ``policy`` or ``engine`` between calls therefore builds a fresh
        executor instead of silently reusing a stale one.  Call
        :meth:`close` to release the workers.
        """
        from repro.core.hoststream import HostStreamExecutor

        bound = self._signature.bind(*args, **kwargs)
        bound.apply_defaults()
        stream_names = [n for n in self._params if self._ref(n).streamed]
        if not stream_names:
            return self(*args, **kwargs)
        spec = self._ref(stream_names[0]).prefetch
        g = spec.elements_per_fetch
        kinds = tuple(
            (policy.params if policy is not None else self._ref(n).kind)
            for n in stream_names
        )
        fixed = {
            n: v if isinstance(v, jax.Array) else self.place(n, v)
            for n, v in bound.arguments.items()
            if n not in stream_names
        }
        streamed_vals = {n: bound.arguments[n] for n in stream_names}
        n_rows = jax.tree.leaves(streamed_vals[stream_names[0]])[0].shape[0]
        if n_rows % g != 0:
            raise ValueError(
                f"leading axis {n_rows} not divisible by elements_per_fetch={g}"
            )

        # the executor (and its jitted per-block apply + engine worker) is
        # built once per (streamed-arg set, kinds, engine, mesh, streamed
        # tree structure) and reused across calls; the fixed arguments
        # travel in the carry, so new values don't retrace.  The structure
        # is part of the key because the executor's broadcast
        # device_shardings are derived from it — a different pytree shape
        # for the same arg name needs a fresh executor
        key = (
            tuple(stream_names),
            tuple(k.jax_kind for k in kinds),
            id(engine) if engine is not None else None,
            self.mesh(),
            tuple(jax.tree.structure(streamed_vals[n]) for n in stream_names),
        )
        ex = self._stream_host_cache.get(key)
        if ex is None:
            base = self._fn

            @jax.jit
            def apply(carry, block):
                return carry, base(**carry, **dict(zip(stream_names, block)))

            # stage each block at its ref's device sharding: under a mesh
            # the engine packs one buffer per (device, group) instead of
            # falling back to per-leaf placement
            dev_sh = tuple(
                jax.tree.map(lambda _: self._device_sharding(n), streamed_vals[n])
                for n in stream_names
            )
            ex = HostStreamExecutor(
                apply, writeback=True, engine=engine, device_shardings=dev_sh
            )
            self._stream_host_cache[key] = ex

        groups = [
            tuple(
                jax.tree.map(lambda a: a[i : i + g], streamed_vals[n])
                for n in stream_names
            )
            for i in range(0, n_rows, g)
        ]
        if any(not k.jax_addressable for k in kinds):
            groups = [
                self._spill(f"g{i:04d}", grp, spill_dir)
                for i, grp in enumerate(groups)
            ]
        _, outs = ex.run(fixed, groups, mode=mode, prefetch=spec, stats=stats)
        return jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *outs)

    def _spill(self, key: str, group: Any, spill_dir: Any) -> Any:
        """Move one block to the DiskHost tier: persist it in the spill
        store and return the memory-mapped view tree.  A privately created
        temp store is ephemeral (deleted on close); a caller-supplied
        ``spill_dir`` is durable and never deleted."""
        import pathlib

        from repro.core.spillstore import SpillStore

        if self._spill_store is not None and spill_dir is not None:
            if pathlib.Path(spill_dir) != self._spill_store.dir:
                raise ValueError(
                    f"stream_host already bound a spill store at "
                    f"{str(self._spill_store.dir)!r}; close() before "
                    f"switching to spill_dir={str(spill_dir)!r}"
                )
        if self._spill_store is None:
            ephemeral = spill_dir is None
            if ephemeral:
                import tempfile

                spill_dir = tempfile.mkdtemp(
                    prefix=f"repro-spill-{self._fn.__name__}-"
                )
            self._spill_store = SpillStore(spill_dir, ephemeral=ephemeral)
        self._spill_store.put(key, group)
        return self._spill_store.get(key)

    def close(self) -> None:
        """Shut down any host-stream executors (and their engine workers),
        and drop the spill store (deleting it if privately created)."""
        for ex in self._stream_host_cache.values():
            ex.close()
        self._stream_host_cache.clear()
        if self._spill_store is not None:
            self._spill_store.close()  # deletes iff the store is ephemeral
            self._spill_store = None

    def lower(self, *args: Any, streamed: bool = True):
        """Lower without executing (dry-run path; keeps true memory kinds)."""
        if streamed not in self._compiled:
            self._compiled[streamed] = self._build(streamed)
        return self._compiled[streamed].lower(*args)


def offload(
    fn: Optional[Callable[..., Any]] = None,
    *,
    refs: Optional[Mapping[str, OffloadRef]] = None,
    mesh: Optional[Mesh] = None,
    out_specs: Any = None,
    donate: tuple[str, ...] = (),
) -> Any:
    """Decorate a function for accelerator offload (see module docstring)."""

    def wrap(f: Callable[..., Any]) -> OffloadedFunction:
        return OffloadedFunction(f, refs or {}, mesh, out_specs, donate)

    if fn is not None:
        return wrap(fn)
    return wrap

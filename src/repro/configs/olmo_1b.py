"""OLMo-1B [arXiv:2402.00838; hf:allenai/OLMo-1B].

16L d_model=2048 16H (kv=16, MHA) d_ff=8192 vocab=50304 — non-parametric LN,
SwiGLU, RoPE, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    mlp_type="swiglu",
    norm_type="layernorm_nonparam",
    pos_type="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2402.00838; hf",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, remat="none",
    )

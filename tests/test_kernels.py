"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True).

Sweeps shapes and dtypes per kernel; every PrefetchSpec setting must be
value-identical (the paper's §3.1 correctness invariant).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.refspec import PrefetchSpec
from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.streamed_matmul import matmul_ref, streamed_matmul


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# streamed matmul
# ---------------------------------------------------------------------------

MM_SHAPES = [(128, 256, 128), (64, 100, 200), (7, 384, 512), (1, 128, 128), (130, 130, 130)]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_streamed_matmul_matches_oracle(m, k, n, dt):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), dt)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), dt)
    ref = np.asarray(matmul_ref(x, w), np.float32)
    out = streamed_matmul(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref, **_tol(dt))


@pytest.mark.parametrize("dist,slots", [(0, 1), (1, 2), (2, 3), (3, 4), (1, 4)])
def test_streamed_matmul_prefetch_invariance(dist, slots):
    """Paper §3.1: prefetch settings never change the value."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 192), jnp.float32)
    base = streamed_matmul(x, w, spec=PrefetchSpec(1, 1, 0))
    out = streamed_matmul(x, w, spec=PrefetchSpec(slots, 1, dist))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_streamed_matmul_auto_distance():
    """distance='auto' resolves to a static head start at trace time for
    the fixed-shape VMEM ring (found in review: crashed on the sentinel)."""
    from repro.core.refspec import AUTO

    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 192), jnp.float32)
    base = streamed_matmul(x, w, spec=PrefetchSpec(1, 1, 0))
    out = streamed_matmul(x, w, spec=PrefetchSpec(buffer_size=5, distance=AUTO))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_streamed_matmul_batched_dims():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 32, 96), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 64), jnp.float32)
    out = streamed_matmul(x, w)
    ref = matmul_ref(x.reshape(-1, 96), w).reshape(2, 3, 32, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, S, T, N, KH, H, window, q_offset)
    (2, 128, 128, 4, 4, 64, 0, 0),
    (1, 256, 256, 8, 2, 64, 0, 0),
    (2, 128, 128, 4, 1, 128, 0, 0),
    (1, 256, 256, 4, 2, 64, 64, 0),
    (1, 100, 100, 4, 4, 64, 0, 0),
    (2, 64, 192, 4, 2, 64, 0, 128),
    (1, 128, 128, 10, 5, 64, 0, 0),
    (1, 128, 128, 4, 2, 256, 96, 0),
]


@pytest.mark.parametrize("b,s,t,n,kh,h,window,qo", FA_CASES)
def test_flash_attention_matches_oracle(b, s, t, n, kh, h, window, qo):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, n, h), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, kh, h), jnp.float32) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, kh, h), jnp.float32)
    ref = attention_ref(q, k, v, causal=True, window=window, q_offset=qo)
    out = flash_attention(q, k, v, causal=True, window=window, q_offset=qo,
                          block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=2e-4)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dt):
    b, s, n, kh, h = 1, 128, 4, 2, 64
    q = (jax.random.normal(jax.random.PRNGKey(0), (b, s, n, h)) * 0.5).astype(dt)
    k = (jax.random.normal(jax.random.PRNGKey(1), (b, s, kh, h)) * 0.5).astype(dt)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kh, h)).astype(dt)
    ref = np.asarray(attention_ref(q, k, v), np.float32)
    out = np.asarray(flash_attention(q, k, v), np.float32)
    np.testing.assert_allclose(out, ref, **_tol(dt))


def test_flash_attention_block_size_invariance():
    b, s, n, kh, h = 1, 256, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, n, h)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kh, h)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kh, h))
    a = flash_attention(q, k, v, block_q=32, block_kv=64)
    bb = flash_attention(q, k, v, block_q=128, block_kv=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DA_CASES = [
    (2, 512, 4, 4, 64, [512, 300]),
    (2, 1024, 8, 2, 64, [1, 777]),
    (1, 300, 4, 1, 128, [300]),
    (2, 2048, 8, 4, 128, [2048, 100]),
    (1, 256, 10, 5, 64, [129]),
]


@pytest.mark.parametrize("b,t,n,kh,h,lens", DA_CASES)
def test_decode_attention_matches_oracle(b, t, n, kh, h, lens):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, n, h), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, kh, h), jnp.float32) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, kh, h), jnp.float32)
    lengths = jnp.asarray(lens, jnp.int32)
    ref = decode_attention_ref(q, k, v, lengths)
    out = decode_attention(q, k, v, lengths, block_kv=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=2e-4)


@pytest.mark.parametrize("dist,slots", [(0, 1), (1, 2), (3, 4)])
def test_decode_attention_prefetch_invariance(dist, slots):
    b, t, n, kh, h = 2, 512, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, n, h)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, kh, h)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, kh, h))
    lengths = jnp.asarray([512, 77], jnp.int32)
    base = decode_attention(q, k, v, lengths, spec=PrefetchSpec(1, 1, 0))
    out = decode_attention(q, k, v, lengths, spec=PrefetchSpec(slots, 1, dist))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_decode_attention_matches_flash_single_token():
    """Cross-kernel: decode(q1) == flash(full prefix)[:, -1]."""
    b, t, n, kh, h = 1, 256, 4, 2, 64
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q_full = jax.random.normal(keys[0], (b, t, n, h)) * 0.5
    k = jax.random.normal(keys[1], (b, t, kh, h)) * 0.5
    v = jax.random.normal(keys[2], (b, t, kh, h))
    full = flash_attention(q_full, k, v, causal=True)
    one = decode_attention(q_full[:, -1], k, v, jnp.asarray([t], jnp.int32))
    np.testing.assert_allclose(np.asarray(one), np.asarray(full[:, -1]), rtol=1e-4, atol=2e-4)

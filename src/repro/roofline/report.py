"""Render EXPERIMENTS.md tables from results/dryrun.json.

  PYTHONPATH=src python -m repro.roofline.report results/dryrun.json
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.roofline.hw import V5E

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_ms(s):
    return f"{s*1e3:.1f}"


def dryrun_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | status | compile s | mem/dev GiB | HLO GFLOP/dev | coll GB/dev (raw AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]), r["mesh"])
    for r in sorted(rows, key=key):
        if not r.get("runnable", True):
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP ({r['skip_reason'][:40]}…) | — | — | — | — |"
            )
            continue
        if not r.get("ok"):
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** {r.get('error','')[:60]} | — | — | — | — |"
            )
            continue
        mem = r["memory"]["per_device_total_gib"]
        fl = r["cost_raw"]["flops"] / 1e9
        c = r["coll_raw"]
        coll = "/".join(
            f"{c.get(k,0)/1e9:.2f}"
            for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
        )
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']} | "
            f"{mem:.2f} | {fl:.0f} | {coll} |"
        )
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | t_compute ms | t_memory ms (analytic) | t_mem ms (HLO) | t_collective ms | dominant | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))
    for r in sorted([r for r in rows if r.get("roofline")], key=key):
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_ms(t['t_compute_s'])} | "
            f"{_fmt_ms(t['t_memory_s'])} | {_fmt_ms(t.get('t_memory_hlo_s', 0))} | "
            f"{_fmt_ms(t['t_collective_s'])} | {t['dominant']} | "
            f"{t['model_flops_ratio']:.3f} | {t['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def pick_hillclimb(rows: list[dict]) -> list[tuple[str, dict]]:
    """worst roofline fraction / most collective-bound / most paper-representative."""
    cand = [r for r in rows if r.get("roofline")]
    if not cand:
        return []
    worst = min(cand, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(
        cand,
        key=lambda r: r["roofline"]["t_collective_s"]
        / max(r["roofline"]["t_dominant_s"], 1e-30),
    )
    # paper-representative: the memory-hierarchy-bound serve step with the
    # largest streamed state (decode of the biggest cache)
    decodes = [r for r in cand if r.get("step_kind") == "decode"]
    paper = max(
        decodes or cand, key=lambda r: r["probe"]["analytic_bytes"]
    )
    return [("worst-fraction", worst), ("most-collective-bound", coll), ("paper-representative", paper)]


def main() -> int:
    path = Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json")
    rows = json.loads(path.read_text())
    print("## §Dry-run\n")
    print(dryrun_table(rows))
    single = [r for r in rows if r["mesh"] == "16x16"]
    print("\n## §Roofline (single-pod, probe-scaled)\n")
    print(roofline_table(single))
    print("\n## Hillclimb candidates\n")
    for tag, r in pick_hillclimb(single):
        t = r["roofline"]
        print(
            f"- **{tag}**: {r['arch']} x {r['shape']} "
            f"(dominant={t['dominant']}, fraction={t['roofline_fraction']:.3f})"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf:google/recurrentgemma-2b].

26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 (GeGLU) vocab=256000
— RG-LRU + local attention, pattern (rec, rec, attn) cycled, window 2048,
lru_width 2560, sqrt(d) embedding scale, logit softcap 30, tied embeddings.
Heterogeneous blocks => unrolled layer loop.  Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    mlp_type="geglu",
    norm_type="rmsnorm",
    pos_type="rope",
    rope_theta=10_000.0,
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    conv_width=4,
    scale_embeddings=True,
    logit_softcap=30.0,
    tie_embeddings=True,
    use_scan=True,  # period-scan over (rec,rec,attn) triples + unrolled tail
    source="arXiv:2402.19427; hf",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=256, window=16, lru_width=64, remat="none",
    )

"""MusicGen-medium [arXiv:2306.05284; hf:facebook/musicgen-medium].

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 — decoder-only over
EnCodec tokens: 4 codebooks, input embedding = sum over codebooks, 4 parallel
LM heads.  EnCodec itself is a STUB (assignment: precomputed frame tokens via
``input_specs``).  GELU MLP, LayerNorm, sinusoidal positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    mlp_type="gelu",
    norm_type="layernorm",
    pos_type="sinusoidal",
    n_codebooks=4,
    source="arXiv:2306.05284; hf",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64, n_codebooks=2, remat="none",
    )

"""Long-context serving with hierarchical KV placement.

The paper's headline ("process arbitrarily large data sets") applied to the
decode path: a recurrent/windowed arch (recurrentgemma family) decodes far
past its cache window with O(window) state, and the KV cache can be placed
at the Host memory kind (``--kv-kind pinned_host``) — the decode step still
sees references; the runtime streams.

Run:  PYTHONPATH=src:. python examples/long_context_serve.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import serve


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--kv-kind", default="device", choices=["device", "pinned_host"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    mesh = make_local_mesh()
    print(
        f"{args.arch} (smoke): window={cfg.window}, generating {args.gen} tokens "
        f"past a {args.prompt_len}-token prompt; decode state is O(window), "
        f"kv kind = {args.kv_kind}"
    )
    res = serve(
        cfg,
        mesh,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen=args.gen,
        kv_kind=args.kv_kind,
    )
    gen = np.asarray(res["generated"])
    assert gen.shape == (args.batch, args.gen)
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab_size)
    print(
        f"prefill {res['prefill_s']*1e3:.1f} ms; decode {res['decode_s']*1e3:.1f} ms"
        f" ({res['tokens_per_s']:.1f} tok/s); sample: {gen[0][:12]}"
    )
    print("long-context serve: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Memory-mapped chunk-granular spill store — the ``DiskHost`` tier's home.

The paper's §3.2 point is that a memory-hierarchy level need not be
addressable by the accelerator at all: a ``Kind`` subclass plus a runtime
service suffice.  This module is that service for disk: pytree *chunks*
(one transfer group each — a layer's params, one optimizer-state group,
one data shard) are persisted as single binary files with all leaves packed
at 64-byte-aligned offsets, described by a JSON manifest.

Reads are memory-mapped (``np.memmap``): ``get()`` returns a pytree whose
leaves are zero-copy views into the chunk file, so *referencing* a spilled
chunk costs nothing — bytes move only when the transfer engine's disk stage
copies a leaf into a host staging buffer (that copy is the disk read).  One
chunk = one file = one disk request, mirroring the engine's H2D coalescing
at the disk tier.

bf16 (and other extension dtypes) are stored as raw bytes and re-viewed
through ``jnp.dtype`` on load — the same dtype re-view trick checkpoint
restore uses (npy would serialize them as raw void).

Writes are atomic (tmp + rename), so a chunk overwritten while an old
memmap is still open leaves the old mapping valid (the fd keeps the
unlinked inode alive) and the next ``get`` sees the new bytes.

Integrity: ``put`` records a CRC32 per leaf (and per chunk) in the
manifest, and the views ``get`` hands out carry their provenance
(:class:`SpillView`), so the transfer engine's disk stage can verify the
mapped bytes right before consuming them (:func:`verify_disk_leaf`).  A
mismatch is re-read once, then re-fetched from the chunk's durable home via
the store's ``recovery`` callback, and only then surfaces as a rich
:class:`SpillCorruptionError` — corrupt bytes are never silently fed into
the optimizer.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import re
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SpillStore",
    "SpillView",
    "SpillCorruptionError",
    "is_disk_leaf",
    "verify_disk_leaf",
]

log = logging.getLogger("repro.spillstore")

Pytree = Any

#: leaf offsets inside a chunk file are padded to this many bytes
_ALIGN = 64

_MANIFEST = "manifest.json"


def _align(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


def _fname(key: str) -> str:
    """Filesystem-safe chunk file name for a key.  Sanitized names carry a
    short digest of the raw key so distinct keys ('g/1' vs 'g__1') can
    never collapse onto the same chunk file."""
    safe = re.sub(r"[^A-Za-z0-9._-]", "__", key)
    if safe != key:
        digest = hashlib.sha1(key.encode()).hexdigest()[:8]
        safe = f"{safe}-{digest}"
    return safe + ".bin"


def is_disk_leaf(x: Any) -> bool:
    """True if ``x`` is resident at the disk tier (a memory-mapped view —
    the representation ``SpillStore.get`` hands out)."""
    return isinstance(x, np.memmap)


class SpillCorruptionError(RuntimeError):
    """A chunk's bytes no longer match their manifest CRC32.

    Raised on *fetch* (never after the bytes were consumed) with enough
    provenance — chunk key, file, leaf index, byte range, both checksums —
    to locate the bad bytes on disk."""

    def __init__(
        self,
        key: str,
        file: str,
        leaf_index: int,
        offset: int,
        nbytes: int,
        expected: int,
        actual: int,
    ) -> None:
        self.key = key
        self.file = str(file)
        self.leaf_index = leaf_index
        self.offset = offset
        self.nbytes = nbytes
        self.expected = expected
        self.actual = actual
        super().__init__(
            f"spill chunk {key!r} corrupt: leaf {leaf_index} at offset "
            f"{offset} ({nbytes} bytes) of {self.file} has crc32 "
            f"{actual:#010x}, manifest says {expected:#010x}"
        )


class SpillView(np.memmap):
    """A chunk leaf view carrying its provenance (store, chunk key, leaf
    index, byte range, manifest CRC) so fetch-time verification can find
    the checksum for the bytes it is about to consume.

    Derived arrays (slices, dtype views) inherit the provenance of the
    full-leaf view they came from; :func:`verify_disk_leaf` only checks
    views that still cover the whole leaf (``spill_nbytes``)."""

    _SPILL_ATTRS = (
        "spill_store",
        "spill_key",
        "spill_leaf",
        "spill_offset",
        "spill_nbytes",
        "spill_crc32",
        "spill_base",
    )

    def __array_finalize__(self, obj):
        super().__array_finalize__(obj)
        for a in self._SPILL_ATTRS:
            if getattr(self, a, None) is None:
                setattr(self, a, getattr(obj, a, None))


def verify_disk_leaf(leaf: Any) -> Any:
    """CRC-check one fetched leaf view against its manifest checksum.

    The engine's disk stage calls this right before the staging copy (the
    second pass over page-cache-hot bytes costs memcpy speed).  Leaves
    without provenance — plain memmaps, partial views, chunks written
    before CRCs existed — pass through unverified.

    On a mismatch: re-read once (transient corruption heals), then give the
    store's ``recovery`` callback one shot at rewriting the chunk from its
    durable home, and only then raise :class:`SpillCorruptionError`.
    """
    crc = getattr(leaf, "spill_crc32", None)
    store = getattr(leaf, "spill_store", None)
    base = getattr(leaf, "spill_base", None)
    if crc is None or store is None or base is None:
        return leaf
    o, n = leaf.spill_offset, leaf.spill_nbytes
    if n != leaf.size * leaf.dtype.itemsize:
        return leaf  # partial view: a whole-leaf CRC cannot attribute it
    if zlib.crc32(base[o : o + n]) == crc:
        return leaf
    actual = zlib.crc32(base[o : o + n])  # one re-read before declaring rot
    if actual == crc:
        return leaf
    store.crc_failures += 1
    err = SpillCorruptionError(
        leaf.spill_key,
        getattr(leaf, "filename", None) or "<unlinked>",
        leaf.spill_leaf,
        o,
        n,
        crc,
        actual,
    )
    log.error("%s", err)
    try:
        fresh = store.recover(leaf.spill_key)
    except KeyError:
        raise err from None
    return jax.tree.leaves(fresh)[leaf.spill_leaf]


class SpillStore:
    """Chunk-granular pytree spill store backed by mmap'd binary files.

    Within a process the store remembers each chunk's treedef, so
    ``get(key)`` reconstructs the original pytree; a fresh process (restart)
    can pass ``template=`` to re-impose structure from the manifest's flat
    leaf list.
    """

    def __init__(
        self,
        directory: "str | os.PathLike",
        *,
        ephemeral: bool = False,
        recovery: Optional[Callable[[str], Pytree]] = None,
    ) -> None:
        """``ephemeral=True`` marks a store whose contents only matter for
        the lifetime of this process (a run-private spill of recomputable
        state): ``close()`` deletes the directory, and ``put`` skips the
        durability work (per-chunk fsync, per-put manifest flush — the
        manifest is kept in memory and written once on a durable close).

        ``recovery`` maps a chunk key to a rebuilt pytree from the chunk's
        *durable* home (checkpoint leaves, recomputation); it is the one
        re-fetch a CRC mismatch gets before the error surfaces."""
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.ephemeral = ephemeral
        self._lock = threading.Lock()
        self._treedefs: dict[str, Any] = {}
        self._recovery = recovery
        mpath = self.dir / _MANIFEST
        self._manifest: dict[str, Any] = (
            json.loads(mpath.read_text()) if mpath.exists() else {}
        )
        #: bytes written / read-mapped (observability; benchmarks report it)
        self.bytes_written: int = 0
        #: CRC mismatches detected on fetch / chunks rewritten from their
        #: durable home (observability; the recovery bench gates on these)
        self.crc_failures: int = 0
        self.recoveries: int = 0

    def set_recovery(self, fn: Optional[Callable[[str], Pytree]]) -> None:
        """Register (or clear) the durable-home rebuild callback."""
        self._recovery = fn

    # ------------------------------------------------------------------ write
    def put(self, key: str, tree: Pytree) -> None:
        """Persist one chunk atomically (tmp + rename); overwrites ``key``."""
        leaves, treedef = jax.tree.flatten(tree)
        metas = []
        off = 0
        arrays = []
        for leaf in leaves:
            a = np.asarray(leaf)
            metas.append(
                {
                    "offset": off,
                    "shape": list(a.shape),
                    "dtype": str(a.dtype),
                    "nbytes": a.nbytes,
                }
            )
            arrays.append(a)
            off = _align(off + a.nbytes)
        path = self.dir / _fname(key)
        tmp = path.with_suffix(".tmp")
        chunk_crc = 0
        with open(tmp, "wb") as f:
            pos = 0
            for meta, a in zip(metas, arrays):
                f.write(b"\0" * (meta["offset"] - pos))
                # tobytes, not memoryview: extension dtypes (bfloat16) do
                # not implement the buffer protocol
                data = np.ascontiguousarray(a).tobytes()
                # checksum exactly the bytes written, so fetch-time
                # verification can recompute from the raw mapped range
                meta["crc32"] = zlib.crc32(data)
                chunk_crc = zlib.crc32(data, chunk_crc)
                f.write(data)
                pos = meta["offset"] + meta["nbytes"]
            if not self.ephemeral:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic commit; old memmaps stay valid
        entry = {
            "file": path.name,
            "total_bytes": off,
            "crc32": chunk_crc,
            "leaves": metas,
        }
        with self._lock:
            self._treedefs[key] = treedef
            changed = self._manifest.get(key) != entry
            self._manifest[key] = entry
            if not self.ephemeral and changed:
                # durable stores keep the on-disk manifest current per put
                # (crash-restartable); unchanged entries (the steady-state
                # per-step writeback: same file, offsets, dtypes) and
                # ephemeral stores skip the rewrite on the hot path
                self._write_manifest()
        self.bytes_written += sum(m["nbytes"] for m in metas)

    def _write_manifest(self) -> None:
        tmp = self.dir / (_MANIFEST + ".tmp")
        tmp.write_text(json.dumps(self._manifest, indent=1))
        os.replace(tmp, self.dir / _MANIFEST)

    # ------------------------------------------------------------------- read
    def get(self, key: str, template: Optional[Pytree] = None) -> Pytree:
        """Pytree of memory-mapped leaf views into the chunk file (zero-copy
        until the bytes are actually touched).

        ``template`` re-imposes tree structure when the treedef is not known
        in-process (restart); its leaves only supply structure.

        Zero-length leaves come back as plain empty ndarrays (there are no
        bytes to map) — consumers treat them as host-resident, which is
        vacuously correct.
        """
        entry = self._entry(key)
        # mmap rejects empty files: an all-zero-length chunk has no bytes
        # to map, so its views are plain empty ndarrays
        mm = (
            np.memmap(self.dir / entry["file"], dtype=np.uint8, mode="r")
            if entry["total_bytes"]
            else np.empty((0,), np.uint8)
        )
        views = []
        for i, meta in enumerate(entry["leaves"]):
            o, n = meta["offset"], meta["nbytes"]
            # jnp.dtype resolves extension dtypes (bfloat16, fp8) that plain
            # np.dtype does not know — the checkpoint-restore re-view trick
            dt = jnp.dtype(meta["dtype"])
            v = mm[o : o + n].view(dt).reshape(meta["shape"])
            if n and meta.get("crc32") is not None:
                # attach provenance so fetch-time CRC verification can find
                # the checksum (and the raw byte range) for this leaf
                v = v.view(SpillView)
                v.spill_store = self
                v.spill_key = key
                v.spill_leaf = i
                v.spill_offset = o
                v.spill_nbytes = n
                v.spill_crc32 = meta["crc32"]
                v.spill_base = mm
            views.append(v)
        treedef = self._treedefs.get(key)
        if treedef is None and template is not None:
            treedef = jax.tree.structure(template)
            self._treedefs[key] = treedef
        if treedef is None:
            if len(views) == 1:
                return views[0]
            raise KeyError(
                f"chunk {key!r} was written by another process; pass template= "
                "to reconstruct its pytree structure"
            )
        return jax.tree.unflatten(treedef, views)

    def read(self, key: str, template: Optional[Pytree] = None) -> Pytree:
        """Materialized (plain ndarray) copy of a chunk — a full disk read."""
        return jax.tree.map(np.array, self.get(key, template))

    # -------------------------------------------------------------- integrity
    def verify_chunk(self, key: str) -> None:
        """Recompute every leaf CRC of ``key`` from the chunk file; raises
        :class:`SpillCorruptionError` at the first mismatch.  Chunks written
        before CRCs existed (no ``crc32`` in the manifest) pass vacuously."""
        entry = self._entry(key)
        if not entry["total_bytes"]:
            return
        mm = np.memmap(self.dir / entry["file"], dtype=np.uint8, mode="r")
        for i, meta in enumerate(entry["leaves"]):
            expected = meta.get("crc32")
            if expected is None or not meta["nbytes"]:
                continue
            o, n = meta["offset"], meta["nbytes"]
            actual = zlib.crc32(mm[o : o + n])
            if actual != expected:
                self.crc_failures += 1
                raise SpillCorruptionError(
                    key, entry["file"], i, o, n, expected, actual
                )

    def recover(self, key: str) -> Pytree:
        """One re-fetch from the durable home: rewrite ``key`` through the
        registered ``recovery`` callback and return fresh verified views.

        Raises ``KeyError`` when no recovery source is registered — the
        caller's :class:`SpillCorruptionError` then stands, and the driver's
        restart loop (which restores the checkpoint, the *other* durable
        home) is the recovery path."""
        if self._recovery is None:
            raise KeyError(f"no recovery source registered for chunk {key!r}")
        tree = self._recovery(key)
        self.put(key, tree)
        self.recoveries += 1
        log.warning("spill chunk %r rewritten from its durable home", key)
        return self.get(key)

    # ------------------------------------------------------------- inspection
    def _entry(self, key: str) -> dict:
        try:
            return self._manifest[key]
        except KeyError:
            raise KeyError(f"no spilled chunk {key!r} in {self.dir}") from None

    def __contains__(self, key: str) -> bool:
        return key in self._manifest

    def keys(self) -> Iterator[str]:
        return iter(sorted(self._manifest))

    def nbytes(self, key: str) -> int:
        return sum(m["nbytes"] for m in self._entry(key)["leaves"])

    def total_bytes(self) -> int:
        return sum(self.nbytes(k) for k in self._manifest)

    # -------------------------------------------------------------- lifecycle
    def delete(self, key: str) -> None:
        entry = self._entry(key)
        with self._lock:
            del self._manifest[key]
            self._treedefs.pop(key, None)
            self._write_manifest()
        (self.dir / entry["file"]).unlink(missing_ok=True)

    def close(self, *, delete: Optional[bool] = None) -> None:
        """Forget in-memory state.  ``delete`` defaults to ``ephemeral``:
        run-private stores remove their directory (the driver's / offload's
        end-of-run cleanup), durable stores flush the manifest and keep
        their files."""
        self._treedefs.clear()
        if delete is None:
            delete = self.ephemeral
        if delete:
            shutil.rmtree(self.dir, ignore_errors=True)
        elif self.ephemeral:
            # kept alive explicitly: make the on-disk state self-describing
            self._write_manifest()

    def __enter__(self) -> "SpillStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:  # pragma: no cover - trivial
        return f"SpillStore({str(self.dir)!r}, chunks={len(self._manifest)})"

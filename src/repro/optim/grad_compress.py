"""Gradient compression for the cross-pod (DCN) all-reduce.

At 2+ pods the gradient all-reduce crosses data-center network, which is
~10-25x slower than ICI — compressing that traffic is a standard
distributed-optimization trick.  Two codecs:

  * ``bf16``  — cast f32 grads to bf16 for the reduce (2x), no state.
  * ``int8``  — per-leaf max-abs scaling to int8 (4x) with **error
    feedback**: the quantization residual is carried and added to the next
    step's gradient, which keeps SGD/Adam convergence (Karimireddy et al.).

Codecs are value-level (jit-compatible); the explicit cross-pod psum wiring
lives in the shard_map training variant.  Property tests check
``decode(encode(g)) + error == g`` exactly for the tracked residual.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


def compress_bf16(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def init_error_state(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_int8(
    grads: Pytree, error: Optional[Pytree] = None
) -> tuple[Pytree, Pytree, Pytree]:
    """Returns (int8 payload, scales, new error state)."""

    def leaf(g, e):
        gf = g.astype(jnp.float32) + (e if e is not None else 0.0)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        err = gf - q.astype(jnp.float32) * scale
        return q, scale, err

    if error is None:
        flat_e = [None] * len(jax.tree.leaves(grads))
    else:
        flat_e = jax.tree.leaves(error)
    flat_g, treedef = jax.tree.flatten(grads)
    qs, scales, errs = zip(*(leaf(g, e) for g, e in zip(flat_g, flat_e)))
    return (
        treedef.unflatten(list(qs)),
        treedef.unflatten(list(scales)),
        treedef.unflatten(list(errs)),
    )


def decompress_int8(payload: Pytree, scales: Pytree) -> Pytree:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, payload, scales
    )


def requantize_int8(
    payload: Pytree, scales: Pytree, target_scales: Pytree
) -> tuple[Pytree, Pytree]:
    """Rescale an int8 payload quantized at ``scales`` onto
    ``target_scales``.

    Returns ``(payload', extra_error)`` with the exact identity
    ``q * s == q' * t + extra_error`` per leaf, so the re-quantization
    residual can join the error-feedback state.  With ``t >= s`` (the
    cross-pod pmax) no value clips: ``|q * s| <= 127 s <= 127 t``.
    """

    def leaf(q, s, t):
        v = q.astype(jnp.float32) * s
        q2 = jnp.clip(jnp.round(v / t), -127, 127).astype(jnp.int8)
        return q2, v - q2.astype(jnp.float32) * t

    flat_q, treedef = jax.tree.flatten(payload)
    flat_s = jax.tree.leaves(scales)
    flat_t = jax.tree.leaves(target_scales)
    qs, errs = zip(*(leaf(q, s, t) for q, s, t in zip(flat_q, flat_s, flat_t)))
    return treedef.unflatten(list(qs)), treedef.unflatten(list(errs))


def pod_allreduce_int8(grads: Pytree, axis: str, error: Pytree) -> tuple[Pytree, Pytree]:
    """int8-compressed psum over ``axis`` (use under shard_map).

    All pods must agree on ONE quantization scale before integer payloads
    can be summed: the shared scale is the elementwise ``pmax`` of the
    per-pod scales, each pod re-quantizes its payload onto it, and the
    re-quantization residual joins the error-feedback state (the identity
    ``contribution + error == gradient`` is preserved exactly).  The sum
    happens in int32 (no overflow for <= 2^23 pods) and is rescaled by the
    shared scale.  Summing payloads quantized under *different* per-pod
    scales and rescaling by the max — the previous behaviour — inflates a
    small-scale pod's contribution by ``pmax / scale``, which for pods
    with very different gradient magnitudes is orders of magnitude.
    """
    q, scales, err = compress_int8(grads, error)
    pmax = jax.tree.map(lambda s: jax.lax.pmax(s, axis), scales)
    q, extra = requantize_int8(q, scales, pmax)
    err = jax.tree.map(jnp.add, err, extra)
    summed = jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.int32), axis), q
    )
    n = jax.lax.psum(1, axis)
    out = jax.tree.map(
        lambda si, s: si.astype(jnp.float32) * s / n, summed, pmax
    )
    return out, err

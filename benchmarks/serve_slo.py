"""SLO serving study: goodput under load + copy-on-write prefix sharing.

Two experiments over the paged serve session, both under the modeled
Epiphany-class link:

1. **Prefix sharing A/B** — a batch whose prompts share a page-aligned
   system prefix, served with COW prefix sharing ON vs OFF at the
   pinned-host and disk tiers.  Sharing aliases the shared cold pages
   under one content key, so the whole batch pays ONE fetch (and one spill
   chunk) per shared page per step instead of one per request.

2. **Open-loop SLO run** — a seeded Poisson trace (bursty phases, mixed
   prompt/output lengths, shared system prompt) through the admission-
   controlled scheduler on a deterministic virtual clock, reporting
   TTFT/TPOT percentiles, SLO attainment, goodput-under-SLO, and per-tier
   request counts.

Pass gates (the PR acceptance):

  * sharing ON decodes bitwise-identical tokens to sharing OFF,
  * sharing ON performs >= 2x fewer unique cold-page fetches — and, at
    the disk tier, >= 2x fewer disk requests — than the no-sharing
    baseline,
  * the SLO report carries goodput-under-SLO, TTFT/TPOT percentiles and
    per-tier request counts, and is bit-for-bit reproducible across two
    runs of the same seed (virtual clock).

Emits ``results/bench/BENCH_serve_slo.json``.  ``REPRO_BENCH_SMOKE=1``
(set by ``benchmarks/run.py --smoke``) shrinks the trace for CI.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import common as C
from repro.configs import get_smoke_config
from repro.core.engine import EngineConfig, LinkModel, TransferEngine
from repro.launch import serve as sv
from repro.launch.mesh import make_local_mesh
from repro.serve import SLO, LoadGenConfig, Phase, SLOScheduler, generate

SMOKE = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))

#: shared-system-prompt workload: 4 cohabiting slots whose prompts agree on
#: the first 32 tokens (4 pages of 8) with an 8-token private tail — the
#: cold set is dominated by the shared pages, which is the traffic shape
#: prefix sharing exists for
SLOTS = 4
PAGE_LEN = 8
SHARED_PREFIX = 32
PROMPT = 40
GEN = 8 if SMOKE else 16

HOST_LINK = LinkModel(request_s=0.3e-3, bandwidth_Bps=40e6, latency_s=0.0)
DISK_LINK = LinkModel(request_s=0.5e-3, bandwidth_Bps=40e6, latency_s=2e-3)


def _ab_row(kind: str, sharing: bool, res) -> dict:
    st = res["stats"]
    return {
        "kv_kind": kind,
        "prefix_sharing": sharing,
        "unique_group_fetches": st.unique_group_fetches,
        "disk_requests": st.disk_requests,
        "d2h_requests": st.d2h_requests,
        "shared_hits": st.shared_hits,
        "shared_skipped_writebacks": res["shared_skipped_writebacks"],
        "h2d_requests": st.h2d_requests,
        "n_groups": st.n_groups,
        "tokens_per_s": res["tokens_per_s"],
        "per_tier": st.per_tier(),
    }


def _sharing_ab(cfg, mesh) -> tuple[list, bool, bool]:
    rows, bitwise_ok, ratio_ok = [], True, True
    for kind in ("pinned_host", "disk_host"):
        gens = {}
        for sharing in (True, False):
            engine = TransferEngine(
                EngineConfig(link=HOST_LINK, disk_link=DISK_LINK)
            )
            try:
                res = sv.serve(
                    cfg,
                    mesh,
                    batch=SLOTS,
                    prompt_len=PROMPT,
                    gen=GEN,
                    kv_kind=kind,
                    kv_page_len=PAGE_LEN,
                    hot_pages=1,
                    seed=0,
                    shared_prefix_len=SHARED_PREFIX,
                    prefix_sharing=sharing,
                    engine=engine,
                    warmup=False,
                )
            finally:
                engine.close()
            gens[sharing] = res["generated"]
            rows.append(_ab_row(kind, sharing, res))
        on = next(r for r in rows if r["kv_kind"] == kind and r["prefix_sharing"])
        off = next(
            r for r in rows if r["kv_kind"] == kind and not r["prefix_sharing"]
        )
        bitwise_ok &= bool(np.array_equal(gens[True], gens[False]))
        # the acceptance gate: >= 2x fewer unique cold-page fetches, and
        # >= 2x fewer disk requests at the disk tier
        ratio_ok &= (
            on["unique_group_fetches"] * 2 <= off["unique_group_fetches"]
        )
        if kind == "disk_host":
            ratio_ok &= on["disk_requests"] * 2 <= off["disk_requests"]
    return rows, bitwise_ok, ratio_ok


def _slo_trace() -> LoadGenConfig:
    dur = 1.5 if SMOKE else 3.0
    return LoadGenConfig(
        seed=7,
        phases=(
            Phase(duration_s=dur, rate_rps=3.0),
            Phase(duration_s=dur / 3, rate_rps=10.0),
            Phase(duration_s=dur, rate_rps=3.0),
        ),
        prompt_lens=(12, 24, 40),
        prompt_mix=(0.4, 0.3, 0.3),
        gen_lens=(4, 8),
        gen_mix=(0.6, 0.4),
        shared_prefix_len=SHARED_PREFIX,
        shared_frac=0.75,
        vocab_size=256,
    )


def _slo_run(cfg, mesh) -> dict:
    engine = TransferEngine(EngineConfig(link=HOST_LINK, disk_link=DISK_LINK))
    try:
        with sv.ServeSession(
            cfg,
            mesh,
            slots=SLOTS,
            max_len=PROMPT + 16,
            kv_kind="disk_host",
            page_len=PAGE_LEN,
            hot_pages=1,
            seed=0,
            engine=engine,
        ) as session:
            sched = SLOScheduler(
                session,
                generate(_slo_trace()),
                slo=SLO(ttft_s=0.25, tpot_s=0.05),
                max_queue=16,
                virtual_step_s=0.01,
            )
            return sched.run()
    finally:
        engine.close()


def run(tag: str = "BENCH_serve_slo") -> list[dict]:
    cfg = get_smoke_config("smollm-360m")
    mesh = make_local_mesh()

    rows, bitwise_ok, ratio_ok = _sharing_ab(cfg, mesh)
    C.print_table(
        "COW prefix sharing A/B (shared 32-token system prompt)",
        rows,
        ["kv_kind", "prefix_sharing", "unique_group_fetches",
         "disk_requests", "d2h_requests", "shared_hits",
         "shared_skipped_writebacks"],
    )

    rep1 = _slo_run(cfg, mesh)
    rep2 = _slo_run(cfg, mesh)  # same seed, fresh session: must reproduce
    det_fields = (
        "offered", "submitted", "completed", "rejected_oversize",
        "rejected_overload", "emitted_tokens", "n_steps", "makespan_s",
        "slo_attainment", "goodput_rps", "goodput_tokens_per_s",
        "shared_hits", "unique_group_fetches", "disk_requests",
    )
    deterministic = all(rep1[f] == rep2[f] for f in det_fields) and (
        rep1["ttft_s"] == rep2["ttft_s"] and rep1["tpot_s"] == rep2["tpot_s"]
    )
    report_ok = (
        0.0 <= rep1["slo_attainment"] <= 1.0
        and rep1["goodput_tokens_per_s"] >= 0.0
        and {"h2d", "d2h", "disk"} <= set(rep1["per_tier"])
        and rep1["completed"] <= rep1["submitted"]
    )
    slo_row = {
        "kv_kind": "disk_host",
        "suite": "slo_loadgen",
        **{
            k: rep1[k]
            for k in det_fields + ("ttft_s", "tpot_s", "per_tier",
                                   "prefill_compiles",
                                   "shared_skipped_writebacks")
        },
        "deterministic": deterministic,
    }
    C.print_table(
        "open-loop SLO run (virtual clock, disk tier)",
        [slo_row],
        ["offered", "completed", "rejected_overload", "slo_attainment",
         "goodput_rps", "goodput_tokens_per_s", "n_steps",
         "prefill_compiles", "shared_hits", "deterministic"],
    )

    rows.append(slo_row)
    rows.append(
        {"suite": "gates", "bitwise_ok": bitwise_ok, "ratio_ok": ratio_ok,
         "report_ok": report_ok, "deterministic": deterministic}
    )
    C.save_rows(tag, rows)
    return rows


def main() -> int:
    rows = run()
    gates = rows[-1]
    by = {
        (r["kv_kind"], r["prefix_sharing"]): r
        for r in rows
        if "prefix_sharing" in r
    }
    disk_on = by[("disk_host", True)]
    disk_off = by[("disk_host", False)]
    ratio = disk_off["unique_group_fetches"] / max(
        1, disk_on["unique_group_fetches"]
    )
    print(
        f"sharing: {disk_on['unique_group_fetches']} vs "
        f"{disk_off['unique_group_fetches']} unique fetches "
        f"({ratio:.1f}x, gate >= 2x), "
        f"{disk_on['disk_requests']} vs {disk_off['disk_requests']} disk req; "
        f"bitwise={gates['bitwise_ok']}, report_ok={gates['report_ok']}, "
        f"deterministic={gates['deterministic']}"
    )
    ok = (
        gates["bitwise_ok"]
        and gates["ratio_ok"]
        and gates["report_ok"]
        and gates["deterministic"]
    )
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Host-driver streaming engine: the paper's host process + channels, in JAX.

The paper's architecture (§4, Fig 2) keeps bulk data on the host; a host-side
service decodes references and feeds per-core channels (32 x 1KB cells) while
device code computes.  This module is the direct analogue at framework level:
model state stays **outside the XLA program** as host arrays; a background
:class:`~repro.core.engine.TransferEngine` (the host service) coalesces,
stages and issues the H2D transfer for layer-group ``i+distance`` while the
jitted apply for group ``i`` runs.  Because transfers and compute are
separate dispatches, this engine runs on *every* backend — including the
CPU container, where it produces the real measurements behind
``benchmarks/offload_modes.py`` and ``benchmarks/engine_compare.py``
(``results/bench/BENCH_engine.json``; the graph engine in ``prefetch.py``
is the production TPU path).

Three transfer schedules, mirroring the paper's evaluation axes:

``eager``      copy *all* groups, then compute (paper's original offload).
``on_demand``  copy group i synchronously right before computing it
               (paper's pass-by-reference without prefetch — the 21-25x
               slowdown case when transfers are small).
``prefetch``   keep ``distance`` groups in flight ahead of compute.
               ``PrefetchSpec(distance="auto")`` lets the engine's
               :class:`~repro.core.engine.AdaptiveDistance` controller size
               the window from observed stalls.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Optional, Sequence

import jax

from repro.core.engine import AdaptiveDistance, EngineConfig, TransferEngine
from repro.core.refspec import Access, PrefetchSpec

__all__ = ["StreamStats", "HostStreamExecutor"]

Pytree = Any

#: histogram bucket upper bounds (seconds) for per-group transfer waits
_WAIT_BINS = (10e-6, 100e-6, 1e-3, 10e-3, 100e-3)

#: cap on retained per-group samples (waits, distance trace)
_MAX_SAMPLES = 4096


@dataclasses.dataclass
class StreamStats:
    """Per-run accounting (the paper's Table 2 instrumentation).

    ``n_transfers`` counts *logical* group transfers (one per group per
    direction — the seed's unit, kept for continuity); ``h2d_requests`` /
    ``d2h_requests`` count the *actual* requests issued on the link, which
    is what the paper's on-demand penalty scales with.  With coalescing a
    group is one request regardless of its leaf count.
    """

    mode: str = "prefetch"
    n_transfers: int = 0
    bytes_h2d: int = 0
    bytes_d2h: int = 0
    #: addressable devices groups staged onto (max over groups; 1 for
    #: default placement).  With sharding-aware coalescing a group costs
    #: one request per device, so ``requests_per_group == n_devices``
    n_devices: int = 1
    #: sum over groups of that group's device count — the denominator of
    #: the per-(device, group) request invariant, exact even when one run
    #: mixes sharded and default-placement groups
    n_device_groups: int = 0
    # -- residency accounting (the link-traffic truth) ----------------------
    #: submits that actually crossed a link (>= 1 H2D or disk request).
    #: ``requests_per_group`` is a per-PASS invariant and resets its
    #: denominator with every run, so a step whose forward AND backward each
    #: re-fetch every group still reads a clean 1.0/group — this counter is
    #: what benches gate real per-step traffic on instead
    unique_group_fetches: int = 0
    #: submits whose group was already device-resident end to end (weight
    #: residency-cache hits, and device-kind pass-through): zero link bytes
    cache_hits: int = 0
    #: submits that had to move bytes (always == unique_group_fetches; kept
    #: as its own counter so hit-rate reads don't conflate the two views)
    cache_misses: int = 0
    #: pops satisfied by a same-step fetch of the same *content* key — the
    #: copy-on-write prefix-sharing win: N requests whose prompts share a
    #: page-aligned prefix cost ONE fetch (one ``n_groups`` entry) plus
    #: N-1 shared hits, so ``h2d_requests == n_groups`` stays exact
    shared_hits: int = 0
    #: sum of per-group device counts over *fetched* groups only — the
    #: denominator that keeps the one-request-per-(device, group) coalescing
    #: invariant checkable when resident groups pass through at zero requests
    fetched_device_groups: int = 0
    transfer_wait_s: float = 0.0  # time the *compute* path blocked on data
    compute_s: float = 0.0
    total_s: float = 0.0
    # -- engine-era accounting ----------------------------------------------
    h2d_requests: int = 0
    d2h_requests: int = 0
    n_groups: int = 0
    n_runs: int = 0
    writeback_drain_s: float = 0.0
    #: max H2D payload bytes of groups simultaneously in flight (submitted
    #: but not yet consumed by their apply) — the schedule's device-residency
    #: model for streamed state; what ``--device-budget-mb`` gates against
    peak_inflight_bytes: int = 0
    # -- disk tier (DiskHost groups: stage-1 of the three-level pipeline) ---
    disk_requests: int = 0
    bytes_disk: int = 0
    #: time the *transfer worker* (stage 2) blocked on disk fetches; zero
    #: once the disk read-ahead window hides the disk latency
    disk_wait_s: float = 0.0
    # -- robustness (EngineConfig.max_attempts retry) -----------------------
    #: transient transfer faults absorbed by retry (H2D, D2H, disk stage);
    #: equals the injected fault count in the fault-injection benches
    retries: int = 0
    #: transfers that exhausted ``max_attempts`` (the error surfaced)
    give_ups: int = 0
    #: per-group compute-thread stall (the wait histogram's raw samples);
    #: bounded so a stats object shared across a long training run does not
    #: grow with step count — old samples age out, aggregates stay exact
    wait_per_group: "deque[float]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=_MAX_SAMPLES)
    )
    #: prefetch window size used for each group (adaptive-distance trace)
    distance_trace: "deque[int]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=_MAX_SAMPLES)
    )
    #: per-group stage-2-on-stage-1 (H2D-on-disk) stall samples
    disk_wait_per_group: "deque[float]" = dataclasses.field(
        default_factory=lambda: deque(maxlen=_MAX_SAMPLES)
    )

    @property
    def requests_per_group(self) -> float:
        return self.h2d_requests / self.n_groups if self.n_groups else 0.0

    @property
    def disk_requests_per_group(self) -> float:
        return self.disk_requests / self.n_groups if self.n_groups else 0.0

    def per_tier(self) -> dict[str, dict[str, float]]:
        """Request/byte/wait counters per hierarchy tier (paper Table 2,
        extended down the hierarchy).  The wait of each tier is the stall
        of the consumer one level up: compute stalls on host->device,
        host->device stalls on disk."""
        per_dev_groups = self.n_device_groups or self.n_groups
        return {
            "h2d": {
                "requests": self.h2d_requests,
                "bytes": self.bytes_h2d,
                "wait_s": self.transfer_wait_s,
                "requests_per_group": self.requests_per_group,
                # sharded groups cost one request per (device, group): 1.0
                # here is the coalescing invariant under any mesh
                "requests_per_device_group": (
                    self.h2d_requests / per_dev_groups if per_dev_groups else 0.0
                ),
                # the same invariant restricted to groups that actually
                # fetched — exactly 1.0 under coalescing no matter how many
                # resident groups passed through at zero requests
                "requests_per_fetched_device_group": (
                    self.h2d_requests / self.fetched_device_groups
                    if self.fetched_device_groups
                    else 0.0
                ),
                "unique_group_fetches": self.unique_group_fetches,
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "shared_hits": self.shared_hits,
            },
            "d2h": {
                "requests": self.d2h_requests,
                "bytes": self.bytes_d2h,
                "wait_s": self.writeback_drain_s,
            },
            "disk": {
                "requests": self.disk_requests,
                "bytes": self.bytes_disk,
                "wait_s": self.disk_wait_s,
                "requests_per_group": self.disk_requests_per_group,
            },
        }

    def wait_hist(self, bins: Sequence[float] = _WAIT_BINS) -> dict[str, int]:
        """Per-group wait histogram: bucket label -> count."""
        counts = [0] * (len(bins) + 1)
        for w in self.wait_per_group:
            for j, ub in enumerate(bins):
                if w <= ub:
                    counts[j] += 1
                    break
            else:
                counts[-1] += 1
        labels = [f"<={ub:.0e}s" for ub in bins] + [f">{bins[-1]:.0e}s"]
        return dict(zip(labels, counts))

    def reset(self) -> None:
        """Zero all counters (keeps ``mode``) — one benchmark repeat."""
        mode = self.mode
        fresh = StreamStats(mode=mode)
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(fresh, f.name))

    def as_row(self) -> dict[str, Any]:
        row = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name
            not in ("wait_per_group", "distance_trace", "disk_wait_per_group")
        }
        row["requests_per_group"] = self.requests_per_group
        row["disk_requests_per_group"] = self.disk_requests_per_group
        row["wait_hist"] = self.wait_hist()
        row["per_tier"] = self.per_tier()
        row["final_distance"] = self.distance_trace[-1] if self.distance_trace else None
        return row


def _nbytes(tree: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


class HostStreamExecutor:
    """Drives ``carry = apply(carry, group_params)`` over host-resident groups.

    Parameters
    ----------
    apply:
        jitted per-group function ``(carry, group) -> carry`` (or
        ``(carry, group) -> (carry, group_out)`` with ``writeback=True`` —
        the paper's ``rw`` access modifier, used e.g. for streamed optimizer
        state which must be copied back to its home kind).
    device_shardings:
        optional pytree of shardings for the staged groups, broadcast over
        every group.  Coalescing composes with explicit placements: each
        group stages through one buffer per addressable device (one H2D
        request per device per group) and the staged leaves are bitwise
        equal to eager sharded placement.  Per-run heterogeneous layouts
        (e.g. optimizer leaf groups) pass ``group_shardings`` to
        :meth:`run` instead.
    engine / engine_config:
        the transfer engine to run on.  By default a private engine with
        ``EngineConfig()`` (coalescing + async writeback) is created;
        pass ``EngineConfig(coalesce=False, async_writeback=False)`` to
        reproduce the seed executor's per-leaf blocking schedule.
    indexed:
        call ``apply(i, carry, group)`` with the group's position in the
        run — for heterogeneous group sequences whose apply dispatches per
        stage (the weight-streaming path: embed / layer groups / head are
        different jitted programs over one streamed sequence).
    """

    def __init__(
        self,
        apply: Callable[..., Any],
        *,
        writeback: bool = False,
        device_shardings: Optional[Pytree] = None,
        engine: Optional[TransferEngine] = None,
        engine_config: Optional[EngineConfig] = None,
        indexed: bool = False,
    ) -> None:
        self._apply = apply
        self._writeback = writeback
        self._indexed = indexed
        self._shardings = device_shardings
        self._engine = engine or TransferEngine(engine_config)
        self._owns_engine = engine is None
        #: adaptive-distance state, persistent across run() calls
        self._controller: Optional[AdaptiveDistance] = None

    @property
    def engine(self) -> TransferEngine:
        return self._engine

    def close(self) -> None:
        if self._owns_engine:
            self._engine.close()

    def __enter__(self) -> "HostStreamExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    #: sentinel: "no per-group override" (None is a valid override meaning
    #: default placement)
    _UNSET = object()

    # -- transfer primitive (the paper's channel cell write) ----------------
    def _submit(
        self, index: int, group: Pytree, shardings: Any = _UNSET, key=None
    ):
        if shardings is self._UNSET:
            shardings = self._shardings
        return self._engine.submit_group(
            index, group, device_shardings=shardings, key=key
        )

    def run(
        self,
        carry: Pytree,
        groups: Sequence[Pytree],
        *,
        prefetch: Optional[PrefetchSpec] = None,
        mode: str = "prefetch",
        stats: Optional[StreamStats] = None,
        group_shardings: Optional[Sequence[Pytree]] = None,
        group_keys: Optional[Sequence[Optional[str]]] = None,
    ) -> tuple[Pytree, Optional[list]]:
        """Execute all groups under the given schedule.  Returns the final
        carry (+ written-back host groups when ``writeback``).

        ``group_shardings``: optional per-group shardings (one pytree per
        group, aligned with ``groups``) for runs whose groups have
        heterogeneous layouts; overrides the constructor's broadcast
        ``device_shardings``.

        ``group_keys``: optional logical names (one per group, aligned
        with ``groups``) threaded to the engine's hazard sanitizer so
        fetches and writebacks of the same group form a happens-before
        chain across runs; unnamed groups are unchecked.

        A ``groups`` entry may be a zero-arg callable, resolved when its
        transfer is SUBMITTED (not when the run was scheduled): the weight
        streamer's residency-cache substitution must see the cache as it is
        the moment the fetch would be issued — a group that became resident
        mid-pass passes through by reference instead of re-crossing the
        link."""
        if mode not in ("eager", "on_demand", "prefetch"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "prefetch" and prefetch is None:
            prefetch = PrefetchSpec()
        st = stats if stats is not None else StreamStats()
        st.mode = mode
        st.n_runs += 1
        st.n_groups += len(groups)
        cfg = self._engine.config
        if self._writeback and cfg.async_writeback:
            # a failed previous run may have left tickets behind; stale
            # groups must never drain into this run's outputs
            self._engine.discard_writebacks()
        controller: Optional[AdaptiveDistance] = None
        if mode != "prefetch":
            distance = 0
        elif prefetch.is_auto:
            # the controller persists across run() calls: the train loop
            # issues one short run per step, and the learned window must
            # carry over instead of restarting at the minimum every step
            if self._controller is None:
                self._controller = AdaptiveDistance(
                    initial=cfg.min_distance,
                    min_distance=cfg.min_distance,
                    max_distance=cfg.max_distance,
                    wait_eps_s=cfg.wait_eps_s,
                    shrink_after=cfg.shrink_after,
                )
                # external signals (straggler events via engine.widen())
                # reach this window too
                self._engine.register_controller(self._controller)
            controller = self._controller
            distance = controller.distance
        else:
            distance = max(prefetch.distance, 1)
        t_start = time.perf_counter()

        outs: Optional[list] = [] if self._writeback else None
        n = len(groups)

        if group_shardings is not None and len(group_shardings) != n:
            raise ValueError(
                f"group_shardings has {len(group_shardings)} entries for "
                f"{n} groups"
            )
        if group_keys is not None and len(group_keys) != n:
            raise ValueError(
                f"group_keys has {len(group_keys)} entries for {n} groups"
            )

        #: H2D payload bytes of submitted-but-not-yet-consumed groups — the
        #: streamed-state device-residency model (peak gated by the weight
        #: streamer's --device-budget-mb)
        live_bytes = 0

        def submit(i: int):
            nonlocal live_bytes
            group = groups[i]() if callable(groups[i]) else groups[i]
            key = group_keys[i] if group_keys is not None else None
            if group_shardings is None:
                fut = self._submit(i, group, key=key)
            else:  # per-group override, authoritative (None = default)
                fut = self._submit(i, group, group_shardings[i], key=key)
            st.n_transfers += 1
            st.h2d_requests += fut.n_requests
            st.bytes_h2d += fut.nbytes
            st.disk_requests += fut.disk_requests
            st.bytes_disk += fut.disk_nbytes
            st.n_devices = max(st.n_devices, fut.n_devices)
            st.n_device_groups += fut.n_devices
            if fut.is_resident:  # zero link traffic: resident pass-through
                st.cache_hits += 1
            else:
                st.cache_misses += 1
                st.unique_group_fetches += 1
                st.fetched_device_groups += fut.n_devices
            live_bytes += fut.nbytes
            st.peak_inflight_bytes = max(st.peak_inflight_bytes, live_bytes)
            return fut

        #: writeback tickets issued this run (retry accounting at drain)
        wb_tickets: list = []

        def waited(fut) -> float:
            """fut.wait() plus retry/give-up accounting: absorbed transient
            faults land in ``st.retries``; a surfaced (permanent) fault
            counts one give-up and re-raises to the caller."""
            try:
                w = fut.wait()
            except BaseException:
                st.retries += fut.retries
                st.give_ups += 1
                raise
            st.retries += fut.retries
            return w

        if mode == "eager":
            # bulk transfer first — the paper's original kernel invocation
            futs = [submit(i) for i in range(n)]
            for fut in futs:
                w = waited(fut)
                st.transfer_wait_s += w
                st.wait_per_group.append(w)
                st.disk_wait_s += fut.disk_wait_s
                st.disk_wait_per_group.append(fut.disk_wait_s)
            t0 = time.perf_counter()
            for i, fut in enumerate(futs):
                carry = self._step(
                    i, carry, fut.group(), outs, st, wb_tickets,
                    wb_key=group_keys[i] if group_keys is not None else None,
                )
                live_bytes -= fut.nbytes
            jax.block_until_ready(carry)
            st.compute_s += time.perf_counter() - t0
        else:
            inflight: "OrderedDict[int, Any]" = OrderedDict()
            issued = 0
            for i in range(n):
                # top up the pipeline to `distance` groups ahead
                while issued <= min(i + distance, n - 1):
                    inflight[issued] = submit(issued)
                    issued += 1
                fut = inflight.pop(i)
                # the paper's blocking fetch: the core stalls until data
                # lands (zero once the window covers the link latency)
                w = waited(fut)
                st.transfer_wait_s += w
                st.wait_per_group.append(w)
                st.distance_trace.append(distance)
                st.disk_wait_s += fut.disk_wait_s
                st.disk_wait_per_group.append(fut.disk_wait_s)
                if controller is not None:
                    distance = controller.observe(w)
                t0 = time.perf_counter()
                carry = self._step(
                    i, carry, fut.group(), outs, st, wb_tickets,
                    wb_key=group_keys[i] if group_keys is not None else None,
                )
                live_bytes -= fut.nbytes
                st.compute_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            jax.block_until_ready(carry)
            st.compute_s += time.perf_counter() - t0

        if self._writeback and self._engine.config.async_writeback:
            t0 = time.perf_counter()
            try:
                outs = self._engine.drain_writebacks()
            except BaseException:
                st.retries += sum(t.retries for t in wb_tickets)
                st.give_ups += 1
                raise
            st.retries += sum(t.retries for t in wb_tickets)
            st.writeback_drain_s += time.perf_counter() - t0

        st.total_s = time.perf_counter() - t_start
        return (carry, outs) if self._writeback else (carry, None)

    def _step(
        self,
        index: int,
        carry: Pytree,
        buf: Pytree,
        outs: Optional[list],
        st: StreamStats,
        wb_tickets: Optional[list] = None,
        wb_key: Optional[str] = None,
    ) -> Pytree:
        apply = (
            (lambda c, b: self._apply(index, c, b)) if self._indexed else self._apply
        )
        if self._writeback:
            carry, group_out = apply(carry, buf)
            st.bytes_d2h += _nbytes(group_out)
            st.n_transfers += 1
            if self._engine.config.async_writeback:
                # pipelined writeback: D2H runs on the engine worker while
                # the next group computes; drained in order after the loop
                ticket = self._engine.submit_writeback(
                    len(outs), group_out, key=wb_key
                )
                st.d2h_requests += ticket.n_requests
                if wb_tickets is not None:
                    wb_tickets.append(ticket)
                outs.append(None)  # placeholder — replaced by drain
            else:
                host_out = jax.device_get(group_out)  # blocking (seed path)
                n_leaves = len(jax.tree.leaves(group_out))
                # the blocking copy occupies the same (possibly emulated)
                # link as the worker's transfers — and the compute thread
                self._engine.emulate_blocking_transfer(
                    n_leaves, _nbytes(group_out)
                )
                st.d2h_requests += n_leaves
                outs.append(host_out)
        else:
            carry = apply(carry, buf)
        return carry

from repro.parallel.sharding import (
    ShardingPlan,
    batch_spec,
    batch_specs,
    cache_specs_tree,
    make_plan,
    opt_state_specs,
    param_specs,
)

__all__ = [
    "ShardingPlan",
    "make_plan",
    "param_specs",
    "opt_state_specs",
    "batch_spec",
    "batch_specs",
    "cache_specs_tree",
]

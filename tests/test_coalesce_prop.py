"""Property-based coalescing round-trip tests (via the proptest grid shim).

``tests/test_engine.py`` covers hand-picked layouts; this suite sweeps the
pack -> device_put -> bitcast-unpack round trip over the property space the
engine actually sees in training: mixed dtypes (bf16, f32, i32,
f64-canonicalized), odd and zero-length shapes, deep pytrees, and
disk-tier (spill store) sources — asserting bitwise equality with the
per-leaf ``jax.device_put`` reference in every cell.

The sharded axis (``ShardedGroupLayout`` on a forced 2-device mesh) runs
the same property sweep in a subprocess: odd/unaligned shard byte-lengths
(JAX rejects non-divisible explicit shardings outright, so "uneven" means
shards whose sizes force unaligned offsets into the per-device staging
buffers), replicated/zero-length/scalar leaves, bf16/f64, deep pytrees —
asserting bitwise reassembly vs eager sharded placement and exact
per-device request accounting.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, settings, strategies as hst

from repro.core.engine import GroupLayout, TransferEngine
from repro.core.spillstore import SpillStore

#: dtype menu: extension (bf16), native, integer, and canonicalized-wide
_DTYPES = ["bfloat16", "float32", "int32", "float64"]


def _make_leaf(rng, n, dtype_name):
    a = rng.standard_normal((max(n, 0),))
    if dtype_name == "bfloat16":
        return np.asarray(jnp.asarray(a, jnp.bfloat16))
    if dtype_name in ("int32",):
        return (a * 100).astype(dtype_name)
    return a.astype(dtype_name)


def _roundtrip_equals_device_put(group):
    """pack -> H2D -> unpack must equal per-leaf device_put, bitwise."""
    leaves = jax.tree.leaves(group)
    layout = GroupLayout(group)
    staging = layout.new_staging()
    layout.pack_into(leaves, staging)
    flat = jax.device_put(staging)
    out = layout.unpack(flat, leaves)
    for got, src in zip(jax.tree.leaves(out), leaves):
        ref = jax.device_put(src)  # the canonicalizing per-leaf reference
        got, ref = np.asarray(got), np.asarray(ref)
        assert got.dtype == ref.dtype
        assert got.shape == ref.shape
        np.testing.assert_array_equal(got, ref)


@settings(max_examples=40, deadline=None)
@given(
    n=hst.integers(min_value=0, max_value=19),
    dtype_idx=hst.integers(min_value=0, max_value=len(_DTYPES) - 1),
)
def test_single_leaf_roundtrip(n, dtype_idx):
    """Every (length, dtype) cell — including zero-length and odd lengths
    that leave unaligned tails inside the 64B-padded staging buffer."""
    rng = np.random.default_rng(n * 31 + dtype_idx)
    _roundtrip_equals_device_put({"x": _make_leaf(rng, n, _DTYPES[dtype_idx])})


@settings(max_examples=30, deadline=None)
@given(
    depth=hst.integers(min_value=1, max_value=4),
    seed=hst.integers(min_value=0, max_value=3),
)
def test_deep_mixed_pytree_roundtrip(depth, seed):
    """Nested dict/tuple/list pytrees with one leaf of every dtype per
    level, lengths varying per level (incl. an empty leaf)."""
    rng = np.random.default_rng(seed)
    tree = {"empty": _make_leaf(rng, 0, "float32")}
    node = tree
    for lvl in range(depth):
        leaves = tuple(
            _make_leaf(rng, 2 * lvl + i + 1, dt) for i, dt in enumerate(_DTYPES)
        )
        node["child"] = {"leaves": leaves, "l": [leaves[0], leaves[-1]]}
        node = node["child"]
    _roundtrip_equals_device_put(tree)


@settings(max_examples=20, deadline=None)
@given(
    n=hst.integers(min_value=1, max_value=9),
    dtype_idx=hst.integers(min_value=0, max_value=len(_DTYPES) - 1),
)
def test_mixed_device_host_passthrough(n, dtype_idx):
    """Device-resident leaves interleaved with host leaves: the device
    leaves pass by reference, the host leaves round-trip bitwise."""
    rng = np.random.default_rng(n * 7 + dtype_idx)
    dev = jnp.arange(float(n))
    group = {
        "host": _make_leaf(rng, n, _DTYPES[dtype_idx]),
        "dev": dev,
        "host2": _make_leaf(rng, 2 * n + 1, "float32"),
    }
    leaves = jax.tree.leaves(group)
    layout = GroupLayout(group)
    staging = layout.new_staging()
    layout.pack_into(leaves, staging)
    out = layout.unpack(jax.device_put(staging), leaves)
    assert out["dev"] is dev
    np.testing.assert_array_equal(
        np.asarray(out["host"]), np.asarray(jax.device_put(group["host"]))
    )
    np.testing.assert_array_equal(np.asarray(out["host2"]), group["host2"])


@settings(max_examples=12, deadline=None)
@given(
    n=hst.integers(min_value=0, max_value=11),
    dtype_idx=hst.integers(min_value=0, max_value=len(_DTYPES) - 1),
)
def test_disk_tier_roundtrip_through_engine(n, dtype_idx, tmp_path_factory=None):
    """Full engine path for spill-store (DiskHost) groups: disk -> host
    staging -> pack -> device must equal device_put of the original."""
    import tempfile

    rng = np.random.default_rng(n * 13 + dtype_idx)
    group = {
        "a": _make_leaf(rng, n, _DTYPES[dtype_idx]),
        "b": _make_leaf(rng, n + 3, "float32"),
    }
    with tempfile.TemporaryDirectory() as d:
        store = SpillStore(d)
        store.put("g", group)
        disk_group = store.get("g")
        with TransferEngine() as eng:
            fut = eng.submit_group(0, disk_group)
            fut.wait()
            staged = fut.group()
        for got, src in zip(jax.tree.leaves(staged), jax.tree.leaves(group)):
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(jax.device_put(src))
            )


# ---------------------------------------------------------------------------
# sharded axis: ShardedGroupLayout property sweep on a forced 2-device mesh
# ---------------------------------------------------------------------------

_SHARDED_PROP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import itertools
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.engine import TransferEngine
from repro.core.spillstore import SpillStore
from repro.jaxcompat import make_mesh

assert len(jax.devices()) == 2, jax.devices()
mesh = make_mesh((1, 2), ("data", "model"))
SH = NamedSharding(mesh, P(None, "model"))
SH0 = NamedSharding(mesh, P("model"))
REP = NamedSharding(mesh, P())

DTYPES = ["bfloat16", "float32", "int32", "float64"]


def make_leaf(rng, shape, dtype_name):
    a = rng.standard_normal(shape) if shape else rng.standard_normal()
    a = np.asarray(a)
    if dtype_name == "bfloat16":
        return np.asarray(jnp.asarray(a, jnp.bfloat16))
    if dtype_name == "int32":
        return (a * 100).astype(np.int32)
    return a.astype(dtype_name)


def check(group, shardings, expect_devices=2):
    '''engine submit -> staged group must equal eager sharded placement
    bitwise, at exactly one request per (addressable device, group).'''
    eng = TransferEngine()
    try:
        fut = eng.submit_group(0, group, device_shardings=shardings)
        fut.wait()
        staged = fut.group()
        flat_g = jax.tree.leaves(group)
        flat_s = jax.tree.leaves(staged)
        flat_sh, _ = jax.tree.flatten(shardings, is_leaf=lambda s: s is None)
        any_host = any(not isinstance(x, jax.Array) for x in flat_g)
        # exact per-device request accounting: one coalesced request per
        # addressable device (zero when everything already device-resident)
        assert fut.n_requests == (expect_devices if any_host else 0), (
            fut.n_requests, expect_devices)
        for src, got, sh in zip(flat_g, flat_s, flat_sh):
            ref = jax.device_put(src, sh) if sh is not None else jax.device_put(src)
            assert got.dtype == ref.dtype, (got.dtype, ref.dtype)
            assert got.shape == ref.shape
            if sh is not None and not isinstance(src, jax.Array):
                assert got.sharding == ref.sharding, (got.sharding, ref.sharding)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    finally:
        eng.close()


rng = np.random.default_rng(0)

# cell 1: every dtype x shard-unfriendly shapes (odd shard byte-lengths that
# leave unaligned tails in the per-device staging buffer), plus replicated
# odd/zero-length/scalar leaves riding in the same group
for dt in DTYPES:
    for m in (1, 3, 5):  # sharded dim 2*m over 2 devices -> odd shards
        group = {
            "w": make_leaf(rng, (3, 2 * m), dt),      # (3, m) per device
            "v": make_leaf(rng, (2 * m,), dt),        # (m,) per device
            "rep_odd": make_leaf(rng, (7,), "float32"),
            "zero": make_leaf(rng, (0,), dt),
            "scalar": make_leaf(rng, (), dt),
        }
        shardings = {
            "w": SH, "v": SH0, "rep_odd": REP, "zero": REP, "scalar": REP,
        }
        check(group, shardings)

# cell 2: deep pytrees with mixed placement markers (None = default device)
for depth in (1, 2, 3):
    tree, shs = {}, {}
    node, shnode = tree, shs
    for lvl in range(depth):
        leaves = tuple(
            make_leaf(rng, (2, 2 * (lvl + 1) + 2), dt)
            for dt in DTYPES
        )
        node["child"] = {"leaves": leaves, "l": [leaves[0]]}
        shnode["child"] = {
            "leaves": tuple(SH if i % 2 == 0 else REP for i in range(len(DTYPES))),
            "l": [None],
        }
        node, shnode = node["child"], shnode["child"]
    tree["top"] = make_leaf(rng, (4,), "float32")
    shs["top"] = SH0
    check(tree, shs)

# cell 3: device-resident leaves pass through by reference in a sharded group
dev = jax.device_put(make_leaf(rng, (2, 4), "float32"), SH)
group = {"host": make_leaf(rng, (2, 4), "float32"), "dev": dev}
check(group, {"host": SH, "dev": SH})

# cell 4: all-device group costs zero requests
check({"a": dev}, {"a": SH})

# cell 5: disk-tier (spill store) leaves ride the same sharded path
with tempfile.TemporaryDirectory() as d:
    store = SpillStore(d)
    for dt in ("bfloat16", "float64"):
        src = {"w": make_leaf(rng, (2, 6), dt), "b": make_leaf(rng, (7,), "float32")}
        store.put(f"g-{dt}", src)
        disk = store.get(f"g-{dt}")
        eng = TransferEngine()
        try:
            fut = eng.submit_group(0, disk, device_shardings={"w": SH, "b": REP})
            fut.wait()
            staged = fut.group()
            assert fut.n_requests == 2 and fut.disk_requests == 1, (
                fut.n_requests, fut.disk_requests)
            for k, sh in (("w", SH), ("b", REP)):
                ref = jax.device_put(src[k], sh)
                np.testing.assert_array_equal(
                    np.asarray(staged[k]), np.asarray(ref))
        finally:
            eng.close()

print("SHARDED_PROP_OK")
"""


@pytest.mark.slow
def test_sharded_layout_property_sweep_2way_mesh():
    """ShardedGroupLayout over the property space: odd/unaligned shard
    lengths, bf16/f64 canonicalization, zero-length and scalar leaves, deep
    pytrees, device pass-through, and disk-tier sources — bitwise vs eager
    sharded placement with exact per-device request accounting."""
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_PROP_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_PROP_OK" in proc.stdout

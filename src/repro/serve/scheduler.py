"""Admission-controlled SLO scheduler over a :class:`ServeSession`.

The serving loop treats device residency the way the paper's host-side
scheduler treats Epiphany SRAM: a shared, contended resource.  Offered
requests (an arrival-stamped trace from :mod:`repro.serve.loadgen`) flow
through a bounded admission queue into the session's continuous batch;
anything beyond the queue bound is shed (``rejected_overload``), anything
that cannot ever fit is rejected by the session itself
(``rejected_oversize``), and every completed request is scored against its
latency SLOs:

``TTFT``  time from arrival to the first emitted token (prompt queueing +
          prefill), and
``TPOT``  mean time per output token after the first (decode cadence).

**Goodput under SLO** — the headline metric — counts only requests that met
*both* targets: ``goodput_rps`` (SLO-attaining requests per second of
makespan) and ``goodput_tokens_per_s`` (their tokens).  Throughput that
arrives too late to be useful does not count; this is the difference
between a server that is fast and a server that is merely busy.

Two clocks: ``virtual_step_s`` advances time a fixed amount per decode
step (fully deterministic — what the tests and bench gates run), or wall
clock (``virtual_step_s=None``) for real measurements.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

__all__ = ["SLO", "SLOScheduler"]


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency targets."""

    ttft_s: float = 0.5
    tpot_s: float = 0.1


@dataclasses.dataclass
class _Tracked:
    """Per-admitted-request latency bookkeeping."""

    arrival_s: float
    shared: bool
    first_emit_s: Optional[float] = None
    last_emit_s: Optional[float] = None
    n_emitted: int = 0


class SLOScheduler:
    """Drives one session over one offered trace; collects the SLO report.

    ``max_queue`` bounds the admission queue (arrived-but-not-admitted
    requests); arrivals beyond it are shed instead of growing an unbounded
    backlog — open-loop overload must degrade goodput, not crash the
    server.
    """

    def __init__(
        self,
        session,
        offered,
        *,
        slo: Optional[SLO] = None,
        max_queue: int = 32,
        virtual_step_s: Optional[float] = 0.01,
    ) -> None:
        self.session = session
        self.offered = sorted(offered, key=lambda o: o.arrival_s)
        self.slo = slo or SLO()
        self.max_queue = max_queue
        self.virtual_step_s = virtual_step_s
        self.rejected_overload = 0
        self.tracked: dict[int, _Tracked] = {}

    def run(self) -> dict[str, Any]:
        session = self.session
        virtual = self.virtual_step_s is not None
        t0 = time.perf_counter()
        now = 0.0
        i = 0  # next offered arrival
        n = len(self.offered)

        def record(emitted: dict, at: float) -> None:
            for rid, _tok in emitted.items():
                tr = self.tracked.get(rid)
                if tr is None:
                    continue
                if tr.first_emit_s is None:
                    tr.first_emit_s = at
                tr.last_emit_s = at
                tr.n_emitted += 1

        while True:
            if not virtual:
                now = time.perf_counter() - t0
            # arrivals up to the current clock enter the admission queue;
            # the queue bound is the admission-control knob — overflow is
            # shed, not buffered forever
            while i < n and self.offered[i].arrival_s <= now:
                o = self.offered[i]
                i += 1
                if len(session.queue) >= self.max_queue:
                    self.rejected_overload += 1
                    continue
                rid = session.submit(o.prompt, o.gen)
                if rid is None:  # oversize: counted by session.rejected
                    continue
                self.tracked[rid] = _Tracked(
                    arrival_s=o.arrival_s, shared=o.shared
                )
            if session.pending_work():
                record(session.step(), now + (self.virtual_step_s or 0.0))
                if virtual:
                    now += self.virtual_step_s
            elif i < n:
                # idle: jump the clock to the next arrival (virtual) or
                # spin the wall clock forward
                if virtual:
                    now = self.offered[i].arrival_s
                else:
                    now = time.perf_counter() - t0
                    if now < self.offered[i].arrival_s:
                        time.sleep(
                            min(self.offered[i].arrival_s - now, 0.01)
                        )
            else:
                break
        makespan = now if virtual else time.perf_counter() - t0
        return self.report(makespan)

    # -- scoring ------------------------------------------------------------
    def _latencies(self) -> tuple[list, list, list]:
        """(ttft, tpot, met) over completed requests, rid order."""
        ttfts, tpots, met = [], [], []
        for rid, tr in sorted(self.tracked.items()):
            if tr.first_emit_s is None:
                continue
            ttft = tr.first_emit_s - tr.arrival_s
            if tr.n_emitted > 1:
                tpot = (tr.last_emit_s - tr.first_emit_s) / (tr.n_emitted - 1)
            else:
                tpot = 0.0
            ttfts.append(ttft)
            tpots.append(tpot)
            met.append(ttft <= self.slo.ttft_s and tpot <= self.slo.tpot_s)
        return ttfts, tpots, met

    @staticmethod
    def _pcts(xs: list) -> dict[str, float]:
        if not xs:
            return {"p50": 0.0, "p90": 0.0, "p99": 0.0}
        a = np.asarray(xs, np.float64)
        return {
            "p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)),
            "p99": float(np.percentile(a, 99)),
        }

    def report(self, makespan_s: float) -> dict[str, Any]:
        session = self.session
        ttfts, tpots, met = self._latencies()
        good = [
            tr
            for ok, (rid, tr) in zip(met, sorted(self.tracked.items()))
            if ok
        ]
        good_tokens = sum(tr.n_emitted for tr in good)
        completed = len(ttfts)
        return {
            "offered": len(self.offered),
            "submitted": len(self.tracked),
            "completed": completed,
            "rejected_oversize": session.rejected,
            "rejected_overload": self.rejected_overload,
            "shared_offered": sum(
                1 for tr in self.tracked.values() if tr.shared
            ),
            "makespan_s": makespan_s,
            "ttft_s": self._pcts(ttfts),
            "tpot_s": self._pcts(tpots),
            "slo": dataclasses.asdict(self.slo),
            "slo_attainment": (sum(met) / completed) if completed else 0.0,
            "goodput_rps": (len(good) / makespan_s) if makespan_s else 0.0,
            "goodput_tokens_per_s": (
                good_tokens / makespan_s if makespan_s else 0.0
            ),
            "emitted_tokens": sum(
                tr.n_emitted for tr in self.tracked.values()
            ),
            "n_steps": session.n_steps,
            "prefill_compiles": session.prefill_compiles(),
            "shared_hits": session.stats.shared_hits,
            "shared_skipped_writebacks": (
                session.pager.shared_skipped_writebacks
            ),
            "unique_group_fetches": session.stats.unique_group_fetches,
            "disk_requests": session.stats.disk_requests,
            "per_tier": session.stats.per_tier(),
        }

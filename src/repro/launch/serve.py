"""Paged hierarchical KV-cache serving: continuous batching over memory kinds.

The serving counterpart of the streamed optimizer: each request's KV cache
is split into fixed-size page groups (``repro.core.kvpager``) and only the
hot attention window stays device-resident.  Cold pages live at the kind
named by ``--kv-kind`` (``device`` | ``pinned_host`` | ``disk_host``) and
are fetched ahead of the decode step by the
:class:`~repro.core.engine.TransferEngine` — coalesced (one H2D request per
page group), prefetched under a per-request adaptive window
(``distance="auto"``), written back through the pipelined D2H drain when
they fall out of the hot window.  The decode step consumes the assembled
page view **by reference** — the same executable as the unpaged step, so
where the cache lives never changes what is decoded (bitwise).

:class:`ServeSession` is the engine room: a request queue with continuous
batching — requests are admitted into free batch slots and evicted/retired
between decode steps, each with its own prompt length (pad-free: prefill is
per-request) and its own page table.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \\
      --batch 4 --prompt-len 32 --gen 16 --kv-kind pinned_host --kv-page-len 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core import memkind as mk
from repro.core.engine import TransferEngine
from repro.core.hoststream import StreamStats
from repro.core.kvpager import (
    KVPager,
    KVPagerConfig,
    page_template,
    paged_cache_supported,
    shared_prefix_keys,
)
from repro.core.refspec import AUTO
from repro.core.residency import ResidencyCache
from repro.core.spillstore import SpillStore
from repro.launch.mesh import make_local_mesh
from repro.parallel import sharding as sh
from repro.train import steps as st

KV_KINDS = ("device", "pinned_host", "disk_host")


def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def _schedule_note(plan, *, distance, cache_capacity, budget_mb, kv,
                   route_experts) -> str:
    """Best-effort analyzer occupancy report appended to budget errors, so
    a rejected flag combination names the program points that overrun
    instead of just the closed-form floor."""
    try:
        from repro.core import schedcheck as sc

        report = sc.analyze_serve_schedule(
            plan,
            distance=distance,
            cache_capacity=cache_capacity,
            budget_bytes=(
                int(budget_mb * 1e6) if budget_mb is not None else None
            ),
            kv=kv,
            route_experts=route_experts,
        )
        return "\n" + str(report)
    except Exception:
        return ""


def _prompt_batch(cfg, tokens) -> dict:
    """(B, S) prompt ids -> the model's batch dict (codebook archs replicate
    the ids over codebooks, as the seed serve loop did)."""
    tokens = jnp.asarray(tokens, jnp.int32)
    if cfg.n_codebooks:
        b, s = tokens.shape
        return {
            "codes": jnp.broadcast_to(tokens[:, None], (b, cfg.n_codebooks, s))
        }
    return {"tokens": tokens}


def _step_batch(cfg, tok: np.ndarray) -> dict:
    """Per-slot next tokens — (B,) or (B, n_codebooks) — to a one-token
    decode batch dict."""
    if cfg.n_codebooks:
        return {"codes": jnp.asarray(tok).reshape(-1, cfg.n_codebooks, 1)}
    return {"tokens": jnp.asarray(tok).reshape(-1, 1)}


def _emit(cfg, tok) -> int:
    """The emitted stream token (codebook archs report codebook 0)."""
    return int(tok[0]) if cfg.n_codebooks else int(tok)


@dataclasses.dataclass
class Request:
    """One generation request: prompt in, ``gen`` greedy tokens out."""

    rid: int
    prompt: np.ndarray  # (s,) int32
    gen: int
    #: last sampled token — scalar, or (n_codebooks,) for audio archs —
    #: the next decode step's input
    next_token: Optional[np.ndarray] = None
    emitted: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.emitted) >= self.gen


class ServeSession:
    """Continuous-batching decode loop over a paged hierarchical KV cache.

    ``slots`` batch lanes decode in lock-step (one jitted step, per-slot
    positions); requests flow through them: ``submit`` queues work,
    admissions fill free slots between steps (per-request prefill — no
    cross-request prompt padding), finished requests retire and their slot
    is immediately reused.  ``evict``/``readmit`` park a request's pages at
    the host mid-decode and resume it later — decoding continues
    bitwise-identically because pages are reconstructed exactly.
    """

    def __init__(
        self,
        cfg,
        mesh,
        *,
        slots: int,
        max_len: int,
        kv_kind: str = "device",
        page_len: int = 32,
        hot_pages: int = 1,
        distance=AUTO,
        seed: int = 0,
        engine: Optional[TransferEngine] = None,
        spill_dir: Optional[str] = None,
        stats: Optional[StreamStats] = None,
        param_kind: str = "device",
        device_budget_mb: Optional[float] = None,
        param_layers_per_group: Optional[int] = None,
        param_distance=AUTO,
        param_cache_mb: Optional[float] = None,
        expert_stream: bool = False,
        route_experts: bool = True,
        prefix_sharing: bool = True,
    ) -> None:
        self.cfg = cfg
        self.mesh = mesh
        self.slots = slots
        self.stats = stats if stats is not None else StreamStats()
        self.stats.mode = "paged"
        #: transfer accounting of the *weight* stream (separate from the KV
        #: page stream so each tier's request model stays checkable)
        self.param_stats = StreamStats()
        self._kind = mk.as_kind(kv_kind)
        # validate — and do every fallible init — before allocating the
        # engine thread / spill dir: a failed constructor must not leak
        # resources (KVPagerConfig validates its knobs in __post_init__)
        pager_cfg = KVPagerConfig(
            page_len=page_len,
            hot_pages=hot_pages,
            kind=self._kind,
            distance=distance,
        )
        self.max_len = _round_up(max_len, page_len)
        template = st.abstract_caches(cfg, 1, self.max_len)
        if not paged_cache_supported(template):
            raise ValueError(
                f"{cfg.name}: cache tree is not pageable (ring/recurrent "
                "state) — use the unpaged serve path (kv_page_len=0)"
            )
        # streamed weights: plan before any resource allocation (plan
        # construction validates the budget and can raise)
        self._wplan = None
        engine_cfg = None
        if expert_stream and param_kind == "device":
            raise ValueError(
                "--expert-stream streams routed experts from a weight home; "
                "it requires --param-kind pinned_host or disk_host"
            )
        #: expert-group fetch accounting (route-aware MoE streaming) —
        #: separate from ``param_stats`` so the bench can gate routed vs
        #: all-expert link traffic directly
        self.expert_stats: Optional[StreamStats] = None
        #: weight-residency group cache — keeps fetched weight groups
        #: device-resident across prefill/decode steps (serve params are
        #: immutable, so entries are never invalidated, only LRU-evicted)
        self.param_residency: Optional[ResidencyCache] = None
        #: static analyzer report for the streamed-weight + KV page schedule
        #: (:func:`repro.core.schedcheck.analyze_serve_schedule`); ``None``
        #: for device-resident weights
        self.schedule_report = None
        if param_kind != "device":
            from repro.core.engine import EngineConfig
            from repro.core.weightstream import (
                PARAM_KINDS,
                WeightStreamPlan,
                weight_stream_support,
            )

            if param_kind not in PARAM_KINDS:
                raise ValueError(
                    f"unknown param_kind {param_kind!r}; expected one of "
                    f"{PARAM_KINDS}"
                )
            support = weight_stream_support(cfg)
            if not support.serve_supported:
                raise ValueError(
                    f"--param-kind {param_kind}: "
                    f"{support.serve_reason or support.reason}"
                )
            budget = device_budget_mb
            # per-(slot,page) device bytes — the hot-window reservation unit
            # and the analyzer's KV occupancy baseline
            page_nbytes = sum(
                int(np.prod(s.shape)) * s.dtype.itemsize
                for s in jax.tree.leaves(page_template(template, page_len))
            )
            kv_desc = dict(
                slots=slots,
                page_len=page_len,
                hot_pages=hot_pages,
                page_nbytes=page_nbytes,
                max_len=self.max_len,
            )
            if budget is not None:
                # the device budget is shared: the pager's hot window (the
                # current page + hot_pages full pages + the shared zero
                # page, per slot) takes its cut first, weights stream under
                # the remainder
                hot_mb = slots * (hot_pages + 2) * page_nbytes / 1e6
                budget = budget - hot_mb
                if budget <= 0:
                    probe = WeightStreamPlan(
                        cfg,
                        st.abstract_params(cfg),
                        layers_per_group=param_layers_per_group,
                        device_budget_mb=None,
                        expert_stream=expert_stream,
                    )
                    raise ValueError(
                        f"device_budget_mb={device_budget_mb} is consumed by "
                        f"the KV hot window ({hot_mb:.1f} MB); raise the "
                        "budget or shrink hot_pages/page_len"
                        + _schedule_note(
                            probe,
                            distance=1,
                            cache_capacity=0,
                            budget_mb=device_budget_mb,
                            kv=kv_desc,
                            route_experts=route_experts,
                        )
                    )
            self._wplan = WeightStreamPlan(
                cfg,
                st.abstract_params(cfg),
                layers_per_group=param_layers_per_group,
                device_budget_mb=budget,
                expert_stream=expert_stream,
            )
            # weight-residency cache capacity: default = the budget slack
            # above the widest prefetch window (None budget = unbounded);
            # an explicit --param-cache-mb instead RESERVES that many bytes,
            # narrowing the window — which must still fit at distance 1
            if param_cache_mb is None:
                cache_cap = self._wplan.residency_capacity_bytes()
            else:
                cache_cap = int(param_cache_mb * 1e6)
                floor = self._wplan.peak_device_bytes(1, cached_bytes=cache_cap)
                if budget is not None and floor > budget * 1e6:
                    hot_mb = (device_budget_mb or 0) - budget
                    raise ValueError(
                        f"device_budget_mb={device_budget_mb} cannot hold the "
                        f"KV hot window ({hot_mb:.1f} MB) + the distance-1 "
                        f"weight stream floor "
                        f"({self._wplan.peak_device_bytes(1) / 1e6:.1f} MB) + "
                        f"param_cache_mb={param_cache_mb}; raise the budget, "
                        "shrink hot_pages/page_len/param_layers_per_group, or "
                        "lower param_cache_mb"
                        + _schedule_note(
                            self._wplan,
                            distance=1,
                            cache_capacity=cache_cap,
                            budget_mb=device_budget_mb,
                            kv=kv_desc,
                            route_experts=route_experts,
                        )
                    )
            cache_reserved = (
                (cache_cap or 0) if budget is not None else 0
            )
            self.param_residency = ResidencyCache(cache_cap)
            engine_cfg = EngineConfig(
                max_distance=self._wplan.max_distance_for_budget(
                    cached_bytes=cache_reserved
                )
            )
            if engine is not None and (
                budget is not None
                and engine.config.max_distance
                > self._wplan.max_distance_for_budget(
                    cached_bytes=cache_reserved
                )
            ):
                # an external engine must respect the budget's window cap or
                # the adaptive controller can stream past the budget
                raise ValueError(
                    f"external engine's max_distance="
                    f"{engine.config.max_distance} exceeds the device "
                    f"budget's cap "
                    f"{self._wplan.max_distance_for_budget(cached_bytes=cache_reserved)} "
                    "(window + residency cache share the budget); "
                    "pass an engine configured from the plan (or no engine)"
                )
            # static schedule verification: replay the fetch program the
            # session is about to run (prefill walk, router-first decode,
            # KV page demote/readmit) and refuse construction on any
            # occupancy overrun or transfer hazard (core/schedcheck)
            from repro.core.schedcheck import (
                analyze_serve_schedule,
                verify_schedule,
            )

            self.schedule_report = analyze_serve_schedule(
                self._wplan,
                distance=(
                    engine.config.max_distance
                    if engine is not None
                    else engine_cfg.max_distance
                ),
                cache_capacity=cache_cap,
                budget_bytes=(
                    int(device_budget_mb * 1e6)
                    if device_budget_mb is not None
                    else None
                ),
                kv=kv_desc,
                route_experts=route_experts,
            )
            verify_schedule(self.schedule_report)
        self.plan = sh.make_plan(mesh, mode="serve")
        key = jax.random.PRNGKey(seed)
        if self._wplan is not None:
            # group-wise init: the full param tree is never device-resident
            # (the point of streaming arbitrarily large models); homes are
            # built BEFORE the engine thread exists so a failed spill
            # cannot leak a worker
            self.sharder = sh.make_sharder(
                self.plan, st.abstract_params(cfg), slots
            )
            home = st.init_weight_streamed_params(key, cfg, self._wplan)
            self._param_store = None
            if param_kind == "disk_host":
                import tempfile

                pd = (
                    str(Path(spill_dir) / "params")
                    if spill_dir is not None and self._kind == mk.DISK_HOST
                    else tempfile.mkdtemp(prefix="repro-serve-wp-")
                )
                self._param_store = SpillStore(pd, ephemeral=True)
                try:
                    home = self._wplan.spill_home(home, self._param_store)
                except BaseException:
                    # no-leak contract: a failed spill (full disk) must not
                    # orphan the ephemeral chunk directory
                    self._param_store.close()
                    raise
            self.params = home
        else:
            self.params = st.init_train_state(key, cfg)[0]
            self.sharder = sh.make_sharder(self.plan, self.params, slots)
            self._param_store = None

        self._engine = engine or TransferEngine(engine_cfg)
        self._owns_engine = engine is None
        self._store = None
        try:
            if self._kind == mk.DISK_HOST:
                ephemeral = spill_dir is None
                if ephemeral:
                    import tempfile

                    spill_dir = tempfile.mkdtemp(prefix="repro-serve-kv-")
                self._store = SpillStore(spill_dir, ephemeral=ephemeral)

            # cold pages stage at the serve plan's cache specs (derived on
            # the *page* shape so divisibility fallbacks see what actually
            # moves): under --model-parallel a fetched page group costs one
            # coalesced H2D request per device, not one per leaf
            page_specs = sh.cache_specs_tree(
                self.plan, page_template(template, page_len), 1
            )
            self.pager = KVPager(
                template,
                pager_cfg,
                slots=slots,
                engine=self._engine,
                store=self._store,
                device_shardings=sh.named_shardings(mesh, page_specs),
            )
            if self._wplan is not None:
                # stream the homed weights per prefill / decode step; the
                # decode executables consume the groups by reference, so
                # where the weights live never changes the tokens
                p_sh = None
                if mesh.devices.size > 1:
                    p_specs = sh.param_specs(self.plan, st.abstract_params(cfg))
                    p_sh = sh.named_shardings(mesh, p_specs)
                from repro.core.refspec import PrefetchSpec

                w_dist = (
                    param_distance if param_distance == AUTO else int(param_distance)
                )
                param_pf = PrefetchSpec(
                    buffer_size=self._wplan.n_groups + 2, distance=w_dist
                )
                self._prefill = st.make_weight_streamed_prefill_step(
                    cfg, self._wplan, 1, self.max_len, mesh, self.sharder,
                    engine=self._engine, stats=self.param_stats,
                    param_shardings=p_sh, prefetch=param_pf,
                    residency=self.param_residency,
                )
                self._step = st.make_weight_streamed_decode_step(
                    cfg, self._wplan, mesh, self.sharder,
                    engine=self._engine, stats=self.param_stats,
                    param_shardings=p_sh, paged=True, prefetch=param_pf,
                    residency=self.param_residency,
                    route_experts=route_experts,
                )
                self.expert_stats = getattr(self._step, "expert_stats", None)
            else:
                self._prefill = jax.jit(
                    st.make_prefill_step(cfg, 1, self.max_len, mesh, self.sharder)
                )
                self._step = st.make_paged_decode_step(cfg, mesh, self.sharder)
            self._argmax = jax.jit(
                lambda logits: jnp.argmax(logits[..., -1, :], axis=-1).astype(
                    jnp.int32
                )
            )
        except BaseException:
            # the constructor's no-leak contract: anything that fails after
            # the engine thread / spill dirs exist tears them down
            self.close()
            raise

        self.requests: dict[int, Request] = {}
        self.queue: "deque[int]" = deque()
        self._slot_of: dict[int, int] = {}  # rid -> slot
        self._next_rid = 0
        self.n_steps = 0
        #: COW prefix sharing: admit pages under content-digest keys so
        #: requests with a common page-aligned prompt prefix alias one cold
        #: copy (no-op for device-resident caches — nothing is ever cold)
        self._prefix_sharing = prefix_sharing and self._kind != mk.DEVICE
        #: requests rejected at submit (prompt + gen > max_len) — under
        #: open-loop load an oversized request must not kill the session
        self.rejected = 0
        #: readmits that found the batch full, drained (ahead of new
        #: admissions) by the next admission cycle
        self._readmit_queue: "deque[int]" = deque()
        #: per-step compute-blocked transfer wait (steady-state metric)
        self.step_waits: list = []
        #: per-step UNIQUE weight-group fetches (H2D link traffic, not
        #: resident pass-throughs) — the residency gate: with cache slack
        #: this decays to 0 at steady state instead of n_groups every step
        self.param_step_fetches: list = []

    def _tok_shape(self) -> tuple:
        cb = self.cfg.n_codebooks
        return (self.slots, cb) if cb else (self.slots,)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, prompt, gen: int) -> Optional[int]:
        """Queue a request; returns its id.  Admitted at the next step (or
        immediately via :meth:`admit_pending`).

        A request that cannot fit (``prompt + gen > max_len``) is rejected
        gracefully — ``None`` is returned and ``self.rejected`` counts it —
        instead of raising mid-run (under open-loop load one oversized
        prompt must not kill the whole session)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + gen > self.max_len:
            self.rejected += 1
            return None
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(rid=rid, prompt=prompt, gen=gen)
        self.queue.append(rid)
        return rid

    def _bucket_len(self, s: int) -> int:
        """Power-of-two prompt-length bucket (min 8, capped at ``max_len``):
        prefill compiles once per bucket instead of once per distinct
        prompt length.  Bucketing is bitwise-invisible — the pad tail's
        garbage K/V lands beyond the write position (masked by every
        decode step's causal ``pos`` mask until overwritten, or dropped
        with the ``_ZERO`` pages) and the head reads the last *real*
        position via ``last_pos``."""
        b = 8
        while b < s:
            b *= 2
        return min(b, self.max_len)

    def _prefix_keys(self, req: Request) -> Optional[list]:
        if not self._prefix_sharing:
            return None
        return shared_prefix_keys(req.prompt, self.pager.config.page_len)

    def _free_slots(self) -> list:
        return [s for s in range(self.slots) if s not in self.pager._by_slot]

    def admit_pending(self) -> dict:
        """Prefill queued requests into free slots.  Returns ``{rid:
        first_token}`` (the prompt's greedy continuation — emitted at
        admission, before any decode step)."""
        emitted = {}
        for slot in self._free_slots():
            # queued readmits resume first: they were promised a slot
            # before any not-yet-admitted submission existed
            if self._readmit_queue:
                rid = self._readmit_queue.popleft()
                self.pager.readmit(rid, slot)
                self._slot_of[rid] = slot
                continue
            if not self.queue:
                break
            rid = self.queue.popleft()
            req = self.requests[rid]
            s = len(req.prompt)
            width = self._bucket_len(s)
            padded = np.zeros((width,), np.int32)
            padded[:s] = req.prompt
            logits, cache = self._prefill(
                self.params,
                _prompt_batch(self.cfg, padded[None, :]),
                jnp.asarray(s - 1, jnp.int32),
            )
            tok = np.asarray(self._argmax(logits))[0]  # scalar / (n_codebooks,)
            req.next_token = tok
            req.emitted.append(_emit(self.cfg, tok))
            emitted[rid] = req.emitted[-1]
            self._slot_of[rid] = slot
            self.pager.admit(
                rid, slot, cache, s, prefix_keys=self._prefix_keys(req)
            )
            if req.done:  # gen == 1: nothing left to decode
                self._retire(rid)
        self.pager.flush_demotions(self.stats)
        self.pager.prefetch()
        return emitted

    def _retire(self, rid: int) -> None:
        self._slot_of.pop(rid, None)
        self.pager.retire(rid, self.stats)

    def evict(self, rid: int) -> None:
        """Park a mid-decode request at the host and free its slot."""
        self.pager.evict(rid, self.stats)
        self._slot_of.pop(rid, None)

    def readmit(self, rid: int) -> bool:
        """Resume an evicted request in a free slot (pages stream back in
        cold over the following steps).  When the batch is full the
        readmit is QUEUED for the next admission cycle — ahead of new
        submissions — instead of crashing the session mid-run; returns
        True when a slot was taken now, False when queued."""
        table = self.pager.tables.get(rid)
        if table is None:
            raise KeyError(f"unknown request {rid}")
        if table.slot is not None:
            raise ValueError(f"request {rid} is not evicted")
        if rid in self._readmit_queue:
            return False
        free = self._free_slots()
        if not free:
            self._readmit_queue.append(rid)
            return False
        slot = free[0]
        self.pager.readmit(rid, slot)
        self._slot_of[rid] = slot
        return True

    @property
    def active(self) -> dict:
        """rid -> slot of requests currently decoding."""
        return dict(self._slot_of)

    def pending_work(self) -> bool:
        return bool(self.queue or self._slot_of or self._readmit_queue)

    def prefill_compiles(self) -> Optional[int]:
        """Compiled prefill variant count (bucketed prompt widths); None
        for the streamed-weight prefill (a composite, not one jit)."""
        cache_size = getattr(self._prefill, "_cache_size", None)
        return cache_size() if cache_size is not None else None

    # -- the decode loop -----------------------------------------------------
    def warmup(self) -> None:
        """Compile every per-step executable against a throwaway all-zero
        view (no table/stream state is touched), so the first timed step
        does not pay compile time (cf. ``benchmarks/common.timed``)."""
        view = tuple(
            (self.pager._zero_page,) * self.pager.n_pages for _ in range(self.slots)
        )
        tokens = np.zeros(self._tok_shape(), np.int32)
        pos = jnp.zeros((self.slots,), jnp.int32)
        logits, nc = self._step(
            self.params, view, _step_batch(self.cfg, tokens), pos
        )
        self._argmax(logits)
        out = self.pager._extract(
            nc, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)
        )
        jax.block_until_ready(out)

    def step(self) -> dict:
        """One decode step over every active slot.  Returns ``{rid: token}``
        for tokens emitted this step (including first tokens of requests
        admitted at the end of the step)."""
        if not self._slot_of and (self.queue or self._readmit_queue):
            return self.admit_pending()
        wait0 = self.stats.transfer_wait_s
        fetch0 = self.param_stats.unique_group_fetches

        tokens = np.zeros(self._tok_shape(), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        by_slot = {}
        for rid, slot in self._slot_of.items():
            req = self.requests[rid]
            tokens[slot] = req.next_token
            pos[slot] = self.pager.tables[rid].pos
            by_slot[slot] = req

        # pop this step's cold pages (waits only where the window fell
        # short), then speculatively prefetch the same cold set for the
        # next step — those transfers overlap the decode compute below
        view = self.pager.view(self.stats)
        self.pager.prefetch()
        logits, new_cache = self._step(
            self.params, view, _step_batch(self.cfg, tokens), jnp.asarray(pos)
        )
        nxt = np.asarray(self._argmax(logits))  # blocks on the decode compute
        self.pager.update_current(new_cache)

        emitted = {}
        for slot, req in by_slot.items():
            req.next_token = nxt[slot]
            req.emitted.append(_emit(self.cfg, nxt[slot]))
            emitted[req.rid] = req.emitted[-1]
            table = self.pager.tables[req.rid]
            table.pos += 1
            self.pager.advance(table)
        self.pager.flush_demotions(self.stats)
        for req in list(by_slot.values()):
            if req.done:
                self._retire(req.rid)
        self.n_steps += 1
        self.step_waits.append(self.stats.transfer_wait_s - wait0)
        self.param_step_fetches.append(
            self.param_stats.unique_group_fetches - fetch0
        )
        emitted.update(self.admit_pending())
        return emitted

    def run(self) -> dict:
        """Drive steps until every submitted request has finished.  Returns
        ``{rid: np.ndarray of emitted tokens}``."""
        self.admit_pending()
        while self.pending_work():
            self.step()
        return {
            rid: np.asarray(req.emitted, np.int32)
            for rid, req in self.requests.items()
        }

    def close(self) -> None:
        if self._owns_engine:
            self._engine.close()
        if self._store is not None:
            self._store.close()
        if self._param_store is not None:
            self._param_store.close()
        if self.param_residency is not None:
            self.param_residency.clear()  # release resident device copies

    def __enter__(self) -> "ServeSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# unpaged reference path (per-step whole-cache placement)
# ---------------------------------------------------------------------------


def _serve_unpaged(
    cfg,
    mesh,
    *,
    batch: int,
    prompt_len: int,
    gen: int,
    kv_kind: str,
    seed: int,
    engine: Optional[TransferEngine],
    warmup: bool,
    stats: StreamStats,
    param_kind: str = "device",
    device_budget_mb: Optional[float] = None,
    param_layers_per_group: Optional[int] = None,
    param_distance=AUTO,
    param_cache_mb: Optional[float] = None,
    expert_stream: bool = False,
    route_experts: bool = True,
    spill_dir: Optional[str] = None,
):
    """The pre-pager schedule, kept as the A/B baseline: host-resident
    caches round-trip through host memory synchronously on every decode
    step.  Fixed (debugged) version: placement uses the sharding plan's
    cache specs (a bare ``PartitionSpec()`` silently dropped the plan under
    model parallelism), and the cache is only donated when it is
    device-resident (donating a cache the host branch then re-places trips
    deleted-buffer errors).

    Pageable (full-attention) caches prefill per request and decode with
    per-slot positions — the same executables as the paged session, so the
    two paths are bitwise-comparable.  Ring/recurrent caches (``slot_pos``
    is shared across the batch) keep the seed's lock-step schedule: one
    batched prefill, one scalar position.

    ``param_kind`` homes the weights off-device and streams them through
    the group-program executables (``paged=False`` decode) — the route for
    archs whose KV cache is NOT pageable (SWA rings like mixtral) but whose
    weights should still stream; ``expert_stream`` fetches only the routed
    experts per decode step.
    """
    plan = sh.make_plan(mesh, mode="serve")
    key = jax.random.PRNGKey(seed)
    kind = mk.as_kind(kv_kind)
    if kind == mk.DISK_HOST:
        raise ValueError("the unpaged path has no disk home; use --kv-page-len > 0")
    device_resident = kind.jax_kind == "device"

    max_len = prompt_len + gen
    vector_pos = paged_cache_supported(st.abstract_caches(cfg, 1, max_len))

    wplan = None
    param_stats = StreamStats()
    expert_stats = None
    residency = None
    param_store = None
    own_engine = None
    #: KV round-trip emulation stays tied to the CALLER's engine — an
    #: engine created here for the weight stream must not add synthetic
    #: stalls to the cache path
    kv_engine = engine
    if param_kind != "device":
        from repro.core.engine import EngineConfig
        from repro.core.weightstream import (
            PARAM_KINDS,
            WeightStreamPlan,
            weight_stream_support,
        )

        if param_kind not in PARAM_KINDS:
            raise ValueError(
                f"unknown param_kind {param_kind!r}; expected one of "
                f"{PARAM_KINDS}"
            )
        support = weight_stream_support(cfg)
        if not support.serve_supported:
            raise ValueError(
                f"--param-kind {param_kind}: "
                f"{support.serve_reason or support.reason}"
            )
        wplan = WeightStreamPlan(
            cfg,
            st.abstract_params(cfg),
            layers_per_group=param_layers_per_group,
            device_budget_mb=device_budget_mb,
            expert_stream=expert_stream,
        )
        cache_cap = (
            wplan.residency_capacity_bytes()
            if param_cache_mb is None
            else int(param_cache_mb * 1e6)
        )
        residency = ResidencyCache(cache_cap)
        if engine is None:
            engine = own_engine = TransferEngine(
                EngineConfig(max_distance=wplan.max_distance_for_budget())
            )
        # static schedule verification (same contract as ServeSession):
        # refuse to serve a fetch program that can overrun the budget or
        # re-fetch through a pending writeback
        from repro.core.schedcheck import (
            analyze_serve_schedule,
            verify_schedule,
        )

        verify_schedule(
            analyze_serve_schedule(
                wplan,
                distance=engine.config.max_distance,
                cache_capacity=cache_cap,
                route_experts=route_experts,
                fan_in=(
                    max(1, getattr(cfg, "moe_top_k", 2)) * batch
                    if route_experts
                    else None
                ),
            )
        )
        sharder = sh.make_sharder(plan, st.abstract_params(cfg), batch)
        params = st.init_weight_streamed_params(key, cfg, wplan)
        if param_kind == "disk_host":
            import tempfile

            pd = (
                str(Path(spill_dir) / "params")
                if spill_dir is not None
                else tempfile.mkdtemp(prefix="repro-serve-wp-")
            )
            param_store = SpillStore(pd, ephemeral=True)
            try:
                params = wplan.spill_home(params, param_store)
            except BaseException:
                param_store.close()
                raise
    else:
        if expert_stream:
            raise ValueError(
                "--expert-stream streams routed experts from a weight home; "
                "it requires --param-kind pinned_host or disk_host"
            )
        params = st.init_train_state(key, cfg)[0]
        sharder = sh.make_sharder(plan, params, batch)

    if wplan is not None:
        p_sh = None
        if mesh.devices.size > 1:
            p_specs = sh.param_specs(plan, st.abstract_params(cfg))
            p_sh = sh.named_shardings(mesh, p_specs)
        from repro.core.refspec import PrefetchSpec

        w_dist = (
            param_distance if param_distance == AUTO else int(param_distance)
        )
        param_pf = PrefetchSpec(
            buffer_size=wplan.n_groups + 2, distance=w_dist
        )
        decode_fn = st.make_weight_streamed_decode_step(
            cfg, wplan, mesh, sharder, engine=engine, stats=param_stats,
            param_shardings=p_sh, paged=False, prefetch=param_pf,
            residency=residency, route_experts=route_experts,
        )
        expert_stats = getattr(decode_fn, "expert_stats", None)
    else:
        # donation is only safe when the cache stays on device: the host
        # branch re-reads the pre-step tree to place it (satellite bugfix)
        decode_fn = jax.jit(
            st.make_decode_step(cfg, mesh, sharder),
            donate_argnums=(1,) if device_resident else (),
        )
    argmax_fn = jax.jit(
        lambda logits: jnp.argmax(logits[..., -1, :], axis=-1).astype(jnp.int32)
    )

    key_t = jax.random.PRNGKey(seed + 1)
    prompts = np.asarray(
        jax.random.randint(key_t, (batch, prompt_len), 1, cfg.vocab_size), np.int32
    )

    t0 = time.perf_counter()
    if vector_pos:
        if wplan is not None:
            prefill_fn = st.make_weight_streamed_prefill_step(
                cfg, wplan, 1, max_len, mesh, sharder, engine=engine,
                stats=param_stats, param_shardings=p_sh, prefetch=param_pf,
                residency=residency,
            )
        else:
            prefill_fn = jax.jit(
                st.make_prefill_step(cfg, 1, max_len, mesh, sharder)
            )
        stack_fn = jax.jit(
            lambda slots: jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=xs[0].ndim - 4), *slots
            )
        )
        slot_caches, first = [], []
        for b in range(batch):
            logits, cache = prefill_fn(params, _prompt_batch(cfg, prompts[b][None]))
            first.append(np.asarray(argmax_fn(logits))[0])
            slot_caches.append(cache)
        caches = stack_fn(tuple(slot_caches))
        tokens = np.stack(first)
    else:
        # ring/recurrent decode state: batched lock-step prefill (per-slot
        # positions cannot address a shared ring)
        if wplan is not None:
            prefill_fn = st.make_weight_streamed_prefill_step(
                cfg, wplan, batch, max_len, mesh, sharder, engine=engine,
                stats=param_stats, param_shardings=p_sh, prefetch=param_pf,
                residency=residency,
            )
        else:
            prefill_fn = jax.jit(
                st.make_prefill_step(cfg, batch, max_len, mesh, sharder)
            )
        logits, caches = prefill_fn(params, _prompt_batch(cfg, prompts))
        tokens = np.asarray(argmax_fn(logits))
    jax.block_until_ready(caches)
    t_prefill = time.perf_counter() - t0

    # the sharding plan's cache placement (satellite bugfix: was a bare
    # replicated PartitionSpec that dropped the plan under --model-parallel)
    specs = sh.cache_specs_tree(plan, caches, batch)
    cache_leaves = len(jax.tree.leaves(caches))
    cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))

    def round_trip(c):
        t0 = time.perf_counter()
        c = mk.place(c, mesh, specs, kind)
        jax.block_until_ready(c)
        if kv_engine is not None:
            kv_engine.emulate_blocking_transfer(cache_leaves, cache_bytes)
        c = mk.place(c, mesh, specs, mk.DEVICE)
        jax.block_until_ready(c)
        if kv_engine is not None:
            kv_engine.emulate_blocking_transfer(cache_leaves, cache_bytes)
        w = time.perf_counter() - t0
        stats.n_transfers += 2
        stats.n_groups += 1
        stats.h2d_requests += cache_leaves
        stats.d2h_requests += cache_leaves
        stats.bytes_h2d += cache_bytes
        stats.bytes_d2h += cache_bytes
        stats.transfer_wait_s += w
        stats.wait_per_group.append(w)
        return c

    def emitted_of(tok_b):
        return tok_b[:, 0] if cfg.n_codebooks else tok_b

    out_tokens = [emitted_of(tokens)]

    def step_pos(i: int):
        if vector_pos:
            return jnp.asarray(np.full((batch,), prompt_len + i, np.int32))
        return jnp.asarray(prompt_len + i, jnp.int32)  # lock-step scalar

    if warmup:
        # compile the decode step against a throwaway copy so t_decode does
        # not include compile time (satellite bugfix; cf. benchmarks.common)
        caches_w = jax.tree.map(jnp.copy, caches)
        jax.block_until_ready(
            decode_fn(params, caches_w, _step_batch(cfg, tokens), step_pos(0))[0]
        )

    step_waits = []
    # decode-loop-only expert traffic (warmup's routed fetches excluded) —
    # what the bench's routed-vs-all-expert gate divides by gen-1 steps
    eb0 = expert_stats.bytes_h2d if expert_stats is not None else 0
    ef0 = expert_stats.unique_group_fetches if expert_stats is not None else 0
    t0 = time.perf_counter()
    try:
        for i in range(gen - 1):
            w0 = stats.transfer_wait_s
            if not device_resident:
                # the paper's Host kind, pre-pager: the ENTIRE cache
                # round-trips through host memory synchronously every step
                caches = round_trip(caches)
            logits, caches = decode_fn(
                params, caches, _step_batch(cfg, tokens), step_pos(i)
            )
            tokens = np.asarray(argmax_fn(logits))
            out_tokens.append(emitted_of(tokens))
            step_waits.append(stats.transfer_wait_s - w0)
        t_decode = time.perf_counter() - t0
    finally:
        if own_engine is not None:
            own_engine.close()
        if param_store is not None:
            param_store.close()
        if residency is not None:
            residency.clear()

    generated = np.stack(out_tokens, axis=1)
    return {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        # gen-1 decode steps: the first token per slot comes from prefill
        "tokens_per_s": batch * (gen - 1) / t_decode if t_decode else float("inf"),
        "generated": generated,
        "step_waits": step_waits,
        "stats": stats,
        "paged": False,
        "n_steps": gen - 1,
        "param_stats": param_stats,
        "expert_stats": expert_stats,
        "param_plan": wplan,
        "expert_decode_bytes": (
            expert_stats.bytes_h2d - eb0 if expert_stats is not None else 0
        ),
        "expert_decode_fetches": (
            expert_stats.unique_group_fetches - ef0
            if expert_stats is not None
            else 0
        ),
    }


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def serve(
    cfg,
    mesh,
    *,
    batch: int,
    prompt_len: int,
    gen: int,
    kv_kind: str = "device",
    kv_page_len: int = 32,
    hot_pages: int = 1,
    distance=AUTO,
    seed: int = 0,
    n_requests: Optional[int] = None,
    engine: Optional[TransferEngine] = None,
    spill_dir: Optional[str] = None,
    warmup: bool = True,
    param_kind: str = "device",
    device_budget_mb: Optional[float] = None,
    param_layers_per_group: Optional[int] = None,
    param_distance=AUTO,
    param_cache_mb: Optional[float] = None,
    expert_stream: bool = False,
    route_experts: bool = True,
    prefix_sharing: bool = True,
    shared_prefix_len: int = 0,
):
    """Serve ``n_requests`` greedy-decode requests (default: one per batch
    slot) of ``prompt_len`` prompt tokens and ``gen`` generated tokens.
    ``shared_prefix_len`` makes the first that many prompt tokens identical
    across requests (the shared-system-prompt traffic shape);
    ``prefix_sharing`` lets the pager alias those pages copy-on-write.

    ``kv_page_len > 0`` routes decode through the paged
    :class:`ServeSession`; ``kv_page_len=0`` runs the unpaged reference
    schedule (synchronous whole-cache placement per step for host kinds).
    ``param_kind`` homes the *weights* off-device and streams them
    group-wise per prefill/decode step (paged and unpaged sessions).
    ``expert_stream`` splits MoE experts into their own fetch groups and
    decodes router-first, fetching only the routed experts per step
    (``route_experts=False`` keeps the split program but fetches all E —
    the bench's all-expert baseline).
    Returns timing, per-request generated tokens (``(n_requests, gen)``),
    the :class:`StreamStats` row, and pager residency accounting.
    """
    stats = StreamStats()
    n_requests = n_requests or batch
    if kv_page_len <= 0:
        if n_requests != batch:
            raise ValueError("the unpaged path serves exactly one request per slot")
        return _serve_unpaged(
            cfg,
            mesh,
            batch=batch,
            prompt_len=prompt_len,
            gen=gen,
            kv_kind=kv_kind,
            seed=seed,
            engine=engine,
            warmup=warmup,
            stats=stats,
            param_kind=param_kind,
            device_budget_mb=device_budget_mb,
            param_layers_per_group=param_layers_per_group,
            param_distance=param_distance,
            param_cache_mb=param_cache_mb,
            expert_stream=expert_stream,
            route_experts=route_experts,
            spill_dir=spill_dir,
        )

    key_t = jax.random.PRNGKey(seed + 1)
    prompts = np.array(
        jax.random.randint(key_t, (n_requests, prompt_len), 1, cfg.vocab_size),
        np.int32,
    )
    if shared_prefix_len:
        shared = min(shared_prefix_len, prompt_len)
        prompts[:, :shared] = prompts[0, :shared]
    with ServeSession(
        cfg,
        mesh,
        slots=batch,
        max_len=prompt_len + gen,
        kv_kind=kv_kind,
        page_len=kv_page_len,
        hot_pages=hot_pages,
        distance=distance,
        seed=seed,
        engine=engine,
        spill_dir=spill_dir,
        stats=stats,
        param_kind=param_kind,
        device_budget_mb=device_budget_mb,
        param_layers_per_group=param_layers_per_group,
        param_distance=param_distance,
        param_cache_mb=param_cache_mb,
        expert_stream=expert_stream,
        route_experts=route_experts,
        prefix_sharing=prefix_sharing,
    ) as session:
        rids = [session.submit(prompts[i], gen) for i in range(n_requests)]
        if warmup:
            session.warmup()
        t0 = time.perf_counter()
        admitted_first = session.admit_pending()
        t_prefill = time.perf_counter() - t0
        t0 = time.perf_counter()
        while session.pending_work():
            session.step()
        t_decode = time.perf_counter() - t0
        generated = np.stack(
            [np.asarray(session.requests[r].emitted, np.int32) for r in rids]
        )
        total_tokens = int(sum(len(session.requests[r].emitted) for r in rids))
        # first tokens of the initial admissions were emitted during the
        # prefill window, not the decode window — don't count them against
        # t_decode
        decode_tokens = total_tokens - len(admitted_first)
        res = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_per_s": decode_tokens / t_decode if t_decode else float("inf"),
            "generated": generated,
            "step_waits": list(session.step_waits),
            "stats": stats,
            "paged": True,
            "n_steps": session.n_steps,
            "stale_drops": session.pager.stream.stale_drops,
            "rejected": session.rejected,
            "prefill_compiles": session.prefill_compiles(),
            "shared_hits": stats.shared_hits,
            "shared_skipped_writebacks": (
                session.pager.shared_skipped_writebacks
            ),
            "demoted_groups": session.pager.demoted_groups,
            "peak_resident_bytes": session.pager.peak_resident_bytes,
            "total_cache_bytes": session.pager.total_cache_bytes(),
            "param_stats": session.param_stats,
            "expert_stats": session.expert_stats,
            "param_plan": session._wplan,
            "param_step_fetches": list(session.param_step_fetches),
            "param_residency": (
                session.param_residency.counters()
                if session.param_residency is not None
                else None
            ),
        }
        return res


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests to serve (default: one per slot)")
    ap.add_argument("--kv-kind", default="device", choices=KV_KINDS)
    ap.add_argument("--kv-page-len", type=int, default=32,
                    help="tokens per KV page (0 = unpaged reference path)")
    ap.add_argument("--hot-pages", type=int, default=1,
                    help="full pages kept device-resident behind the write head")
    ap.add_argument("--distance", default="auto",
                    help="page prefetch window: an int or 'auto'")
    ap.add_argument("--spill-dir", default=None,
                    help="disk_host page store directory (default: ephemeral)")
    from repro.core.weightstream import PARAM_KINDS

    ap.add_argument("--param-kind", default="device", choices=PARAM_KINDS,
                    help="home tier of the model weights (host/disk kinds "
                    "stream them layer-group-wise per prefill/decode step)")
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="device budget shared by the KV hot window and the "
                    "streamed weight window")
    ap.add_argument("--param-cache-mb", type=float, default=None,
                    help="weight-residency cache capacity (default: the "
                    "budget slack above the prefetch window; unbounded "
                    "without a budget; 0 disables)")
    ap.add_argument("--expert-stream", action="store_true",
                    help="split MoE experts into per-expert fetch groups "
                    "and fetch only the routed top-k per decode step "
                    "(requires a streamed --param-kind and an MoE arch)")
    ap.add_argument("--verify-schedule", action="store_true",
                    help="statically analyze the streamed-weight + KV page "
                    "schedule before serving, print the occupancy report, "
                    "and fail fast on any hazard (requires a streamed "
                    "--param-kind)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable copy-on-write sharing of page-aligned "
                    "prompt prefixes (the A/B baseline; sharing is "
                    "bitwise-invisible either way)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="make the first N prompt tokens identical across "
                    "requests (the shared-system-prompt traffic shape)")
    # -- open-loop load generator + SLO scheduler ---------------------------
    ap.add_argument("--loadgen", action="store_true",
                    help="serve an open-loop Poisson trace through the SLO "
                    "scheduler instead of the fixed request list")
    ap.add_argument("--lg-seed", type=int, default=0)
    ap.add_argument("--lg-phases", default="4:2,1:8,4:2",
                    help="arrival phases as 'duration_s:rate_rps,...' "
                    "(bursty by default)")
    ap.add_argument("--lg-prompt-lens", default="8,24,48",
                    help="prompt-length mixture support (comma ints)")
    ap.add_argument("--lg-gen-lens", default="4,8,16",
                    help="output-length mixture support (comma ints)")
    ap.add_argument("--lg-shared-frac", type=float, default=1.0,
                    help="fraction of offered requests starting with the "
                    "shared system prompt (--shared-prefix-len)")
    ap.add_argument("--slo-ttft-ms", type=float, default=500.0,
                    help="time-to-first-token SLO target")
    ap.add_argument("--slo-tpot-ms", type=float, default=100.0,
                    help="per-output-token SLO target")
    ap.add_argument("--max-queue", type=int, default=32,
                    help="admission queue bound; arrivals beyond it are "
                    "shed (rejected_overload)")
    ap.add_argument("--virtual-step-ms", type=float, default=10.0,
                    help="virtual clock advance per decode step (0 = wall "
                    "clock)")
    args = ap.parse_args()

    if args.verify_schedule and args.param_kind == "device":
        ap.error("--verify-schedule requires --param-kind pinned_host "
                 "or disk_host (device-resident weights have no schedule)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(model=args.model_parallel)
    distance = args.distance if args.distance == AUTO else int(args.distance)
    if args.verify_schedule:
        from repro.core import schedcheck as sc
        from repro.core.weightstream import WeightStreamPlan

        budget = args.device_budget_mb
        kv_desc = None
        if args.kv_page_len > 0:
            max_len = _round_up(
                args.prompt_len + args.gen, args.kv_page_len
            )
            template = st.abstract_caches(cfg, 1, max_len)
            if paged_cache_supported(template):
                page_nbytes = sum(
                    int(np.prod(s.shape)) * s.dtype.itemsize
                    for s in jax.tree.leaves(
                        page_template(template, args.kv_page_len)
                    )
                )
                kv_desc = dict(
                    slots=args.batch,
                    page_len=args.kv_page_len,
                    hot_pages=args.hot_pages,
                    page_nbytes=page_nbytes,
                    max_len=max_len,
                )
                if budget is not None:
                    budget -= (
                        args.batch * (args.hot_pages + 2) * page_nbytes / 1e6
                    )
        wplan = WeightStreamPlan(
            cfg,
            st.abstract_params(cfg),
            device_budget_mb=budget,
            expert_stream=args.expert_stream,
        )
        if args.param_cache_mb is not None:
            cache_cap = int(args.param_cache_mb * 1e6)
        else:
            cache_cap = wplan.residency_capacity_bytes()
        cache_reserved = (cache_cap or 0) if budget is not None else 0
        report = sc.analyze_serve_schedule(
            wplan,
            distance=wplan.max_distance_for_budget(
                cached_bytes=cache_reserved
            ),
            cache_capacity=cache_cap,
            budget_bytes=(
                int(args.device_budget_mb * 1e6)
                if args.device_budget_mb is not None
                else None
            ),
            kv=kv_desc,
        )
        print(report)
        sc.verify_schedule(report)
    if args.loadgen:
        if args.kv_page_len <= 0:
            ap.error("--loadgen drives the paged ServeSession; "
                     "use --kv-page-len > 0")
        from repro.serve import (
            SLO,
            LoadGenConfig,
            Phase,
            SLOScheduler,
            generate,
        )

        phases = tuple(
            Phase(duration_s=float(d), rate_rps=float(r))
            for d, r in (p.split(":") for p in args.lg_phases.split(","))
        )
        prompt_lens = tuple(
            int(x) for x in args.lg_prompt_lens.split(",")
        )
        gen_lens = tuple(int(x) for x in args.lg_gen_lens.split(","))
        lg_cfg = LoadGenConfig(
            seed=args.lg_seed,
            phases=phases,
            prompt_lens=prompt_lens,
            prompt_mix=(1.0,) * len(prompt_lens),
            gen_lens=gen_lens,
            gen_mix=(1.0,) * len(gen_lens),
            shared_prefix_len=args.shared_prefix_len,
            shared_frac=args.lg_shared_frac,
            vocab_size=cfg.vocab_size,
        )
        offered = generate(lg_cfg)
        with ServeSession(
            cfg,
            mesh,
            slots=args.batch,
            max_len=args.prompt_len + args.gen,
            kv_kind=args.kv_kind,
            page_len=args.kv_page_len,
            hot_pages=args.hot_pages,
            distance=distance,
            seed=args.seed,
            spill_dir=args.spill_dir,
            param_kind=args.param_kind,
            device_budget_mb=args.device_budget_mb,
            param_cache_mb=args.param_cache_mb,
            expert_stream=args.expert_stream,
            prefix_sharing=not args.no_prefix_sharing,
        ) as session:
            sched = SLOScheduler(
                session,
                offered,
                slo=SLO(
                    ttft_s=args.slo_ttft_ms / 1e3,
                    tpot_s=args.slo_tpot_ms / 1e3,
                ),
                max_queue=args.max_queue,
                virtual_step_s=(
                    args.virtual_step_ms / 1e3
                    if args.virtual_step_ms > 0
                    else None
                ),
            )
            rep = sched.run()
        print(
            f"loadgen {args.arch}: offered {rep['offered']}, completed "
            f"{rep['completed']} ({rep['rejected_oversize']} oversize, "
            f"{rep['rejected_overload']} overload) over "
            f"{rep['makespan_s']:.2f} s"
        )
        print(
            f"SLO: attainment {rep['slo_attainment']*100:.1f}%, goodput "
            f"{rep['goodput_rps']:.2f} req/s / "
            f"{rep['goodput_tokens_per_s']:.1f} tok/s under SLO, TTFT p50 "
            f"{rep['ttft_s']['p50']*1e3:.1f} ms p99 "
            f"{rep['ttft_s']['p99']*1e3:.1f} ms, TPOT p50 "
            f"{rep['tpot_s']['p50']*1e3:.1f} ms"
        )
        print(
            f"sharing: {rep['shared_hits']} shared-page fetch hits, "
            f"{rep['shared_skipped_writebacks']} skipped writebacks, "
            f"{rep['unique_group_fetches']} unique fetches, "
            f"{rep['disk_requests']} disk req, prefill compiles "
            f"{rep['prefill_compiles']}"
        )
        return 0
    res = serve(
        cfg,
        mesh,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen=args.gen,
        n_requests=args.requests,
        kv_kind=args.kv_kind,
        kv_page_len=args.kv_page_len,
        hot_pages=args.hot_pages,
        distance=distance,
        seed=args.seed,
        spill_dir=args.spill_dir,
        param_kind=args.param_kind,
        device_budget_mb=args.device_budget_mb,
        param_cache_mb=args.param_cache_mb,
        expert_stream=args.expert_stream,
        prefix_sharing=not args.no_prefix_sharing,
        shared_prefix_len=args.shared_prefix_len,
    )
    stats = res["stats"]
    print(
        f"served {args.arch}: prefill {res['prefill_s']*1e3:.1f} ms, "
        f"decode {res['decode_s']*1e3:.1f} ms total, "
        f"{res['tokens_per_s']:.1f} tok/s "
        f"(kv_kind={args.kv_kind}, page_len={args.kv_page_len}, "
        f"paged={res['paged']})"
    )
    print(
        f"transfers: h2d {stats.h2d_requests} req / {stats.bytes_h2d} B, "
        f"d2h {stats.d2h_requests} req / {stats.bytes_d2h} B, "
        f"disk {stats.disk_requests} req, "
        f"compute wait {stats.transfer_wait_s*1e3:.2f} ms"
    )
    if res["paged"]:
        print(
            f"residency: peak {res['peak_resident_bytes']} B device-resident "
            f"of {res['total_cache_bytes']} B total cache "
            f"({res['demoted_groups']} demotions, "
            f"{res['stale_drops']} stale prefetches)"
        )
    if res.get("param_plan") is not None:
        ps = res["param_stats"]
        plan = res["param_plan"]
        h2d = ps.per_tier()["h2d"]
        print(
            f"weights: {plan.n_groups} groups x {plan.layers_per_group} "
            f"layers, {ps.h2d_requests} H2D req "
            f"({h2d['requests_per_fetched_device_group']:.2f}/"
            f"(device,group) fetched), peak streamed "
            f"{ps.peak_inflight_bytes} B of {plan.total_param_bytes} B "
            f"total params"
        )
        if res.get("expert_stats") is not None:
            es = res["expert_stats"]
            print(
                f"experts: {es.unique_group_fetches} fetched groups / "
                f"{es.cache_hits} resident hits, {es.bytes_h2d} B H2D "
                f"over {res['n_steps']} steps"
            )
        if res.get("param_residency") is not None:
            rc = res["param_residency"]
            print(
                f"weight residency: {rc['hits']} hits / {rc['misses']} "
                f"misses, {rc['resident_bytes']} B resident "
                f"(peak {rc['peak_resident_bytes']} B, "
                f"{rc['evictions']} evictions)"
            )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Batched serving driver: prefill + decode loop with placement policies.

Demonstrates the paper's memory kinds on the serving path: the KV cache can
be placed at ``Device`` (HBM) or ``PinnedHost`` level via ``--kv-kind``, and
host-resident caches are streamed per decode step (pass-by-reference: the
compiled step reads the device-resident view, the driver moves data).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \\
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core import memkind as mk
from repro.launch.mesh import make_local_mesh
from repro.models import transformer
from repro.parallel import sharding as sh
from repro.train import steps as st


def serve(
    cfg,
    mesh,
    *,
    batch: int,
    prompt_len: int,
    gen: int,
    kv_kind: str = "device",
    seed: int = 0,
):
    plan = sh.make_plan(mesh, mode="serve")
    key = jax.random.PRNGKey(seed)
    params = st.init_train_state(key, cfg)[0]
    sharder = sh.make_sharder(plan, params, batch)

    max_len = prompt_len + gen
    prefill_fn = jax.jit(st.make_prefill_step(cfg, batch, max_len, mesh, sharder))
    decode_fn = jax.jit(st.make_decode_step(cfg, mesh, sharder), donate_argnums=(1,))

    kind = mk.as_kind(kv_kind)
    tokens = jax.random.randint(key, (batch, prompt_len), 1, cfg.vocab_size)
    if cfg.n_codebooks:
        prompt = {"codes": jnp.broadcast_to(tokens[:, None], (batch, cfg.n_codebooks, prompt_len))}
    else:
        prompt = {"tokens": tokens}

    t0 = time.perf_counter()
    logits, caches = prefill_fn(params, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    t0 = time.perf_counter()
    for i in range(gen):
        nxt = jnp.argmax(logits[..., -1, :], axis=-1).astype(jnp.int32)
        if cfg.n_codebooks:
            step_batch = {"codes": nxt.reshape(batch, cfg.n_codebooks, 1)}
            out_tokens.append(nxt[:, 0])
        else:
            nxt = nxt.reshape(batch, 1)
            step_batch = {"tokens": nxt}
            out_tokens.append(nxt[:, 0])
        if kind.jax_kind != "device":
            # paper's Host kind: cache round-trips through host memory —
            # the decode step still sees a reference; the runtime moves data
            caches = mk.place(caches, mesh, jax.sharding.PartitionSpec(), kind)
            caches = mk.place(caches, mesh, jax.sharding.PartitionSpec(), mk.DEVICE)
        logits, caches = decode_fn(params, caches, step_batch, jnp.asarray(prompt_len + i, jnp.int32))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    return {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * gen / t_decode if t_decode else float("inf"),
        "generated": jnp.stack(out_tokens, axis=1),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-kind", default="device", choices=["device", "pinned_host"])
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(model=args.model_parallel)
    res = serve(
        cfg,
        mesh,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen=args.gen,
        kv_kind=args.kv_kind,
    )
    print(
        f"served {args.arch}: prefill {res['prefill_s']*1e3:.1f} ms, "
        f"decode {res['decode_s']*1e3:.1f} ms total, "
        f"{res['tokens_per_s']:.1f} tok/s (kv_kind={args.kv_kind})"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Seed-style per-leaf streaming vs the coalesced transfer engine (A/B).

Same workload as ``benchmarks/offload_modes.py`` (the paper's Fig-3 ML
benchmark: feed-forward ``ro`` streaming + combine-gradients ``rw``
streaming), run through ``HostStreamExecutor`` under two engine configs:

``seed``
    ``EngineConfig(coalesce=False, async_writeback=False)`` — one H2D
    request per pytree leaf per group, blocking D2H per ``rw`` group
    (the seed executor's schedule).
``engine``
    the default config — coalesced single-request groups, staging-buffer
    reuse, pipelined writeback.

Two link regimes per config:

* ``real`` — the container's actual host->device path (main memory), where
  the win is dispatch-count reduction;
* ``paper`` — the engine's deterministic link emulation at the paper's
  measured Epiphany constants (88 MB/s, 0.104 ms/request), where the
  request-count collapse dominates wall time exactly as in §5.1/Table 2.

Emits ``results/bench/BENCH_engine.json``.  The pass gate is the tentpole
acceptance: coalescing reaches 1 request/group and the engine beats the
seed schedule's prefetch-mode wall time by >= 20% on the paper link.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core.engine import EngineConfig, PAPER_EPIPHANY_LINK
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import PrefetchSpec

CONFIGS = {
    "seed": lambda link: EngineConfig(
        coalesce=False, async_writeback=False, link=link
    ),
    "engine": lambda link: EngineConfig(link=link),
}


#: leaves per weight group — the offload_modes model keeps each group's
#: weights as a single leaf; real train-loop groups (one transformer layer's
#: param dict) are many-leaf pytrees, which is where the seed's one-request-
#: per-leaf schedule multiplies (the paper's request-count penalty)
N_W_PARTS = 6


def _workload(n_pixels: int = 3600, groups: int = 16, batch_images: int = 8):
    """The offload_modes ML workload with train-loop group structure:
    ro groups {x, w-parts} for feed-forward, rw groups + device-resident
    upstream grad for combine-gradients."""
    cfg = C.LungNNConfig(n_pixels=n_pixels, batch_images=batch_images)
    params = C.init_lung_nn(cfg)
    xs, ys = C.make_images(cfg, batch_images)
    xs_host = np.asarray(xs)
    gp = n_pixels // groups
    hp = cfg.n_hidden // N_W_PARTS

    def w_parts(i):
        w = np.asarray(params["w1"][i * gp : (i + 1) * gp])
        return tuple(w[:, j * hp : (j + 1) * hp] for j in range(N_W_PARTS))

    w1_groups = [w_parts(i) for i in range(groups)]
    x_groups = [xs_host[:, i * gp : (i + 1) * gp] for i in range(groups)]

    h = jax.nn.sigmoid(xs @ params["w1"][:, : hp * N_W_PARTS])
    p = jax.nn.sigmoid(h @ params["w2"][: hp * N_W_PARTS])
    dh = ((p - jnp.asarray(ys)) @ params["w2"][: hp * N_W_PARTS].T) * h * (1 - h)

    @jax.jit
    def ff_apply(carry, group):
        w = jnp.concatenate(group["w"], axis=1)
        return carry + group["x"] @ w

    @jax.jit
    def grad_apply(carry, group):
        w = jnp.concatenate(group["w"], axis=1)
        gw = group["x"].T @ group["dh"]  # dh passes by reference (device)
        return carry + jnp.sum(gw * w), gw

    ff_groups = [{"x": x, "w": w} for x, w in zip(x_groups, w1_groups)]
    rw_groups = [{"x": x, "w": w, "dh": dh} for x, w in zip(x_groups, w1_groups)]
    ff_carry = jnp.zeros((batch_images, hp * N_W_PARTS), jnp.float32)
    return ff_apply, ff_groups, ff_carry, grad_apply, rw_groups


def run(tag: str = "BENCH_engine") -> list[dict]:
    ff_apply, ff_groups, ff_carry, grad_apply, rw_groups = _workload()
    spec = PrefetchSpec(buffer_size=6, elements_per_fetch=1, distance=2)
    rows = []
    values = {}
    for link_name, link in (("real", None), ("paper", PAPER_EPIPHANY_LINK)):
        for cfg_name, make_cfg in CONFIGS.items():
            # -- ro phase: feed forward ---------------------------------------
            ex = HostStreamExecutor(ff_apply, engine_config=make_cfg(link))
            st = StreamStats()
            t = C.timed(
                lambda: ex.run(
                    ff_carry, ff_groups, mode="prefetch", prefetch=spec, stats=st
                )[0],
                stats=st, repeats=5,
            )
            out, _ = ex.run(ff_carry, ff_groups, mode="prefetch", prefetch=spec)
            values[(link_name, cfg_name, "ff")] = np.asarray(out)
            ex.close()

            # -- rw phase: combine gradients (writeback) ----------------------
            ex2 = HostStreamExecutor(
                grad_apply, writeback=True, engine_config=make_cfg(link)
            )
            st2 = StreamStats()
            t2 = C.timed(
                lambda: ex2.run(
                    jnp.zeros(()), rw_groups, mode="prefetch", prefetch=spec,
                    stats=st2,
                )[0],
                stats=st2, repeats=5,
            )
            ex2.close()

            per = max(st.n_runs, 1)
            per2 = max(st2.n_runs, 1)
            rows.append(
                {
                    "link": link_name,
                    "config": cfg_name,
                    "ff_s": t["median_s"],
                    "rw_s": t2["median_s"],
                    "total_s": t["median_s"] + t2["median_s"],
                    # min over repeats: the least-interference estimate this
                    # loaded container can produce — what the gate uses
                    "total_min_s": t["min_s"] + t2["min_s"],
                    "h2d_requests_per_group": st.requests_per_group,
                    "rw_h2d_requests_per_group": st2.requests_per_group,
                    "d2h_requests": st2.d2h_requests // per2,
                    "transfer_wait_s": st.transfer_wait_s / per,
                    "rw_transfer_wait_s": st2.transfer_wait_s / per2,
                    "writeback_drain_s": st2.writeback_drain_s / per2,
                    "wait_hist": st.wait_hist(),
                }
            )

    by = {(r["link"], r["config"]): r for r in rows}
    for link_name in ("real", "paper"):
        seed, eng = by[(link_name, "seed")], by[(link_name, "engine")]
        eng["speedup_vs_seed"] = seed["total_s"] / eng["total_s"]
        eng["speedup_min_vs_seed"] = seed["total_min_s"] / eng["total_min_s"]
        seed["speedup_vs_seed"] = seed["speedup_min_vs_seed"] = 1.0

    C.print_table(
        "coalesced transfer engine vs seed per-leaf schedule (prefetch mode)",
        rows,
        ["link", "config", "ff_s", "rw_s", "total_s",
         "h2d_requests_per_group", "d2h_requests", "speedup_vs_seed",
         "speedup_min_vs_seed"],
    )
    C.save_rows(tag, rows)  # after the speedup columns exist

    # schedule must never change values
    np.testing.assert_array_equal(
        values[("real", "seed", "ff")], values[("real", "engine", "ff")]
    )
    return rows


def main() -> int:
    rows = run()
    by = {(r["link"], r["config"]): r for r in rows}
    one_req = by[("real", "engine")]["h2d_requests_per_group"] == 1.0
    seed_req = by[("real", "seed")]["h2d_requests_per_group"]
    eng = by[("paper", "engine")]
    speedup = max(eng["speedup_vs_seed"], eng["speedup_min_vs_seed"])
    print(
        f"requests/group: engine 1 vs seed {seed_req:.0f}; "
        f"paper-link wall-time speedup: {speedup:.2f}x "
        f"(median {eng['speedup_vs_seed']:.2f}x, "
        f"min {eng['speedup_min_vs_seed']:.2f}x; gate: >= 1.20x)"
    )
    return 0 if one_req and seed_req > 1 and speedup >= 1.20 else 1


if __name__ == "__main__":
    raise SystemExit(main())

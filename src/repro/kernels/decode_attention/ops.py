"""Public jit'd wrapper for flash-decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.refspec import PrefetchSpec
from repro.kernels.decode_attention.kernel import decode_attention_p

_DEFAULT_SPEC = PrefetchSpec(buffer_size=2, elements_per_fetch=1, distance=1)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("spec", "block_kv", "interpret"))
def decode_attention(
    q: jax.Array,  # (B, N, H)
    k: jax.Array,  # (B, T, KH, H)
    v: jax.Array,  # (B, T, KH, H)
    lengths: jax.Array,  # (B,) int32 — valid prefix per sequence
    *,
    spec: PrefetchSpec = _DEFAULT_SPEC,
    block_kv: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """One-token GQA attention vs a large KV cache streamed from HBM.

    Matches ``ref.decode_attention_ref``; the PrefetchSpec only changes the
    DMA schedule, never the value (property-tested).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, n, h = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = n // kh

    bkv = min(block_kv, _ceil_to(t, 128))
    tp = _ceil_to(t, bkv)

    qg = q.reshape(b, kh, g, h).reshape(b * kh, g, h)
    kg = k.transpose(0, 2, 1, 3).reshape(b * kh, t, h)
    vg = v.transpose(0, 2, 1, 3).reshape(b * kh, t, h)
    kg = jnp.pad(kg, ((0, 0), (0, tp - t), (0, 0)))
    vg = jnp.pad(vg, ((0, 0), (0, tp - t), (0, 0)))
    lens = jnp.repeat(lengths.astype(jnp.int32), kh)

    out = decode_attention_p(
        qg, kg, vg, lens, spec=spec, block_kv=bkv, interpret=interpret
    )
    return out.reshape(b, kh, g, h).reshape(b, n, h)


def decode_attention_paged(
    q: jax.Array,  # (B, N, H)
    k_pages,  # sequence of (B, Tp, KH, H) device-resident pages
    v_pages,  # sequence of (B, Tp, KH, H)
    lengths: jax.Array,  # (B,) int32 — valid prefix per sequence
    *,
    spec: PrefetchSpec = _DEFAULT_SPEC,
    block_kv: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash-decode over a paged KV-cache view, by reference.

    The kernel-level counterpart of the serving pager's
    :func:`repro.core.kvpager.assemble_view`: the TPU decode path for a
    page-granular cache (this container's CPU serving session instead
    assembles the dense view and decodes through the XLA attention —
    see ``train.steps.make_paged_decode_step``).  ``k_pages`` /
    ``v_pages`` are the per-page device tensors.  They are joined at trace
    time (pure concatenation, no host copies); the kernel's DMA grid then
    streams ``block_kv``-row slabs out of HBM exactly as for a contiguous
    cache.  ``block_kv`` defaults to the page length, floored at the
    TPU lane width (128) — so each DMA covers one page when pages are
    >= 128 tokens, and a whole number of pages per slab otherwise.
    Values are bitwise-identical to :func:`decode_attention` on the dense
    cache (property-tested).
    """
    k_pages, v_pages = tuple(k_pages), tuple(v_pages)
    if not k_pages or len(k_pages) != len(v_pages):
        raise ValueError("k_pages / v_pages must be equal-length, non-empty")
    if block_kv is None:
        block_kv = max(k_pages[0].shape[1], 128)
    k = jnp.concatenate(k_pages, axis=1)
    v = jnp.concatenate(v_pages, axis=1)
    return decode_attention(
        q, k, v, lengths, spec=spec, block_kv=block_kv, interpret=interpret
    )

"""Unit tests for ``runtime/elastic.py`` and ``runtime/straggler.py``
(ISSUE 5 satellite: these modules had only incidental coverage; the sweep
below surfaced and pins two real bugs).

Bugs found and fixed:
  * ``elastic_mesh_shape(pod_size=...)`` with a pod size not divisible by
    the model axis silently returned a mesh whose product lost devices
    (48 devices, pod_size=24, model=16 -> a 32-device (2, 1, 16) mesh).
  * ``StragglerMonitor`` with a window of near-identical step times had
    MAD ~ 0, so the robust z-score flagged *microsecond* jitter as a
    straggler; the MAD is now floored at 1% of the median.
"""
import time

import numpy as np
import pytest

from repro.runtime.elastic import elastic_mesh_shape
from repro.runtime.straggler import StragglerMonitor


# ---------------------------------------------------------------------------
# elastic_mesh_shape
# ---------------------------------------------------------------------------


def test_elastic_shape_product_always_matches_device_count():
    """Invariant: the returned mesh uses EVERY device, for every valid
    (n_devices, model, pod_size) cell."""
    for model in (1, 2, 4, 16):
        for mult in (1, 2, 3, 15, 16, 30, 32, 64):
            n = model * mult
            for pod_size in (None, model, 2 * model, 24, n):
                shape, axes = elastic_mesh_shape(n, model=model, pod_size=pod_size)
                assert int(np.prod(shape)) == n, (n, model, pod_size, shape)
                assert len(shape) == len(axes)
                assert axes[-1] == "model" and shape[-1] == model


def test_elastic_pod_size_not_divisible_by_model_falls_through():
    """Bugfix pin: pod_size=24 with model=16 cannot form whole model groups
    per pod; the old code returned (2, 1, 16) = 32 devices for 48."""
    shape, axes = elastic_mesh_shape(48, model=16, pod_size=24)
    assert int(np.prod(shape)) == 48
    assert "pod" not in axes


def test_elastic_pod_path_used_when_divisible():
    shape, axes = elastic_mesh_shape(512, model=16, pod_size=256)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # degenerate pod count (single pod) keeps the flat mesh
    shape, axes = elastic_mesh_shape(256, model=16, pod_size=256)
    assert axes == ("data", "model") and shape == (16, 16)


def test_elastic_prefer_pods_false_always_flat():
    shape, axes = elastic_mesh_shape(512, model=16, prefer_pods=False)
    assert axes == ("data", "model") and shape == (32, 16)


def test_elastic_invalid_inputs_raise():
    with pytest.raises(ValueError, match="not divisible"):
        elastic_mesh_shape(100, model=16)
    with pytest.raises(ValueError, match=">= 1"):
        elastic_mesh_shape(16, model=0)
    with pytest.raises(ValueError, match="cannot host"):
        elastic_mesh_shape(8, model=16)
    with pytest.raises(ValueError, match="cannot host"):
        elastic_mesh_shape(0, model=16)


def test_elastic_shrink_sequence_node_loss():
    """The docstring scenario: losing hosts shrinks the data axis while the
    model axis (an architectural choice) is preserved."""
    healthy, _ = elastic_mesh_shape(512, model=16)
    lost_two, _ = elastic_mesh_shape(480, model=16)
    assert healthy == (2, 16, 16)
    assert int(np.prod(lost_two)) == 480 and lost_two[-1] == 16


# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------


def _fill(m: StragglerMonitor, durations):
    m.window.extend(durations)


def test_straggler_identical_window_tolerates_jitter():
    """Bugfix pin: with 20 identical 10 ms steps in the window, a step of
    10.05 ms (0.5% jitter) must NOT be flagged — the raw MAD is zero and
    the unfloored z-score was ~3e4."""
    m = StragglerMonitor(window=32, z_threshold=6.0)
    _fill(m, [0.010] * 20)
    m.start_step(1)
    m._t0 = time.perf_counter() - 0.01005  # 10.05 ms step
    ev = m.end_step()
    assert ev is None, ev


def test_straggler_identical_window_still_flags_real_outlier():
    m = StragglerMonitor(window=32, z_threshold=6.0)
    _fill(m, [0.010] * 20)
    m.start_step(2)
    m._t0 = time.perf_counter() - 0.08  # 8x the median
    ev = m.end_step()
    assert ev is not None and ev.step == 2
    assert ev.z > 6.0 and m.events == [ev]


def test_straggler_needs_warm_window():
    """No flagging before 8 samples (the z-score is meaningless)."""
    m = StragglerMonitor(window=32)
    for i in range(7):
        m.start_step(i)
        m._t0 = time.perf_counter() - (1.0 if i == 3 else 0.001)
        assert m.end_step() is None
    assert len(m.window) == 7


def test_straggler_window_is_bounded():
    m = StragglerMonitor(window=10)
    for i in range(25):
        m.start_step(i)
        m._t0 = time.perf_counter() - 0.001
        m.end_step()
    assert len(m.window) == 10


def test_straggler_deadline_only_while_step_in_flight():
    m = StragglerMonitor(deadline_s=0.005)
    assert not m.check_deadline()  # no step started
    m.start_step(0)
    m._t0 = time.perf_counter() - 0.01
    assert m.check_deadline()
    m.end_step()
    assert not m.check_deadline()  # step finished — no stale deadline
    m2 = StragglerMonitor()  # no deadline configured
    m2.start_step(0)
    time.sleep(0.001)
    assert not m2.check_deadline()


def test_straggler_end_without_start_asserts():
    m = StragglerMonitor()
    with pytest.raises(AssertionError):
        m.end_step()


def test_straggler_noisy_window_uses_real_mad():
    """With genuine spread in the window the MAD floor must not mask real
    outliers nor create false ones."""
    rng = np.random.default_rng(0)
    m = StragglerMonitor(window=50, z_threshold=6.0)
    _fill(m, list(0.010 + rng.uniform(-0.002, 0.002, size=30)))
    m.start_step(7)
    m._t0 = time.perf_counter() - 0.011  # inside the spread
    assert m.end_step() is None
    m.start_step(8)
    m._t0 = time.perf_counter() - 0.05  # 5x median, far outside
    ev = m.end_step()
    assert ev is not None and ev.step == 8

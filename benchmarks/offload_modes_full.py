"""Paper Fig 4 analogue: FULL-size images — data larger than fast memory.

The paper's headline: pass-by-reference + prefetch let micro-cores process
images ~2000x larger than the interpolated ones, impossible under eager copy
within the device memory budget.  Here the image is scaled to dominate any
single transfer budget; eager mode is *disallowed* by a configurable device
memory budget (mirroring the 32 KB core / 32 MB shared limits), and the
streamed modes process it in bounded-size groups.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common as C
from benchmarks.offload_modes import run as run_modes


def main() -> int:
    # "full" images: 1.8M pixels (scaled so the CPU container finishes
    # quickly; the RATIO structure, not absolute size, is the claim)
    n_pixels = 1_800_000
    budget_bytes = 4 * 1024 * 1024  # device fast-memory budget per transfer
    image_bytes = n_pixels * 4
    print(
        f"full image: {image_bytes/2**20:.1f} MiB vs fast-memory budget "
        f"{budget_bytes/2**20:.1f} MiB -> eager per-argument copy infeasible; "
        f"streaming in {image_bytes // budget_bytes + 1} bounded groups"
    )
    rows = run_modes(n_pixels, groups=120, batch_images=2, tag="fig4_full")
    from benchmarks.offload_modes import modeled_link_rows

    modeled = {r["mode"]: r for r in modeled_link_rows(rows, n_pixels, 2)}
    speedup = modeled["on_demand_element"]["total_s"] / modeled["prefetch"]["total_s"]
    pf_vs_eager = modeled["eager"]["total_s"] / modeled["prefetch"]["total_s"]
    print(
        f"paper-link model: on-demand(element)/prefetch = {speedup:.0f}x "
        f"(paper Fig4: ~21x on Epiphany); eager/prefetch = {pf_vs_eager:.2f}x "
        f"(paper: prefetch up to 1.3x over eager)"
    )
    return 0 if speedup >= 5.0 and pf_vs_eager >= 1.0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Elastic re-meshing: resume a job on a different device count.

Checkpoints store unsharded leaves (see ``repro.checkpoint``), so the
*device* side of elasticity reduces to choosing a new mesh and re-deriving
shardings from the same logical rules.  Policy: keep the model axis (TP
degree is an architectural choice — it must divide heads/ffn), shrink/grow
the data axis; drop the pod axis when only one pod survives.

The *host/disk* side does not reduce so neatly: the streamed trainer homes
params and moments as **layer-group chunks** (checkpoint leaves named
``params__groups__g001_layers_000_002__...``, spill-store chunks keyed
``wp/<group>`` / ``wopt/<group>``), and a re-mesh that re-derives the
device budget — or an operator that changes ``--param-layers-per-group`` —
changes the partition itself.  :func:`reshard_grouped_checkpoint` migrates
a grouped checkpoint between partitions **by streaming**: old leaves are
memory-mapped, sliced/concatenated per *new* layer group, and written
through :meth:`CheckpointManager.save_streamed` — peak memory is one new
group's largest leaf, never the full tree.  Spill chunks re-partition for
free on the next step (restore hands plain arrays; the streamed step
re-spills group-wise under the new plan); :func:`prune_stale_spill` drops
the dead chunks of the old grouping from durable stores.

The driver's restart loop is wired through :func:`check_restart_mesh`: on
every restart it re-derives the elastic mesh shape for the *live* device
count and raises :class:`RemeshRequired` when the count changed — compiled
programs and layouts cannot be rebuilt in-process, so the recovery path is
a relaunch, which re-runs the reshard-on-resume check above (the forced
2↔1-device subprocess tests exercise exactly this path).
"""
from __future__ import annotations

import logging
import re
from typing import Any, Optional

log = logging.getLogger("repro.elastic")

Pytree = Any

#: checkpoint leaf-name separator (matches repro.checkpoint.manager._SEP)
_SEP = "__"

_GROUP_KEY_RE = re.compile(
    r"g(\d{3,})_(embed|head|"
    r"(layers|expert|period|block)_(\d{3,})_(\d{3,})(?:_e(\d{2,}))?)"
)


def elastic_mesh_shape(
    n_devices: int,
    *,
    model: int = 16,
    prefer_pods: bool = True,
    pod_size: Optional[int] = None,
) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest (pod, data, model) shape that fits ``n_devices``.

    >>> elastic_mesh_shape(512, model=16)      # healthy 2-pod job
    ((2, 16, 16), ('pod', 'data', 'model'))
    >>> elastic_mesh_shape(480, model=16)      # lost 2 hosts (8 chips each)
    ((30, 16), ('data', 'model'))
    >>> elastic_mesh_shape(256, model=16)
    ((16, 16), ('data', 'model'))
    """
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if n_devices < model:
        raise ValueError(
            f"{n_devices} devices cannot host a model axis of {model}"
        )
    if n_devices % model != 0:
        raise ValueError(f"{n_devices} devices not divisible by model={model}")
    rest = n_devices // model
    if prefer_pods and pod_size:
        chips_per_pod = pod_size
        # a pod must hold whole model groups, or the (pod, data, model)
        # product silently loses devices (pod_size=24, model=16 used to
        # yield a 32-device mesh for 48 devices)
        if (
            chips_per_pod % model == 0
            and chips_per_pod >= model
            and n_devices % chips_per_pod == 0
            and n_devices // chips_per_pod > 1
        ):
            pods = n_devices // chips_per_pod
            data = chips_per_pod // model
            return (pods, data, model), ("pod", "data", "model")
    if prefer_pods and rest % 16 == 0 and rest // 16 > 1:
        return (rest // 16, 16, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


# ---------------------------------------------------------------------------
# mesh identity + construction over the live device set
# ---------------------------------------------------------------------------


def mesh_fingerprint(mesh) -> dict:
    """JSON-serializable identity of a mesh (checkpoint/run metadata): a
    changed fingerprint on resume means shardings were re-derived and
    host/disk homes may need re-partitioning."""
    return {
        "n_devices": int(mesh.devices.size),
        "shape": [int(s) for s in mesh.devices.shape],
        "axes": [str(a) for a in mesh.axis_names],
    }


def elastic_local_mesh(model: int = 1):
    """Mesh over whatever devices exist *now*, via :func:`elastic_mesh_shape`.

    Unlike ``make_local_mesh`` (which asserts divisibility), the requested
    model axis degrades to the largest degree the surviving device count
    can host — the 2-device → 1-device resume keeps working instead of
    crashing on ``2 % 2 != 0``."""
    import jax

    from repro.jaxcompat import make_mesh

    n = len(jax.devices())
    m = max(1, min(model, n))
    while n % m:
        m -= 1
    if m != model:
        log.warning(
            "elastic mesh: model axis %d does not fit %d device(s); "
            "degraded to %d",
            model, n, m,
        )
    shape, axes = elastic_mesh_shape(n, model=m, prefer_pods=False)
    return make_mesh(shape, axes)


class RemeshRequired(RuntimeError):
    """The live device count no longer matches the mesh this process
    compiled for.  In-process restart cannot recover (programs and layouts
    are baked for the old mesh); relaunching re-derives everything — and
    the resume path re-partitions host/disk-homed state by streaming."""


def check_restart_mesh(expected: dict) -> None:
    """Called by the driver's restart loop: re-derive the elastic mesh for
    the live device count and raise :class:`RemeshRequired` if it changed
    since ``expected`` (a :func:`mesh_fingerprint`)."""
    import jax

    n = len(jax.devices())
    if n == expected.get("n_devices"):
        return
    model = 1
    axes = expected.get("axes") or []
    shape = expected.get("shape") or []
    if "model" in axes:
        model = int(shape[axes.index("model")])
    m = max(1, min(model, n))
    while n % m:
        m -= 1
    new_shape, new_axes = elastic_mesh_shape(n, model=m, prefer_pods=False)
    raise RemeshRequired(
        f"device count changed under a live job: compiled for "
        f"{expected.get('n_devices')} devices {tuple(shape)}, now {n}; "
        f"relaunch to re-mesh as {new_shape} {new_axes} — resume will "
        f"re-derive shardings and re-partition host/disk-homed state by "
        f"streaming"
    )


# ---------------------------------------------------------------------------
# streamed checkpoint re-partition (grouped weight-stream checkpoints)
# ---------------------------------------------------------------------------


def parse_group_key(key: str) -> Optional[dict]:
    """Parse a weight-stream group key (``g000_embed`` /
    ``g001_layers_000_002`` / ``g003_period_000_004`` /
    ``g002_block_002_003`` / ``g002_expert_001_002_e03`` / ``g004_head``)
    into its kind + layer bounds (+ expert index, -1 for non-expert kinds);
    None for names that are not group keys."""
    m = _GROUP_KEY_RE.fullmatch(key)
    if m is None:
        return None
    if m.group(2) == "embed":
        return {"key": key, "kind": "embed", "lo": 0, "hi": 0, "expert": -1}
    if m.group(2) == "head":
        return {"key": key, "kind": "head", "lo": 0, "hi": 0, "expert": -1}
    kind = m.group(3)
    expert = m.group(6)
    if (kind == "expert") != (expert is not None):
        return None  # the _eNN suffix is exactly the expert kinds' marker
    return {
        "key": key,
        "kind": kind,
        "lo": int(m.group(4)),
        "hi": int(m.group(5)),
        "expert": int(expert) if expert is not None else -1,
    }


def reshard_grouped_checkpoint(
    ckpt,
    plan,
    *,
    step: Optional[int] = None,
    extra_meta: Optional[dict] = None,
) -> bool:
    """Stream-repartition a grouped (weight-streamed) checkpoint into
    ``plan``'s grouping, in place, at the same step.

    The old partition is recovered from the stored leaf *names*
    (``{params|opt}__groups__<gkey>__<subpath>``), so checkpoints written
    before run metadata existed reshard too.  For each **new** layer group
    ``[lo, hi)``, every overlapping old group's stacked leaves are loaded
    memory-mapped, sliced along axis 0, and concatenated — one output leaf
    in memory at a time; embed/head and non-group leaves (``opt__step``)
    pass through byte-identical under their (possibly renumbered) new
    keys.  Values are never transformed, only re-partitioned, which is why
    the resumed loss series stays bitwise-equal.

    Returns True when a reshard was performed; False when there is nothing
    to do (no checkpoint, not grouped, or the partition already matches).
    """
    import numpy as np

    if step is None:
        step = ckpt.latest_step()
    if step is None:
        return False
    meta = ckpt.load_meta(step)
    names = [leaf["name"] for leaf in meta["leaves"]]
    dtypes = {leaf["name"]: leaf["dtype"] for leaf in meta["leaves"]}

    # recover the old partition from leaf names
    old_groups: dict[str, dict] = {}
    subs: dict[tuple[str, str], list[str]] = {}
    passthrough: list[str] = []
    for name in names:
        parts = name.split(_SEP)
        g = (
            parse_group_key(parts[2])
            if len(parts) >= 4 and parts[0] in ("params", "opt") and parts[1] == "groups"
            else None
        )
        if g is None:
            passthrough.append(name)
            continue
        old_groups[parts[2]] = g
        subs.setdefault((parts[0], parts[2]), []).append(_SEP.join(parts[3:]))
    if not old_groups:
        return False  # not a grouped checkpoint
    new_keys = {g.key for g in plan.groups}
    if set(old_groups) == new_keys:
        return False  # same partition — nothing to re-shard

    mid_kinds_old = frozenset(
        g["kind"] for g in old_groups.values() if g["kind"] not in ("embed", "head")
    )
    mid_kinds_new = frozenset(
        g.kind for g in plan.groups if g.kind not in ("embed", "head")
    )
    if mid_kinds_old != mid_kinds_new:
        raise ValueError(
            f"checkpoint step {step} was written with a "
            f"{sorted(mid_kinds_old)} group program but the plan builds "
            f"{sorted(mid_kinds_new)} — kind-family changes "
            "(e.g. toggling --expert-stream) cannot be streamed between "
            "partitions; resume with the original flags, or export the "
            "params and re-import under the new program"
        )
    if "expert" in mid_kinds_old:
        # expert programs force layers_per_group=1, so their keys are a
        # function of the config alone — differing key sets mean the model
        # (n_experts / n_layers) changed, which no reshard can bridge
        raise ValueError(
            f"checkpoint step {step} and the plan both use expert-split "
            "groups but their group keys differ — the MoE shape changed; "
            "re-grouping cannot change the model"
        )
    #: stacked middle kinds reslice along axis 0 ("period" in period-unit
    #: coordinates); named "block" groups redistribute whole block subtrees
    stacked_kind = (
        "layers" if "layers" in mid_kinds_old
        else ("period" if "period" in mid_kinds_old else None)
    )
    old_stacked = sorted(
        (g for g in old_groups.values() if g["kind"] == stacked_kind),
        key=lambda g: g["lo"],
    )
    scale = plan.scan_period if stacked_kind == "period" else 1
    old_blocks = {k: g for k, g in old_groups.items() if g["kind"] == "block"}
    old_embed = next(
        (k for k, g in old_groups.items() if g["kind"] == "embed"), None
    )
    old_head = next(
        (k for k, g in old_groups.items() if g["kind"] == "head"), None
    )
    span = max(
        (
            g["hi"]
            for g in old_groups.values()
            if g["kind"] not in ("embed", "head")
        ),
        default=0,
    )
    if span != plan.n_layers:
        raise ValueError(
            f"checkpoint step {step} covers {span} layers but the plan has "
            f"{plan.n_layers} — re-grouping cannot change the model"
        )

    new_embed = plan.groups[0].key
    new_head = plan.groups[-1].key

    def _load(name: str):
        return ckpt.load_leaf(step, name, dtype=dtypes.get(name), mmap=True)

    def leaves():
        # `tops` iterates the state roots that home grouped leaves: a
        # params-only checkpoint (serve export) has no ("opt", gkey) subs
        for top in ("params", "opt"):
            for old_key, new_key in ((old_embed, new_embed), (old_head, new_head)):
                for sub in subs.get((top, old_key), []):
                    yield (
                        _SEP.join((top, "groups", new_key, sub)),
                        _load(_SEP.join((top, "groups", old_key, sub))),
                    )
            if old_stacked:
                layer_subs = subs.get((top, old_stacked[0]["key"]), [])
                for ng in plan.groups:
                    if ng.kind != stacked_kind:
                        continue
                    for sub in layer_subs:
                        parts = []
                        for og in old_stacked:
                            lo, hi = max(ng.lo, og["lo"]), min(ng.hi, og["hi"])
                            if lo >= hi:
                                continue
                            arr = _load(
                                _SEP.join((top, "groups", og["key"], sub))
                            )
                            parts.append(
                                arr[
                                    (lo - og["lo"]) // scale
                                    : (hi - og["lo"]) // scale
                                ]
                            )
                        out = (
                            np.ascontiguousarray(parts[0])
                            if len(parts) == 1
                            else np.concatenate(
                                [np.asarray(p) for p in parts], axis=0
                            )
                        )
                        yield _SEP.join((top, "groups", ng.key, sub)), out
            if old_blocks:
                # each sub is "<block_name>__<rest>": whole named blocks
                # move to whichever new group homes that block name
                sub_home = {}
                for old_key in old_blocks:
                    for sub in subs.get((top, old_key), []):
                        sub_home[sub] = old_key
                for ng in plan.groups:
                    if ng.kind != "block":
                        continue
                    names = set(plan.block_names(ng))
                    for sub, old_key in sub_home.items():
                        if sub.split(_SEP)[0] not in names:
                            continue
                        yield (
                            _SEP.join((top, "groups", ng.key, sub)),
                            _load(_SEP.join((top, "groups", old_key, sub))),
                        )
        for name in passthrough:
            yield name, _load(name)

    log.info(
        "re-sharding checkpoint step %d: %d old groups -> %d new groups "
        "(layers_per_group=%d), one group leaf at a time",
        step, len(old_groups), plan.n_groups, plan.layers_per_group,
    )
    ckpt.save_streamed(
        step,
        leaves(),
        extra_meta=extra_meta,
        treedef=meta.get("treedef", "resharded"),
    )
    return True


def ensure_plan_matches_checkpoint(
    checkpoint_dir,
    plan,
    *,
    mesh=None,
    run_meta: Optional[dict] = None,
) -> bool:
    """Launcher-side resume check: if the latest checkpoint's weight
    grouping differs from ``plan``'s (an elastic re-mesh re-derived the
    budget, or the operator changed the group size), stream-repartition it
    in place before the driver restores.  Logs the mesh change (shardings
    re-derive from the new mesh on their own — checkpoint leaves are
    unsharded).  Returns True when a reshard was performed."""
    from pathlib import Path

    from repro.checkpoint.manager import CheckpointManager

    if not Path(checkpoint_dir).exists():
        return False
    ckpt = CheckpointManager(checkpoint_dir, keep=0)  # keep=0: never prunes
    step = ckpt.latest_step()
    if step is None:
        return False
    saved = ckpt.load_meta(step).get("extra") or {}
    if (
        mesh is not None
        and saved.get("mesh")
        and saved["mesh"] != mesh_fingerprint(mesh)
    ):
        log.warning(
            "elastic re-mesh: checkpoint step %d was written on mesh %s, "
            "resuming on %s — shardings re-derive from the new mesh; "
            "host/disk-homed groups re-partition below if the grouping "
            "changed",
            step, saved["mesh"], mesh_fingerprint(mesh),
        )
    return reshard_grouped_checkpoint(ckpt, plan, step=step, extra_meta=run_meta)


def prune_stale_spill(store, plan) -> int:
    """Drop spill chunks keyed by a *previous* grouping (``wp/``/``wopt/``
    keys not in ``plan``) from a durable store, so re-meshes do not
    accumulate dead chunk files.  Returns the number removed."""
    valid = {plan.spill_key(g) for g in plan.groups}
    valid |= {f"wopt/{g.key}" for g in plan.groups}
    stale = [
        k
        for k in list(store.keys())
        if (k.startswith("wp/") or k.startswith("wopt/")) and k not in valid
    ]
    for k in stale:
        store.delete(k)
    if stale:
        log.info(
            "pruned %d stale spill chunk(s) left by a previous grouping",
            len(stale),
        )
    return len(stale)

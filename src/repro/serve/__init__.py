"""Production serve front end: traffic generation and SLO scheduling.

The paper's runtime serves "data sets of arbitrarily large size" from tiny
device memories; this package supplies the traffic side of that claim — an
open-loop load generator (:mod:`repro.serve.loadgen`) and an
admission-controlled scheduler with per-request latency SLOs
(:mod:`repro.serve.scheduler`) driving the paged
:class:`~repro.launch.serve.ServeSession`.
"""
from repro.serve.loadgen import LoadGenConfig, OfferedRequest, Phase, generate
from repro.serve.scheduler import SLO, SLOScheduler

__all__ = [
    "LoadGenConfig",
    "OfferedRequest",
    "Phase",
    "generate",
    "SLO",
    "SLOScheduler",
]

"""Streamed model parameters: host/disk-homed weights under a device budget.

The paper's flagship claim ("compute with data sets of arbitrarily large
size", §3.1) applied to the largest pytree in the system — the model
weights.  A :class:`WeightStreamPlan` partitions a model's parameter tree
into an ordered **group program** of typed fetch groups:

  kind        home slice
  ----------  -----------------------------------------------------------
  ``embed``   token/audio embedding + vision merger (always group 0)
  ``layers``  contiguous ``[lo:hi)`` slice of uniform stacked ``blocks``
              leaves; under ``expert_stream`` the slice excludes the
              routed-expert tensors (router + attention + norms only)
  ``expert``  ONE routed expert of ONE MoE layer: the ``(1, d, f)`` rows
              ``blocks.moe.{wi,wo,wg}[l, e]`` as their own fetch group
  ``period``  a slice of stacked period-units of a period-scanned hetero
              stack (``blocks["periods"]``, hybrid/ssm archs)
  ``block``   named unrolled blocks (``layer_###`` / period-scan tails) —
              heterogeneous per-layer structures
  ``head``    final norm + LM head (always the last group; tied/codebook
              heads re-read the embedding table, so their *fetch* group
              also references the embed home leaves)

The middle of the program is summarized by :attr:`units` — the compute
**stream units** the step builders walk (one unit = the groups consumed by
one jitted stage call): a ``moe`` unit spans a layer's non-expert group
plus its E expert groups; every other kind is one group per unit.

Between steps the weights live at their **home kind** — host numpy
(``pinned_host``) or :class:`~repro.core.spillstore.SpillStore` memmap
chunks (``disk_host``, one chunk per group = one disk request) — and
stream group-wise through the :class:`~repro.core.engine.TransferEngine`
while the previous group's compute runs:

  forward    fetch order ``embed, U0, .., Un, head``; the head stage also
             computes the head/loss gradients (its params are in hand).
  backward   **reverse** fetch order — each unit's groups are re-fetched
             and the unit vjp recomputes its forward from the saved
             boundary activation (activation checkpointing at unit
             granularity), so backward peak residency equals forward's.
  optimizer  home order; each group streams ``{grads, moments}`` H2D and
             its updated ``{params, moments}`` ride ONE pipelined D2H
             drain back to the home kind.

Route-aware decode (``expert_stream``): the decode program fetches only a
layer's non-expert group through the pipeline, runs the router first, and
then fetches just the routed top-k experts' groups — the all-expert fetch
never happens, and the expert-granular residency cache keeps hot experts
device-resident across steps.

The plan is also the **device-budget model**: ``peak_device_bytes(d)`` is
the sliding-window maximum of ``d + 2`` consecutive stream-unit byte
counts (``d`` prefetched + 1 landing + 1 being consumed; a ``moe`` unit
counts all its groups since train/prefill hold them together), and
``max_distance_for_budget`` caps the adaptive prefetch window so the
streamed residency can never exceed ``--device-budget-mb`` no matter what
the controller learns.  Both take a ``cached_bytes`` term for the
:class:`~repro.core.residency.ResidencyCache`; ``residency_capacity_bytes``
is the slack left above the widest allowed window.

Where data lives never changes what is computed: every consumer runs the
same jitted per-group programs on the same values for every kind, so
streamed runs are bitwise-equal to the device-resident run (gated in
``benchmarks/weight_stream.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "WeightGroup",
    "StreamUnit",
    "WeightStreamPlan",
    "WeightStreamSupport",
    "weight_stream_support",
    "weight_stream_supported",
    "merge_expert_slice",
    "PARAM_KINDS",
]

Pytree = Any

#: the CLI surface of ``--param-kind``
PARAM_KINDS = ("device", "pinned_host", "disk_host")

#: spill-store key namespace for parameter group chunks
_KEY_PREFIX = "wp"

#: expert tensor names inside a block's ``moe`` subtree (wg only for gated)
_EXPERT_NAMES = ("wi", "wo", "wg")


@dataclasses.dataclass(frozen=True)
class WeightStreamSupport:
    """Reasoned support report for streaming an arch's parameters.

    ``supported`` covers the train path; ``serve_supported`` the decode
    path (heterogeneous layouts stream for train but their decode state is
    not group-pageable).  ``reason`` / ``serve_reason`` say why not —
    surfaced verbatim by the CLI ``--param-kind`` rejection errors."""

    supported: bool
    layout: str  # "uniform" | "period" | "unrolled" | ""
    reason: str = ""
    serve_supported: bool = False
    serve_reason: str = ""

    def __bool__(self) -> bool:
        return self.supported


def weight_stream_support(cfg) -> WeightStreamSupport:
    """Layout-aware support report: which group program (if any) can
    stream this arch's parameters, and why not where it can't."""
    if cfg.n_layers < 1:
        r = (
            f"{cfg.name}: weight streaming needs at least one block layer "
            f"(n_layers={cfg.n_layers})"
        )
        return WeightStreamSupport(False, "", r, False, r)
    if cfg.uniform_blocks and cfg.use_scan:
        return WeightStreamSupport(True, "uniform", "", True, "")
    layout = "period" if cfg.period_scan else "unrolled"
    serve_reason = (
        f"{cfg.name}: streamed serving requires uniform scanned blocks — "
        f"the {layout} layout's per-block decode state is not "
        "group-pageable; train-side streaming is supported via "
        f"{layout} group programs"
    )
    return WeightStreamSupport(True, layout, "", False, serve_reason)


def weight_stream_supported(cfg) -> bool:
    """Boolean view of :func:`weight_stream_support` (train path)."""
    return weight_stream_support(cfg).supported


def _tree_bytes(tree: Pytree) -> int:
    """Exact byte count of a pytree of shaped, dtyped leaves.  A leaf
    without a dtype is a hard error: silently assuming float32 would
    under-count the device budget for wider types."""
    total = 0
    for path, x in jax.tree_util.tree_flatten_with_path(tree)[0]:
        dt = getattr(x, "dtype", None)
        if dt is None:
            raise TypeError(
                "byte accounting needs a dtype on every leaf; leaf "
                f"{jax.tree_util.keystr(path)!r} ({type(x).__name__}) has "
                "none"
            )
        total += int(np.prod(np.shape(x), dtype=np.int64)) * np.dtype(dt).itemsize
    return total


def _to_host(x):
    """numpy view of a concrete leaf; tracers/ShapeDtypeStructs pass through
    so ``jax.eval_shape`` templates (driver restore) survive homing."""
    if isinstance(x, (jax.core.Tracer, jax.ShapeDtypeStruct)):
        return x
    return np.asarray(x)


def _concrete(tree: Pytree) -> bool:
    return all(
        not isinstance(x, (jax.core.Tracer, jax.ShapeDtypeStruct))
        for x in jax.tree.leaves(tree)
    )


@dataclasses.dataclass(frozen=True)
class WeightGroup:
    """One home group of the partition (a transfer group when fetched)."""

    index: int
    key: str  # pytree key in the home dict (sorted == home order)
    kind: str  # "embed" | "layers" | "expert" | "period" | "block" | "head"
    lo: int = 0  # absolute layer range covered by the group
    hi: int = 0
    expert: int = -1  # expert index for kind == "expert"


@dataclasses.dataclass(frozen=True)
class StreamUnit:
    """One compute stage of the stream program: the tuple of group indices
    a single jitted stage call consumes (a ``moe`` unit spans the layer's
    non-expert group plus its expert groups; other kinds are 1:1)."""

    kind: str  # "layers" | "moe" | "period" | "block"
    gidx: tuple  # indices into plan.groups, fetch order
    lo: int  # absolute layer range
    hi: int


def merge_expert_slice(ne: Pytree, experts: Sequence[Pytree]) -> Pytree:
    """Rebuild a stacked one-layer block slice from a non-expert group and
    its per-expert groups: each expert leaf ``(1, d, f)`` gains an expert
    axis and the stack concatenates to ``(1, E, d, f)`` — bitwise-identical
    to the slice the un-split layer group would have carried.  jnp-based so
    it runs inside the jitted stage (device-side, no host round trip)."""
    moe = dict(ne["moe"])
    for name in experts[0]:
        moe[name] = jnp.concatenate([e[name][:, None] for e in experts], axis=1)
    out = dict(ne)
    out["moe"] = moe
    return out


class WeightStreamPlan:
    """Partition of a model parameter tree into a typed group program.

    Parameters
    ----------
    cfg:
        the :class:`~repro.configs.base.ModelConfig` (must satisfy
        :func:`weight_stream_support`).
    abstract_params:
        ``jax.eval_shape`` tree of the *compute-dtype* params (what
        ``repro.train.steps.abstract_params`` returns) — shapes/dtypes
        drive the byte accounting and the group templates.
    layers_per_group:
        stream units per middle group — layers for the uniform/unrolled
        layouts, period-units for the period layout (each period-unit is
        ``cfg.scan_period`` layers).  ``None`` picks the largest count
        whose distance-1 peak fits ``device_budget_mb`` (falling back
        to 1).  Forced to 1 by ``expert_stream``.
    device_budget_mb:
        device-residency budget for streamed weights.  Enforced two ways:
        construction fails if even the smallest grouping at distance 1
        cannot fit, and :meth:`max_distance_for_budget` caps the prefetch
        window at run time.  ``None`` = unbounded.
    expert_stream:
        split each MoE layer into a non-expert group (router + attention +
        norms) plus one group per routed expert, enabling route-aware
        decode fetches and an expert-granular residency cache.  Requires
        an MoE config with the uniform layout.
    """

    def __init__(
        self,
        cfg,
        abstract_params: Pytree,
        *,
        layers_per_group: Optional[int] = None,
        device_budget_mb: Optional[float] = None,
        expert_stream: bool = False,
    ) -> None:
        support = weight_stream_support(cfg)
        if not support.supported:
            raise ValueError(support.reason)
        if "blocks" not in abstract_params:
            raise ValueError("param tree has no 'blocks' subtree")
        self.cfg = cfg
        self.support = support
        self.layout = support.layout
        self.n_layers = cfg.n_layers
        self.scan_period = cfg.scan_period
        if expert_stream:
            if self.layout != "uniform":
                raise ValueError(
                    f"{cfg.name}: --expert-stream needs the uniform layout "
                    f"(this arch streams via {self.layout} group programs)"
                )
            if not cfg.n_experts:
                raise ValueError(
                    f"{cfg.name}: --expert-stream requires an MoE config "
                    "(n_experts == 0)"
                )
        self.expert_stream = bool(expert_stream)
        keys = set(abstract_params)
        self.embed_keys = tuple(k for k in ("embed", "vision") if k in keys)
        self.head_home_keys = tuple(k for k in ("ln_f", "head") if k in keys)
        #: tied / codebook heads read the embedding table at the head stage
        self.head_reads_embed = "head" not in keys or bool(cfg.n_codebooks)

        blocks_abs = abstract_params["blocks"]
        self._blocks_template = blocks_abs
        total_block_bytes = _tree_bytes(blocks_abs)
        self.per_layer_bytes = total_block_bytes // max(1, self.n_layers)
        self.embed_bytes = _tree_bytes(
            {k: abstract_params[k] for k in self.embed_keys}
        )
        head_home_bytes = _tree_bytes(
            {k: abstract_params[k] for k in self.head_home_keys}
        )
        embed_table_bytes = (
            _tree_bytes(abstract_params.get("embed", {}))
            if self.head_reads_embed
            else 0
        )
        self.head_home_bytes = head_home_bytes
        self.embed_table_bytes = embed_table_bytes
        self.head_fetch_bytes = head_home_bytes + embed_table_bytes
        self.total_param_bytes = (
            self.embed_bytes + head_home_bytes + total_block_bytes
        )

        # ---- layout-specific byte model (exact: stacked leaves divide
        # evenly along the stacking axis, named blocks are counted per tree)
        self.expert_names: tuple = ()
        self.per_expert_bytes = 0
        self.nonexpert_layer_bytes = self.per_layer_bytes
        self._block_bytes: dict = {}  # named-block layouts: name -> bytes
        tail_bytes = 0
        if self.layout == "uniform":
            if self.expert_stream:
                moe_abs = blocks_abs["moe"]
                self.expert_names = tuple(
                    n for n in _EXPERT_NAMES if n in moe_abs
                )
                expert_total = _tree_bytes(
                    {n: moe_abs[n] for n in self.expert_names}
                )
                self.per_expert_bytes = expert_total // (
                    self.n_layers * cfg.n_experts
                )
                self.nonexpert_layer_bytes = (
                    self.per_layer_bytes - cfg.n_experts * self.per_expert_bytes
                )
            unit_bytes = [self.per_layer_bytes] * self.n_layers
        elif self.layout == "period":
            p = self.scan_period
            self._n_full = self.n_layers // p
            periods_bytes = _tree_bytes(blocks_abs["periods"])
            unit_bytes = [periods_bytes // self._n_full] * self._n_full
            self._tail_names = tuple(
                f"tail_{k}" for k in range(self.n_layers - self._n_full * p)
            )
            for name in self._tail_names:
                self._block_bytes[name] = _tree_bytes(blocks_abs[name])
            tail_bytes = sum(self._block_bytes.values())
        else:  # unrolled
            names = [f"layer_{i:03d}" for i in range(self.n_layers)]
            for name in names:
                self._block_bytes[name] = _tree_bytes(blocks_abs[name])
            unit_bytes = [self._block_bytes[n] for n in names]
        self._unit_bytes = unit_bytes
        self._tail_unit_bytes = tail_bytes

        budget = (
            int(device_budget_mb * 1e6) if device_budget_mb is not None else None
        )
        self.device_budget_bytes = budget
        if self.expert_stream:
            layers_per_group = 1
        elif layers_per_group is None:
            layers_per_group = self._fit_layers_per_group(budget)
        if layers_per_group < 1:
            raise ValueError("layers_per_group must be >= 1")
        self.layers_per_group = min(layers_per_group, len(unit_bytes))

        self._build_groups()

        if budget is not None and self.peak_device_bytes(1) > budget:
            raise ValueError(
                f"--device-budget-mb {device_budget_mb} cannot hold even a "
                f"distance-1 weight stream (peak "
                f"{self.peak_device_bytes(1) / 1e6:.1f} MB with "
                f"layers_per_group={self.layers_per_group}); raise the budget"
            )

    # --------------------------------------------------------- group program
    def _build_groups(self) -> None:
        groups: list[WeightGroup] = [WeightGroup(0, "g000_embed", "embed")]
        units: list[StreamUnit] = []
        names_map: dict = {}
        if self.layout == "uniform" and self.expert_stream:
            E = self.cfg.n_experts
            for l in range(self.n_layers):
                i = len(groups)
                groups.append(
                    WeightGroup(
                        i, f"g{i:03d}_layers_{l:03d}_{l + 1:03d}", "layers", l, l + 1
                    )
                )
                gidx = [i]
                for e in range(E):
                    i = len(groups)
                    groups.append(
                        WeightGroup(
                            i,
                            f"g{i:03d}_expert_{l:03d}_{l + 1:03d}_e{e:02d}",
                            "expert",
                            l,
                            l + 1,
                            expert=e,
                        )
                    )
                    gidx.append(i)
                units.append(StreamUnit("moe", tuple(gidx), l, l + 1))
        elif self.layout == "uniform":
            lo = 0
            while lo < self.n_layers:
                hi = min(lo + self.layers_per_group, self.n_layers)
                i = len(groups)
                groups.append(
                    WeightGroup(
                        i, f"g{i:03d}_layers_{lo:03d}_{hi:03d}", "layers", lo, hi
                    )
                )
                units.append(StreamUnit("layers", (i,), lo, hi))
                lo = hi
        elif self.layout == "period":
            p = self.scan_period
            lo_u = 0
            while lo_u < self._n_full:
                hi_u = min(lo_u + self.layers_per_group, self._n_full)
                i = len(groups)
                groups.append(
                    WeightGroup(
                        i,
                        f"g{i:03d}_period_{lo_u * p:03d}_{hi_u * p:03d}",
                        "period",
                        lo_u * p,
                        hi_u * p,
                    )
                )
                units.append(StreamUnit("period", (i,), lo_u * p, hi_u * p))
                lo_u = hi_u
            if self._tail_names:
                i = len(groups)
                lo = self._n_full * p
                g = WeightGroup(
                    i, f"g{i:03d}_block_{lo:03d}_{self.n_layers:03d}", "block",
                    lo, self.n_layers,
                )
                groups.append(g)
                units.append(StreamUnit("block", (i,), lo, self.n_layers))
                names_map[g.key] = self._tail_names
        else:  # unrolled
            lo = 0
            while lo < self.n_layers:
                hi = min(lo + self.layers_per_group, self.n_layers)
                i = len(groups)
                g = WeightGroup(
                    i, f"g{i:03d}_block_{lo:03d}_{hi:03d}", "block", lo, hi
                )
                groups.append(g)
                units.append(StreamUnit("block", (i,), lo, hi))
                names_map[g.key] = tuple(
                    f"layer_{j:03d}" for j in range(lo, hi)
                )
                lo = hi
        groups.append(
            WeightGroup(len(groups), f"g{len(groups):03d}_head", "head")
        )
        self.groups = tuple(groups)
        self.units = tuple(units)
        self.layer_groups = tuple(g for g in groups if g.kind == "layers")
        self.expert_groups = tuple(g for g in groups if g.kind == "expert")
        self.n_groups = len(groups)
        self._block_names_map = names_map

    def block_names(self, g: WeightGroup) -> tuple:
        """The named-block keys a ``block`` group homes."""
        return self._block_names_map[g.key]

    def experts_for_layer(self, lo: int) -> tuple:
        """The expert groups of the layer starting at ``lo`` (fetch order)."""
        return tuple(
            g for g in self.expert_groups if g.lo == lo
        )

    # ------------------------------------------------------------ byte model
    def group_bytes(self, g: WeightGroup, *, fetch: bool = True) -> int:
        if g.kind == "embed":
            return self.embed_bytes
        if g.kind == "head":
            # home bytes exclude the tied embed-table re-read (which is the
            # embed TABLE, not the whole embed group — vision towers ride
            # the embed group but are never re-read at the head stage)
            return self.head_fetch_bytes if fetch else self.head_home_bytes
        if g.kind == "expert":
            return self.per_expert_bytes
        if g.kind == "layers":
            return (g.hi - g.lo) * self.nonexpert_layer_bytes
        if g.kind == "period":
            n_units = (g.hi - g.lo) // self.scan_period
            return n_units * self._unit_bytes[0]
        return sum(self._block_bytes[n] for n in self.block_names(g))

    def fetch_sequence_bytes(self) -> list[int]:
        """Per-group H2D bytes in forward fetch order."""
        return [self.group_bytes(g) for g in self.groups]

    def _window_sequence_bytes(self) -> list[int]:
        """Per-STAGE bytes for the residency window model.  A ``moe`` unit's
        groups are consumed together by train/prefill (the merged stage
        holds the non-expert slice plus every expert), so the unit counts
        as one window element of their summed bytes — decode's routed
        subset only ever uses less."""
        seq = [self.embed_bytes]
        for u in self.units:
            seq.append(sum(self.group_bytes(self.groups[i]) for i in u.gidx))
        seq.append(self.head_fetch_bytes)
        return seq

    @staticmethod
    def _window_max(seq: list, distance: int) -> int:
        w = max(1, distance + 2)
        return max(sum(seq[i : min(i + w, len(seq))]) for i in range(len(seq)))

    def peak_device_bytes(self, distance: int, cached_bytes: int = 0) -> int:
        """Streamed-weight residency model: with ``distance`` stages
        prefetched, at most ``distance + 2`` consecutive stream units are
        device-resident at once (in flight + landing + being consumed).
        The backward pass walks the same sequence reversed, so the same
        sliding-window maximum bounds both passes.

        ``cached_bytes`` adds a residency-cache ceiling on top of the
        window: cached groups are extra device residency the stream does
        not see (a cache hit transfers zero bytes, so it never lands in
        the window term — the sum is a conservative bound, never an
        undercount).

        This is the documented FAST PATH of the occupancy model.  The
        exact per-point model lives in
        :func:`repro.core.schedcheck.analyze_train_schedule`, which
        replays the executor loop group by group; on the uniform, period
        and unrolled layouts without expert streaming or a cache the two
        are EQUAL (asserted in ``tests/test_schedcheck.py``), and the
        fast path upper-bounds the exact model everywhere else (expert
        streaming fetches at group granularity below the unit window;
        cache hits fetch zero bytes below the constant ``cached_bytes``
        term) — so a distance this model admits can never overrun the
        budget at run time."""
        return cached_bytes + self._window_max(
            self._window_sequence_bytes(), distance
        )

    def _peak_for_grouping(self, upg: int, distance: int) -> int:
        """Residency peak for a hypothetical units-per-group — shared by
        :meth:`peak_device_bytes` semantics and the auto group-sizing so
        the fit can never pick a group size the validation then rejects."""
        seq = [self.embed_bytes]
        lo = 0
        while lo < len(self._unit_bytes):
            hi = min(lo + upg, len(self._unit_bytes))
            seq.append(sum(self._unit_bytes[lo:hi]))
            lo = hi
        if self._tail_unit_bytes:
            seq.append(self._tail_unit_bytes)
        seq.append(self.head_fetch_bytes)
        return self._window_max(seq, distance)

    def max_distance_for_budget(self, cap: int = 8, cached_bytes: int = 0) -> int:
        """Largest prefetch distance whose modeled peak fits the budget —
        the engine's ``max_distance`` so the adaptive controller can never
        learn its way past the budget.  ``cached_bytes`` reserves residency
        for the group cache: window + cached bytes share the one budget, so
        a caller pinning cache capacity gets a correspondingly narrower
        window cap.

        Sized against the :meth:`peak_device_bytes` fast path; since that
        bound dominates the exact per-point model (see
        :mod:`repro.core.schedcheck`), every distance admitted here is
        statically verifiable against the same budget."""
        if self.device_budget_bytes is None:
            return cap
        d = 1
        while (
            d < cap
            and self.peak_device_bytes(d + 1, cached_bytes)
            <= self.device_budget_bytes
        ):
            d += 1
        return d

    def residency_capacity_bytes(self, cap: int = 8) -> Optional[int]:
        """Byte ceiling for the weight-residency group cache: the budget
        slack ABOVE the widest allowed prefetch window, so streaming keeps
        its latency-optimal window and cached + streamed bytes still can
        never exceed the budget.  ``None`` (no budget) = unbounded; zero
        slack = an inert cache = exactly the uncached schedule."""
        if self.device_budget_bytes is None:
            return None
        return max(
            0,
            self.device_budget_bytes
            - self.peak_device_bytes(self.max_distance_for_budget(cap)),
        )

    def _fit_layers_per_group(self, budget: Optional[int]) -> int:
        n = len(self._unit_bytes)
        if budget is None:
            return max(1, n // 4)
        for upg in range(n, 1, -1):
            # the EXACT distance-1 sliding-window peak (not a per-group
            # approximation — a window holds up to 3 consecutive groups)
            if self._peak_for_grouping(upg, 1) <= budget:
                return upg
        return 1

    def grouping(self) -> list[dict]:
        """JSON-serializable description of the group program.  Recorded
        in checkpoint/run metadata; the elastic resharder compares it (via
        the group keys, which encode kind + layer bounds) against a
        restored checkpoint's to decide whether host/disk-homed state must
        be re-partitioned."""
        return [
            {"key": g.key, "kind": g.kind, "lo": g.lo, "hi": g.hi,
             "expert": g.expert}
            for g in self.groups
        ]

    # ------------------------------------------------------------- slicing
    def _strip_experts(self, tree: Pytree) -> Pytree:
        """A block slice minus the routed-expert tensors (router kept)."""
        out = {k: v for k, v in tree.items() if k != "moe"}
        out["moe"] = {
            k: v for k, v in tree["moe"].items() if k not in self.expert_names
        }
        return out

    def home_group(self, params: Pytree, g: WeightGroup) -> Pytree:
        """The group's slice of a *full* param tree (views, no copies)."""
        if g.kind == "embed":
            return {k: params[k] for k in self.embed_keys}
        if g.kind == "head":
            return {k: params[k] for k in self.head_home_keys}
        if g.kind == "expert":
            moe = params["blocks"]["moe"]
            return {
                n: moe[n][g.lo : g.hi, g.expert] for n in self.expert_names
            }
        if g.kind == "period":
            p = self.scan_period
            return jax.tree.map(
                lambda a: a[g.lo // p : g.hi // p], params["blocks"]["periods"]
            )
        if g.kind == "block":
            return {n: params["blocks"][n] for n in self.block_names(g)}
        sl = jax.tree.map(lambda a: a[g.lo : g.hi], params["blocks"])
        return self._strip_experts(sl) if self.expert_stream else sl

    def init_home(self, params: Pytree) -> dict:
        """Home representation: ``{"groups": {key: group_tree}}`` with
        host-numpy leaves (a plain pytree — checkpointable as-is).
        Abstract leaves pass through for ``eval_shape`` templates."""
        return {
            "groups": {
                g.key: jax.tree.map(_to_host, self.home_group(params, g))
                for g in self.groups
            }
        }

    def assemble(self, home: dict) -> Pytree:
        """Full host param tree from a home (sliced groups concatenated,
        expert groups restacked) — for conversion/export; the streamed
        paths never call this."""
        cat = lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0)
        out: dict = {}
        for g in self.groups:
            if g.kind in ("embed", "head"):
                out.update({k: v for k, v in home["groups"][g.key].items()})
        if self.layout == "uniform" and self.expert_stream:
            layer_parts = []
            for u in self.units:
                ne = home["groups"][self.groups[u.gidx[0]].key]
                experts = [home["groups"][self.groups[i].key] for i in u.gidx[1:]]
                moe = dict(ne["moe"])
                for name in self.expert_names:
                    moe[name] = np.concatenate(
                        [np.asarray(e[name])[:, None] for e in experts], axis=1
                    )
                merged = dict(ne)
                merged["moe"] = moe
                layer_parts.append(merged)
            out["blocks"] = jax.tree.map(cat, *layer_parts)
        elif self.layout == "uniform":
            parts = [home["groups"][g.key] for g in self.layer_groups]
            out["blocks"] = jax.tree.map(cat, *parts)
        elif self.layout == "period":
            parts = [
                home["groups"][g.key] for g in self.groups if g.kind == "period"
            ]
            blocks = {"periods": jax.tree.map(cat, *parts)}
            for g in self.groups:
                if g.kind == "block":
                    blocks.update(home["groups"][g.key])
            out["blocks"] = blocks
        else:  # unrolled
            blocks = {}
            for g in self.groups:
                if g.kind == "block":
                    blocks.update(home["groups"][g.key])
            out["blocks"] = blocks
        return out

    # ------------------------------------------------------------- fetching
    def fetch_group(self, home: dict, g: WeightGroup, cache=None) -> Pytree:
        """The pytree actually streamed for a stage.  Identical to the home
        group except the head stage of tied/codebook archs, whose fetch
        group additionally references the embed home leaves (coalesced into
        the same staging buffer — still ONE H2D request per device).

        ``cache`` (a :class:`~repro.core.residency.ResidencyCache` keyed by
        group key, holding device-resident HOME trees) substitutes resident
        groups in place: a whole-group hit hands back committed
        ``jax.Array`` leaves that pass through the engine at zero H2D
        requests.  The tied head's embed-table leaf is borrowed from the
        resident embed group even on a head miss, so the table's bytes are
        never re-read across the link while its source group is resident."""
        tree = cache.lookup(g.key) if cache is not None else None
        if cache is not None and getattr(cache, "sanitize", False):
            cache.sanitize_home(
                g.key, home["groups"][g.key], hit=tree is not None
            )
        if tree is None:
            tree = home["groups"][g.key]
        if g.kind == "head" and self.head_reads_embed:
            tree = dict(tree)
            emb = cache.peek(self.groups[0].key) if cache is not None else None
            tree["embed"] = (
                emb["embed"]
                if emb is not None
                else home["groups"][self.groups[0].key]["embed"]
            )
        return tree

    def fetch_groups_forward(self, home: dict, cache=None) -> list:
        return [self.fetch_group(home, g, cache) for g in self.groups]

    def fetch_thunks_forward(self, home: dict, cache) -> list:
        """Forward fetch sequence as zero-arg thunks, resolved by the
        executor at SUBMIT time: residency decisions must see the cache as
        it is when the transfer would be issued, not when the step was
        scheduled (the embed group a head fetch wants to borrow from may
        only become resident mid-pass)."""
        return [
            (lambda g=g: self.fetch_group(home, g, cache)) for g in self.groups
        ]

    def cache_home_tree(self, g: WeightGroup, fetched: Pytree) -> Pytree:
        """The cacheable HOME part of a landed fetch group: the tied head's
        borrowed embed-table leaf belongs to the embed group's entry, so it
        is stripped rather than double-counted (and double-retained)."""
        if g.kind == "head" and self.head_reads_embed:
            return {k: fetched[k] for k in self.head_home_keys}
        return fetched

    def split_head_grads(self, dp_head: Pytree) -> tuple[Pytree, Optional[Pytree]]:
        """Split the head *fetch* group's grads into (head-home part, embed
        table part or None) — tied archs sum the embed part into the embed
        stage's gradient."""
        home = {k: dp_head[k] for k in self.head_home_keys}
        embed = dp_head.get("embed") if self.head_reads_embed else None
        return home, embed

    # ------------------------------------------------------------ shardings
    @staticmethod
    def _drop_expert_axis(sh):
        """Sharding for an expert group's ``(1, d, f)`` leaves derived from
        the stacked ``(L, E, d, f)`` leaf's sharding: drop the expert-axis
        spec entry (axis 1), keep the rest."""
        spec = list(sh.spec)
        if len(spec) > 1:
            spec.pop(1)
        return jax.sharding.NamedSharding(
            sh.mesh, jax.sharding.PartitionSpec(*spec)
        )

    def _group_sharding(self, g: WeightGroup, p_shardings, *, fetch: bool):
        if g.kind == "embed":
            return {k: p_shardings[k] for k in self.embed_keys}
        if g.kind == "head":
            tree = {k: p_shardings[k] for k in self.head_home_keys}
            if fetch and self.head_reads_embed:
                tree = dict(tree)
                tree["embed"] = p_shardings["embed"]
            return tree
        if g.kind == "expert":
            moe = p_shardings["blocks"]["moe"]
            return {
                n: self._drop_expert_axis(moe[n]) for n in self.expert_names
            }
        if g.kind == "period":
            return p_shardings["blocks"]["periods"]
        if g.kind == "block":
            return {n: p_shardings["blocks"][n] for n in self.block_names(g)}
        if self.expert_stream:
            return self._strip_experts(p_shardings["blocks"])
        return p_shardings["blocks"]

    def group_shardings(self, p_shardings: Optional[Pytree]):
        """Per-fetch-group sharding trees from a full-params sharding tree
        (slicing a stacked leaf keeps its rank, so the blocks leaf sharding
        applies to every sliced group unchanged; expert groups drop the
        expert-axis spec entry)."""
        if p_shardings is None:
            return None
        return [
            self._group_sharding(g, p_shardings, fetch=True) for g in self.groups
        ]

    def home_group_shardings(self, p_shardings: Optional[Pytree]):
        """Home-order sharding trees (no tied-embed aliasing) — the layout
        the optimizer phase stages grads/moments at."""
        if p_shardings is None:
            return None
        return [
            self._group_sharding(g, p_shardings, fetch=False)
            for g in self.groups
        ]

    # ------------------------------------------------------------- spilling
    def spill_key(self, g: WeightGroup) -> str:
        return f"{_KEY_PREFIX}/{g.key}"

    def spill_home(self, home: dict, store) -> dict:
        """Re-home every group at the ``DiskHost`` tier: one spill-store
        chunk per group (= one disk request per fetch), leaves replaced by
        memmap views.  Abstract templates pass through; groups already
        disk-resident are not rewritten."""
        from repro.core.spillstore import is_disk_leaf

        groups = {}
        for g in self.groups:
            tree = home["groups"][g.key]
            if not _concrete(tree):
                return home
            if all(is_disk_leaf(v) for v in jax.tree.leaves(tree)):
                groups[g.key] = tree
                continue
            store.put(self.spill_key(g), tree)
            groups[g.key] = store.get(self.spill_key(g))
        return {"groups": groups}

    def is_spilled(self, home: dict) -> bool:
        from repro.core.spillstore import is_disk_leaf

        return any(
            is_disk_leaf(v)
            for v in jax.tree.leaves(home["groups"])
        )

    def device_home(self, home: dict, p_shardings: Optional[Pytree] = None) -> dict:
        """Place every home group on device (the ``param_kind=device``
        baseline: fetch groups pass through the engine by reference)."""
        shardings = self.home_group_shardings(p_shardings)
        groups = {}
        for i, g in enumerate(self.groups):
            tree = home["groups"][g.key]
            if shardings is None:
                groups[g.key] = jax.device_put(tree)
            else:
                groups[g.key] = jax.device_put(tree, shardings[i])
        return {"groups": groups}

    def __repr__(self) -> str:  # pragma: no cover - trivial
        return (
            f"WeightStreamPlan({self.cfg.name}, layout={self.layout}, "
            f"n_groups={self.n_groups}, "
            f"layers_per_group={self.layers_per_group}, "
            f"expert_stream={self.expert_stream}, "
            f"total={self.total_param_bytes / 1e6:.1f}MB)"
        )

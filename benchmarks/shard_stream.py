"""Sharding-aware coalesced streaming vs the per-leaf fallback (A/B).

The paper's §5.1 result is that the offload penalty scales with *request
count*; PR 1's coalescer collapsed it to 1 request/group — but only for
default placement.  Any ``--model-parallel`` run passed explicit
``device_shardings`` and silently fell back to the seed's
one-``device_put``-per-leaf schedule.  This suite pins the recovered
collapse on a forced 2-device host mesh under the modeled Epiphany-class
link:

``per_leaf``
    ``EngineConfig(coalesce=False)`` with explicit shardings — the old
    fallback: one request per (host leaf, addressable shard).
``sharded``
    the default engine with the same shardings — ONE coalesced H2D
    request per (addressable device, group), leaves assembled with
    ``jax.make_array_from_single_device_arrays``.

Gates (the ISSUE 4 acceptance): sharded streaming costs exactly 1 request
per (device, group); the per-leaf fallback costs one per (leaf, shard);
steady-state transfer wait is >= 2x lower; all schedules bitwise-equal to
eager sharded placement.  Emits ``results/bench/BENCH_shard.json``.

The forced device count must precede JAX init, so ``main()`` re-execs
itself in a child process with ``XLA_FLAGS`` set (the aggregator runs
suites in-process with JAX already initialised).
"""
from __future__ import annotations

import os
import subprocess
import sys

_CHILD_FLAG = "REPRO_SHARD_BENCH_CHILD"

#: weight parts per group — the per-leaf schedule costs
#: (1 + N_W_PARTS) x n_devices requests/group, the engine n_devices
N_W_PARTS = 12
N_GROUPS = 16
N_DEVICES = 2


def _run_child() -> list[dict]:
    import numpy as np

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks import common as C
    from repro.core.engine import EngineConfig, PAPER_EPIPHANY_LINK
    from repro.core.hoststream import HostStreamExecutor, StreamStats
    from repro.core.refspec import AUTO, PrefetchSpec
    from repro.jaxcompat import make_mesh

    smoke = bool(os.environ.get("REPRO_BENCH_SMOKE"))
    repeats = 3 if smoke else 5
    devs = jax.devices()
    assert len(devs) == N_DEVICES, devs
    mesh = make_mesh((1, N_DEVICES), ("data", "model"))

    # small leaves: the link's per-request cost (the term coalescing
    # collapses) dominates the serial bandwidth term
    rng = np.random.default_rng(0)
    groups = [
        {
            "x": rng.standard_normal((8, 48)).astype(np.float32),
            "w": tuple(
                rng.standard_normal((48, 32)).astype(np.float32)
                for _ in range(N_W_PARTS)
            ),
        }
        for _ in range(N_GROUPS)
    ]
    shardings = {
        "x": NamedSharding(mesh, P()),
        "w": tuple(
            NamedSharding(mesh, P(None, "model")) for _ in range(N_W_PARTS)
        ),
    }

    @jax.jit
    def apply(carry, g):
        w = jnp.concatenate(g["w"], axis=1)
        return carry + g["x"] @ w

    carry0 = jnp.zeros((8, 32 * N_W_PARTS), jnp.float32)
    spec = PrefetchSpec(buffer_size=N_GROUPS + 2, distance=AUTO)

    # bitwise reference: eager sharded placement, same apply
    eager_groups = [jax.device_put(g, shardings) for g in groups]
    with HostStreamExecutor(apply, device_shardings=shardings) as ex:
        ref, _ = ex.run(carry0, eager_groups, mode="eager")
    ref = np.asarray(ref)

    configs = {
        "per_leaf": EngineConfig(coalesce=False, link=PAPER_EPIPHANY_LINK),
        "sharded": EngineConfig(link=PAPER_EPIPHANY_LINK),
    }
    rows = []
    for name, cfg in configs.items():
        ex = HostStreamExecutor(apply, device_shardings=shardings, engine_config=cfg)
        st = StreamStats()
        t = C.timed(
            lambda: ex.run(carry0, groups, mode="prefetch", prefetch=spec, stats=st)[0],
            stats=st,
            repeats=repeats,
        )
        out, _ = ex.run(carry0, groups, mode="prefetch", prefetch=spec)
        np.testing.assert_array_equal(np.asarray(out), ref)  # bitwise, any schedule
        ex.close()
        waits = list(st.wait_per_group)
        steady = waits[len(waits) // 2 :]
        per = max(st.n_runs, 1)
        rows.append(
            {
                "config": name,
                "n_devices": st.n_devices,
                "n_leaves": 1 + N_W_PARTS,
                "requests_per_group": st.requests_per_group,
                "requests_per_device_group": st.per_tier()["h2d"][
                    "requests_per_device_group"
                ],
                "h2d_requests": st.h2d_requests // per,
                "bytes_h2d": st.bytes_h2d // per,
                "transfer_wait_s": st.transfer_wait_s / per,
                "steady_wait_per_group_s": float(np.median(steady)),
                "total_s": t["median_s"],
                "total_min_s": t["min_s"],
                "final_distance": st.distance_trace[-1] if st.distance_trace else None,
                "bitwise_equal_to_eager_sharded": True,
            }
        )

    by = {r["config"]: r for r in rows}
    by["sharded"]["wait_collapse_vs_per_leaf"] = (
        by["per_leaf"]["steady_wait_per_group_s"]
        / max(by["sharded"]["steady_wait_per_group_s"], 1e-9)
    )
    by["per_leaf"]["wait_collapse_vs_per_leaf"] = 1.0
    C.print_table(
        "sharded coalesced streaming vs per-leaf fallback (2-device mesh, paper link)",
        rows,
        ["config", "n_devices", "requests_per_group", "requests_per_device_group",
         "steady_wait_per_group_s", "transfer_wait_s", "total_s",
         "wait_collapse_vs_per_leaf"],
    )
    C.save_rows("BENCH_shard", rows)
    return rows


def _child_main() -> int:
    rows = _run_child()
    by = {r["config"]: r for r in rows}
    eng, leaf = by["sharded"], by["per_leaf"]
    one_per_device = eng["requests_per_device_group"] == 1.0
    storm = leaf["requests_per_group"] == (1 + N_W_PARTS) * N_DEVICES
    collapse = eng["wait_collapse_vs_per_leaf"]
    print(
        f"requests/group: sharded {eng['requests_per_group']:.0f} "
        f"(= n_devices) vs per-leaf {leaf['requests_per_group']:.0f} "
        f"(= n_leaves x n_shards); steady-wait collapse {collapse:.1f}x "
        f"(gate: >= 2x)"
    )
    return 0 if one_per_device and storm and collapse >= 2.0 else 1


def main() -> int:
    if os.environ.get(_CHILD_FLAG):
        return _child_main()
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEVICES}"
    env[_CHILD_FLAG] = "1"
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run([sys.executable, "-m", "benchmarks.shard_stream"], env=env)
    return proc.returncode


if __name__ == "__main__":
    raise SystemExit(main())

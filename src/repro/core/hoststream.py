"""Host-driver streaming engine: the paper's host process + channels, in JAX.

The paper's architecture (§4, Fig 2) keeps bulk data on the host; a host-side
service decodes references and feeds per-core channels (32 x 1KB cells) while
device code computes.  This module is the direct analogue at framework level:
model state stays **outside the XLA program** as host arrays; the driver
issues asynchronous ``jax.device_put`` transfers for layer-group ``i+distance``
while the jitted apply for group ``i`` runs.  Because transfers and compute
are separate dispatches, this engine runs on *every* backend — including the
CPU container, where it produces the real measurements behind EXPERIMENTS.md
§Bench (the graph engine in ``prefetch.py`` is the production TPU path).

Three transfer schedules, mirroring the paper's evaluation axes:

``eager``      copy *all* groups, then compute (paper's original offload).
``on_demand``  copy group i synchronously right before computing it
               (paper's pass-by-reference without prefetch — the 21-25x
               slowdown case when transfers are small).
``prefetch``   keep ``distance`` groups in flight ahead of compute.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable, Optional, Sequence

import jax

from repro.core.refspec import Access, PrefetchSpec

__all__ = ["StreamStats", "HostStreamExecutor"]

Pytree = Any


@dataclasses.dataclass
class StreamStats:
    """Per-run accounting (the paper's Table 2 instrumentation)."""

    mode: str = "prefetch"
    n_transfers: int = 0
    bytes_h2d: int = 0
    bytes_d2h: int = 0
    transfer_wait_s: float = 0.0  # time the *compute* path blocked on data
    compute_s: float = 0.0
    total_s: float = 0.0

    def as_row(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def _nbytes(tree: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


class HostStreamExecutor:
    """Drives ``carry = apply(carry, group_params)`` over host-resident groups.

    Parameters
    ----------
    apply:
        jitted per-group function ``(carry, group) -> carry`` (or
        ``(carry, group) -> (carry, group_out)`` with ``writeback=True`` —
        the paper's ``rw`` access modifier, used e.g. for streamed optimizer
        state which must be copied back to its home kind).
    device_sharding:
        optional pytree of shardings for the staged groups.
    """

    def __init__(
        self,
        apply: Callable[..., Any],
        *,
        writeback: bool = False,
        device_shardings: Optional[Pytree] = None,
    ) -> None:
        self._apply = apply
        self._writeback = writeback
        self._shardings = device_shardings

    # -- transfer primitive (the paper's channel cell write) ----------------
    def _put(self, group: Pytree) -> Pytree:
        if self._shardings is not None:
            return jax.device_put(group, self._shardings)
        return jax.device_put(group)

    def run(
        self,
        carry: Pytree,
        groups: Sequence[Pytree],
        *,
        prefetch: Optional[PrefetchSpec] = None,
        mode: str = "prefetch",
        stats: Optional[StreamStats] = None,
    ) -> tuple[Pytree, Optional[list]]:
        """Execute all groups under the given schedule.  Returns the final
        carry (+ written-back host groups when ``writeback``)."""
        if mode not in ("eager", "on_demand", "prefetch"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "prefetch" and prefetch is None:
            prefetch = PrefetchSpec()
        distance = 0 if mode != "prefetch" else max(prefetch.distance, 1)
        st = stats if stats is not None else StreamStats()
        st.mode = mode
        t_start = time.perf_counter()

        outs: list = [] if self._writeback else None
        n = len(groups)

        if mode == "eager":
            # bulk transfer first — the paper's original kernel invocation
            staged = []
            for grp in groups:
                buf = self._put(grp)
                st.n_transfers += 1
                st.bytes_h2d += _nbytes(grp)
                staged.append(buf)
            t0 = time.perf_counter()
            jax.block_until_ready(staged)
            st.transfer_wait_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            for buf in staged:
                carry = self._step(carry, buf, outs, st)
            jax.block_until_ready(carry)
            st.compute_s += time.perf_counter() - t0
        else:
            inflight: "OrderedDict[int, Pytree]" = OrderedDict()
            issued = 0
            for i in range(n):
                # top up the pipeline to `distance` groups ahead
                while issued <= min(i + distance, n - 1):
                    inflight[issued] = self._put(groups[issued])
                    st.n_transfers += 1
                    st.bytes_h2d += _nbytes(groups[issued])
                    issued += 1
                buf = inflight.pop(i)
                if mode == "on_demand":
                    # the paper's blocking fetch: core stalls until data lands
                    t0 = time.perf_counter()
                    jax.block_until_ready(buf)
                    st.transfer_wait_s += time.perf_counter() - t0
                t0 = time.perf_counter()
                carry = self._step(carry, buf, outs, st)
                st.compute_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            jax.block_until_ready(carry)
            st.compute_s += time.perf_counter() - t0

        st.total_s = time.perf_counter() - t_start
        return (carry, outs) if self._writeback else (carry, None)

    def _step(self, carry: Pytree, buf: Pytree, outs: Optional[list], st: StreamStats) -> Pytree:
        if self._writeback:
            carry, group_out = self._apply(carry, buf)
            host_out = jax.device_get(group_out)  # write back to home kind
            st.bytes_d2h += _nbytes(group_out)
            st.n_transfers += 1
            outs.append(host_out)
        else:
            carry = self._apply(carry, buf)
        return carry

"""Open-loop load generator: seeded Poisson arrivals over bursty phases.

Real serving traffic is not a fixed ``--requests`` list: requests arrive
on their own clock (open loop — arrivals do not wait for completions),
rates burst, and prompt/output lengths are mixed.  This module synthesizes
that shape deterministically: a seeded :func:`numpy.random.default_rng`
drives exponential inter-arrival times per :class:`Phase` (piecewise-
constant rate — the bursty pattern), categorical prompt/output length
mixtures, and an optional shared system prompt (``shared_prefix_len``
identical leading tokens on a ``shared_frac`` fraction of requests — the
traffic shape copy-on-write prefix sharing exists for).

Everything is derived from ``LoadGenConfig.seed``: the same config always
yields the same offered trace, which is what makes the scheduler's SLO
reports and the ``serve_slo`` bench gates reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["Phase", "LoadGenConfig", "OfferedRequest", "generate"]


@dataclasses.dataclass(frozen=True)
class Phase:
    """One piecewise-constant arrival-rate segment."""

    duration_s: float
    rate_rps: float

    def __post_init__(self) -> None:
        if self.duration_s <= 0:
            raise ValueError("duration_s must be > 0")
        if self.rate_rps < 0:
            raise ValueError("rate_rps must be >= 0")


@dataclasses.dataclass(frozen=True)
class LoadGenConfig:
    """Knobs of one offered-traffic trace (fully seeded — deterministic)."""

    seed: int = 0
    #: bursty arrival profile: steady -> burst -> steady by default
    phases: tuple = (
        Phase(duration_s=4.0, rate_rps=2.0),
        Phase(duration_s=1.0, rate_rps=8.0),
        Phase(duration_s=4.0, rate_rps=2.0),
    )
    #: prompt-length mixture (categorical over ``prompt_lens``)
    prompt_lens: tuple = (8, 24, 48)
    prompt_mix: tuple = (0.5, 0.3, 0.2)
    #: output-length mixture
    gen_lens: tuple = (4, 8, 16)
    gen_mix: tuple = (0.5, 0.3, 0.2)
    #: shared system prompt: this many identical leading tokens on a
    #: ``shared_frac`` fraction of requests (0 disables)
    shared_prefix_len: int = 0
    shared_frac: float = 1.0
    vocab_size: int = 256

    def __post_init__(self) -> None:
        if not self.phases:
            raise ValueError("at least one phase is required")
        if len(self.prompt_lens) != len(self.prompt_mix):
            raise ValueError("prompt_lens and prompt_mix must align")
        if len(self.gen_lens) != len(self.gen_mix):
            raise ValueError("gen_lens and gen_mix must align")
        if not 0.0 <= self.shared_frac <= 1.0:
            raise ValueError("shared_frac must be in [0, 1]")


@dataclasses.dataclass(frozen=True)
class OfferedRequest:
    """One offered request: when it arrives and what it asks for."""

    arrival_s: float
    prompt: np.ndarray  # (s,) int32
    gen: int
    #: True when the prompt starts with the trace's shared system prompt
    shared: bool


def _normalized(mix: Sequence[float]) -> np.ndarray:
    w = np.asarray(mix, np.float64)
    if w.sum() <= 0:
        raise ValueError("mixture weights must sum to > 0")
    return w / w.sum()


def generate(cfg: LoadGenConfig) -> list[OfferedRequest]:
    """The offered trace: arrival-sorted requests over the phase profile."""
    rng = np.random.default_rng(cfg.seed)
    shared_prefix = None
    if cfg.shared_prefix_len > 0:
        shared_prefix = rng.integers(
            1, cfg.vocab_size, size=cfg.shared_prefix_len, dtype=np.int32
        )
    p_mix = _normalized(cfg.prompt_mix)
    g_mix = _normalized(cfg.gen_mix)

    out: list[OfferedRequest] = []
    phase_start = 0.0
    for phase in cfg.phases:
        phase_end = phase_start + phase.duration_s
        if phase.rate_rps > 0:
            t = phase_start
            while True:
                # open loop: exponential inter-arrival at the phase rate,
                # independent of anything the server does
                t += rng.exponential(1.0 / phase.rate_rps)
                if t >= phase_end:
                    break
                plen = int(rng.choice(cfg.prompt_lens, p=p_mix))
                gen = int(rng.choice(cfg.gen_lens, p=g_mix))
                shared = (
                    shared_prefix is not None
                    and rng.random() < cfg.shared_frac
                )
                if shared:
                    tail = rng.integers(
                        1, cfg.vocab_size,
                        size=max(0, plen - len(shared_prefix)),
                        dtype=np.int32,
                    )
                    prompt = np.concatenate([shared_prefix, tail])[:plen]
                else:
                    prompt = rng.integers(
                        1, cfg.vocab_size, size=plen, dtype=np.int32
                    )
                out.append(
                    OfferedRequest(
                        arrival_s=t,
                        prompt=np.asarray(prompt, np.int32),
                        gen=gen,
                        shared=bool(shared),
                    )
                )
        phase_start = phase_end
    return out

"""Static schedule verification + runtime hazard sanitizer.

The streamed-memory runtime derives every transfer from a
:class:`~repro.core.weightstream.WeightStreamPlan` group program, so the
whole schedule — fetch order, residency, writebacks, KV paging — is known
*before* the engine runs.  This module symbolically executes those
programs and checks, at every program point:

1. **Exact device occupancy** ≤ the device budget.  The plan's
   ``peak_device_bytes`` is a sliding-window *fast path* (``distance + 2``
   consecutive stream units); the analyzer replays the executor loop —
   top-up to ``i + distance``, consume, retire with one stage of lag —
   and takes a per-point maximum over in-flight window bytes + residency
   cache bytes + KV hot reservation, including the tied-embed head borrow
   and the router-first expert fan-in.  On uniform/period/unrolled
   layouts without expert streaming the exact model equals the fast path
   bound; with expert streaming or a residency cache it is tighter (the
   fast path stays a sound upper bound — asserted in tests).
2. **Staging lifetime** — no pool slot reacquired while a ticket is in
   flight (runtime sanitizer; the static side has no aliasing since the
   pool is engine-internal).
3. **RAW hazards** between D2H writeback drains and H2D re-fetches of the
   same group or spill chunk (the bug class the stale-cache invalidation
   of the optimizer writeback path fixed reactively).
4. **Pin/unpin balance** and **spill-key uniqueness** across the program.

Static entry points: :func:`analyze_train_schedule`,
:func:`analyze_serve_schedule`, :func:`verify_schedule` (raises
:class:`ScheduleError` carrying the :class:`ScheduleReport`).  Runtime
side: :class:`HazardSanitizer` (wired into ``TransferEngine`` /
``ResidencyCache`` under ``EngineConfig(sanitize=True)`` or
``REPRO_SANITIZE=1``) raises :class:`HazardError` at the faulting call.

``python -m repro.core.schedcheck`` sweeps every supported arch × layout
× ``expert_stream`` from ``weight_stream_support``'s set and exits
non-zero on any violation — the CI matrix step.

The analyzers duck-type the plan (only the byte model and group/unit
tuples are read), so this module imports no sibling at module scope and
stays import-cycle-free.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import zlib
from collections import OrderedDict
from typing import Any, Iterable, Optional, Sequence

__all__ = [
    "HazardError",
    "HazardSanitizer",
    "PhasePeak",
    "ScheduleError",
    "ScheduleReport",
    "ScheduleViolation",
    "analyze_serve_schedule",
    "analyze_train_schedule",
    "sanitize_enabled",
    "tree_fingerprint",
    "verify_schedule",
]


def sanitize_enabled(default: bool = False) -> bool:
    """Whether ``REPRO_SANITIZE`` asks for the runtime hazard sanitizer."""
    v = os.environ.get("REPRO_SANITIZE")
    if v is None:
        return default
    return v.strip().lower() not in ("", "0", "false", "no")


class HazardError(RuntimeError):
    """A runtime transfer-hazard the sanitizer refuses to let proceed.

    Deliberately NOT a transient fault: the engine's retry loop must never
    swallow one (a hazard retried is a hazard hidden)."""


@dataclasses.dataclass(frozen=True)
class ScheduleViolation:
    rule: str  # "budget" | "raw-writeback" | "pin-overcommit" | ...
    phase: str
    index: int
    key: str
    message: str
    occupancy_bytes: int = 0
    budget_bytes: int = 0

    def __str__(self) -> str:
        loc = f"{self.phase}[{self.index}] {self.key}".rstrip()
        return f"{self.rule} @ {loc}: {self.message}"


@dataclasses.dataclass(frozen=True)
class PhasePeak:
    phase: str
    n_points: int  # program points measured (submits + consumes)
    peak_bytes: int
    at_index: int
    at_key: str


@dataclasses.dataclass
class ScheduleReport:
    kind: str  # "train" | "serve"
    name: str
    layout: str
    distance: int
    budget_bytes: Optional[int]
    cache_capacity_bytes: Optional[int]
    cached: bool
    phases: list = dataclasses.field(default_factory=list)
    violations: list = dataclasses.field(default_factory=list)
    notes: list = dataclasses.field(default_factory=list)
    n_spill_keys: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def peak_bytes(self) -> int:
        return max((p.peak_bytes for p in self.phases), default=0)

    def __str__(self) -> str:
        mb = lambda b: "unbounded" if b is None else f"{b / 1e6:.2f}MB"  # noqa: E731
        lines = [
            f"schedule[{self.kind}] {self.name}: layout={self.layout} "
            f"distance={self.distance} budget={mb(self.budget_bytes)} "
            f"cache={mb(self.cache_capacity_bytes) if self.cached else 'off'}"
        ]
        for p in self.phases:
            lines.append(
                f"  {p.phase:<9s} {p.n_points:4d} points  "
                f"peak {p.peak_bytes / 1e6:8.2f}MB  at {p.at_key}"
            )
        for n in self.notes:
            lines.append(f"  note: {n}")
        if self.n_spill_keys:
            lines.append(f"  spill keys: {self.n_spill_keys} unique")
        if self.violations:
            lines.append(f"  VIOLATIONS ({len(self.violations)}):")
            for v in self.violations:
                lines.append(f"    - {v}")
        else:
            lines.append("  OK: occupancy, hazards, pins verified")
        return "\n".join(lines)


class ScheduleError(RuntimeError):
    """Static verification failed; ``.report`` holds the full analysis."""

    def __init__(self, report: ScheduleReport) -> None:
        super().__init__(str(report))
        self.report = report


# --------------------------------------------------------------------------
# residency-cache simulator — mirrors core.residency.ResidencyCache exactly:
# OrderedDict LRU, put on an existing key touches + widens the pin without
# re-inserting bytes, eviction walks LRU order skipping pinned entries and
# refuses the put when only pinned entries remain.
class _CacheSim:
    def __init__(self, capacity_bytes: Optional[int]) -> None:
        self.capacity_bytes = capacity_bytes
        self._entries: "OrderedDict[str, list]" = OrderedDict()  # key -> [nbytes, pinned]
        self.resident_bytes = 0

    def lookup(self, key: str) -> bool:
        e = self._entries.get(key)
        if e is None:
            return False
        self._entries.move_to_end(key)
        return True

    def peek(self, key: str) -> bool:
        return key in self._entries

    def put(self, key: str, nbytes: int, *, pinned: bool = False) -> bool:
        e = self._entries.get(key)
        if e is not None:
            self._entries.move_to_end(key)
            e[1] = e[1] or pinned
            return True
        cap = self.capacity_bytes
        if cap is not None:
            # refusal must leave the cache untouched (ResidencyCache sizes
            # the eviction set before dropping anything)
            evictable = [k for k, v in self._entries.items() if not v[1]]
            spare = cap - self.resident_bytes
            i = 0
            while spare < nbytes and i < len(evictable):
                spare += self._entries[evictable[i]][0]
                i += 1
            if spare < nbytes:
                return False
            for k in evictable[:i]:
                self.resident_bytes -= self._entries.pop(k)[0]
        self._entries[key] = [nbytes, pinned]
        self.resident_bytes += nbytes
        return True

    def keys(self) -> list:
        return list(self._entries)

    def unpin_all(self) -> None:
        for e in self._entries.values():
            e[1] = False


# --------------------------------------------------------------------------
# phase simulator: replays HostStreamExecutor.run over one fetch order.


class _PhaseSim:
    """Symbolic executor for one streamed phase.

    Occupancy components tracked per program point (after every submit and
    every consume, exactly where the engine's live-byte gauge moves):

    - ``alive``: fetched-but-not-retired group bytes.  A group retires one
      stage after its unit's compute consumed it (the previous stage's
      buffers are still referenced while the next stage lands) — the same
      ``distance + 2`` shape the fast-path window model bounds.
    - residency-cache bytes (``_CacheSim``), minus the overlap with alive
      fetches: a consumed group cached in place reduces its alive residual
      to fetch − home bytes (the tied-embed borrow is the residual on the
      head group).
    - a constant baseline (KV hot-page reservation for serve).
    """

    def __init__(
        self,
        report: ScheduleReport,
        phase: str,
        *,
        cache: Optional[_CacheSim],
        budget_bytes: Optional[int],
        baseline_bytes: int = 0,
    ) -> None:
        self.report = report
        self.phase = phase
        self.cache = cache
        self.budget = budget_bytes
        self.baseline = baseline_bytes
        self.alive: "OrderedDict[int, int]" = OrderedDict()  # gindex -> bytes
        self.pending_wb: dict = {}  # key -> count
        self.n_points = 0
        self.peak = 0
        self.peak_at = (-1, "")
        self.transient = 0  # stage-local extra bytes (expert fan-in)

    def occupancy(self) -> int:
        cache_bytes = self.cache.resident_bytes if self.cache else 0
        return (
            self.baseline
            + self.transient
            + cache_bytes
            + sum(self.alive.values())
        )

    def measure(self, index: int, key: str) -> None:
        occ = self.occupancy()
        self.n_points += 1
        if occ > self.peak:
            self.peak, self.peak_at = occ, (index, key)
        if self.budget is not None and occ > self.budget:
            self.report.violations.append(
                ScheduleViolation(
                    "budget",
                    self.phase,
                    index,
                    key,
                    f"device occupancy {occ / 1e6:.2f}MB exceeds budget "
                    f"{self.budget / 1e6:.2f}MB "
                    f"(window {sum(self.alive.values()) / 1e6:.2f}MB + cache "
                    f"{(self.cache.resident_bytes if self.cache else 0) / 1e6:.2f}MB"
                    + (
                        f" + reserved {self.baseline / 1e6:.2f}MB"
                        if self.baseline
                        else ""
                    )
                    + ")",
                    occupancy_bytes=occ,
                    budget_bytes=self.budget,
                )
            )

    # -- engine events -----------------------------------------------------
    def submit(self, g, fetch_bytes: int, key: str) -> None:
        if key in self.pending_wb:
            self.report.violations.append(
                ScheduleViolation(
                    "raw-writeback",
                    self.phase,
                    g.index,
                    key,
                    "H2D fetch submitted while a D2H writeback of the same "
                    "group is still in flight (drain before re-fetching)",
                )
            )
        self.alive[g.index] = fetch_bytes
        self.measure(g.index, key)

    def writeback(self, key: str) -> None:
        self.pending_wb[key] = self.pending_wb.get(key, 0) + 1

    def drain(self) -> None:
        self.pending_wb.clear()

    def retire(self, gindex: int) -> None:
        self.alive.pop(gindex, None)

    def finish(self) -> None:
        self.drain()
        self.alive.clear()
        self.transient = 0
        idx, key = self.peak_at
        self.report.phases.append(
            PhasePeak(self.phase, self.n_points, self.peak, idx, key)
        )


def _unit_of(plan) -> tuple:
    """Map group index -> unit id, plus unit id -> member count."""
    out = {0: 0}  # embed is its own stage
    size = {0: 1}
    uid = 1
    for u in plan.units:
        for gi in u.gidx:
            out[gi] = uid
        size[uid] = len(u.gidx)
        uid += 1
    out[plan.groups[-1].index] = uid  # head
    size[uid] = 1
    return out, size


def _run_phase(
    plan,
    order: Sequence,
    sim: _PhaseSim,
    *,
    distance: int,
    pin_keys: Iterable[str] = (),
    cache_puts: bool = True,
    writeback: bool = False,
    moe_fan: Optional[int] = None,
    units: bool = True,
) -> None:
    """Replay the executor loop over ``order`` (a sequence of groups).

    Top-up submits to ``i + distance``; consume applies stage ``i``; the
    groups of the *previous completed unit* retire when the next unit's
    compute is issued (a ``moe`` unit's stage fires once all its members
    are consumed, whichever direction the order walks them).
    ``units=False`` treats every group as its own stage (the decode
    program fetches one leading group per unit).  ``moe_fan`` (decode)
    adds the routed expert fan-in as a stage transient on each
    unit-leading layers group.
    """
    pin_keys = set(pin_keys)
    unit_of, unit_size = _unit_of(plan)
    n = len(order)
    submitted = 0
    fetch_hit: dict = {}  # gindex -> cache hit at submit time
    consumed: dict = {}  # unit id -> members consumed so far
    prev_unit_groups: list = []
    cur_unit_groups: list = []

    def _submit(j: int) -> None:
        g = order[j]
        hit = sim.cache.lookup(g.key) if sim.cache else False
        fetch_hit[g.index] = hit
        if hit:
            nbytes = 0
        elif (
            g.kind == "head"
            and sim.cache
            and getattr(plan, "head_reads_embed", False)
            and sim.cache.peek(plan.groups[0].key)
        ):
            # tied head with the embed group resident: the table re-read
            # is served from the cached embed tree, only home bytes move
            nbytes = plan.head_home_bytes
        else:
            nbytes = plan.group_bytes(g, fetch=True)
        sim.submit(g, nbytes, g.key)

    for i in range(n):
        while submitted <= min(i + distance, n - 1):
            _submit(submitted)
            submitted += 1
        g = order[i]
        uid = unit_of[g.index]
        cur_unit_groups.append(g.index)
        consumed[uid] = consumed.get(uid, 0) + 1
        if sim.cache and cache_puts:
            home = plan.group_bytes(g, fetch=False)
            if sim.cache.put(g.key, home, pinned=g.key in pin_keys):
                if not fetch_hit.get(g.index, False):
                    # the cached tree IS the landed tree: only the
                    # non-cacheable residual (head's table borrow) stays
                    # attributed to the stream window
                    sim.alive[g.index] = max(
                        0, sim.alive.get(g.index, 0) - home
                    )
        if writeback:
            sim.writeback(g.key)
        if not units or consumed[uid] >= unit_size[uid]:
            # unit compute issued: the previous unit's buffers retire
            if moe_fan is not None and g.kind == "layers":
                sim.transient = moe_fan * plan.per_expert_bytes
            for gi in prev_unit_groups:
                sim.retire(gi)
            prev_unit_groups, cur_unit_groups = cur_unit_groups, []
            sim.measure(g.index, g.key)
            sim.transient = 0
    sim.finish()


def _default_pin_keys(plan, bwd_order, capacity: Optional[int]) -> list:
    """The pin prefix ``make_weight_streamed_train_step`` constructs: the
    first backward groups whose home bytes fit the cache capacity (an
    unbounded cache pins them all)."""
    keys, total = [], 0
    for g in bwd_order:
        nb = plan.group_bytes(g, fetch=False)
        if capacity is not None and total + nb > capacity:
            break
        keys.append(g.key)
        total += nb
    return keys


def _check_spill_keys(plan, report: ScheduleReport) -> None:
    keys = [plan.spill_key(g) for g in plan.groups]
    keys += [f"wopt/{g.key}" for g in plan.groups]
    seen: set = set()
    for k in keys:
        if k in seen:
            report.violations.append(
                ScheduleViolation(
                    "spill-key-collision",
                    "spill",
                    -1,
                    k,
                    "two groups map to the same spill-store key",
                )
            )
        seen.add(k)
    report.n_spill_keys = len(seen)


def _check_pins(plan, pin_keys, capacity, report: ScheduleReport) -> None:
    by_key = {g.key: g for g in plan.groups}
    total = 0
    for k in pin_keys:
        g = by_key.get(k)
        if g is None:
            report.violations.append(
                ScheduleViolation(
                    "pin-unknown-key", "pins", -1, k,
                    "pin key names no group in the plan",
                )
            )
            continue
        total += plan.group_bytes(g, fetch=False)
    if capacity is not None and total > capacity:
        report.violations.append(
            ScheduleViolation(
                "pin-overcommit",
                "pins",
                -1,
                ",".join(pin_keys),
                f"pinned home bytes {total / 1e6:.2f}MB exceed cache "
                f"capacity {capacity / 1e6:.2f}MB — the backward turnaround "
                "cannot keep its groups resident",
            )
        )


def analyze_train_schedule(
    plan,
    *,
    distance: int,
    cached: bool = True,
    cache_capacity: Optional[int] = None,
    budget_bytes: Optional[int] = None,
    spill: bool = False,
    pin_keys: Optional[Sequence[str]] = None,
) -> ScheduleReport:
    """Symbolically execute the streamed train step's three phases.

    Forward walks ``plan.groups`` in fetch order; backward walks the
    middle groups reversed then the embed group; the optimizer phase walks
    head + backward order with a D2H writeback per group (hazard-checked,
    not budget-checked — optimizer residency is accounted by its own
    stats, matching the runtime's budget convention)."""
    if budget_bytes is None:
        budget_bytes = getattr(plan, "device_budget_bytes", None)
    report = ScheduleReport(
        kind="train",
        name=getattr(getattr(plan, "cfg", None), "name", "?"),
        layout=plan.layout,
        distance=distance,
        budget_bytes=budget_bytes,
        cache_capacity_bytes=cache_capacity if cached else None,
        cached=cached,
    )
    groups = list(plan.groups)
    bwd_order = list(reversed(groups[1:-1])) + [groups[0]]
    o_order = [groups[-1]] + bwd_order
    cache = _CacheSim(cache_capacity) if cached else None
    if pin_keys is None:
        pin_keys = _default_pin_keys(plan, bwd_order, cache_capacity) if cached else []
    _check_pins(plan, pin_keys, cache_capacity if cached else None, report)

    sim = _PhaseSim(report, "forward", cache=cache, budget_bytes=budget_bytes)
    _run_phase(plan, groups, sim, distance=distance, pin_keys=pin_keys)
    sim = _PhaseSim(report, "backward", cache=cache, budget_bytes=budget_bytes)
    _run_phase(plan, bwd_order, sim, distance=distance, pin_keys=pin_keys)
    # optimizer: hazard + refresh coverage only (budget convention: the
    # F+B stream peak is what --device-budget-mb bounds; optimizer state
    # is reported separately by opt_stats)
    sim = _PhaseSim(report, "optimizer", cache=cache, budget_bytes=None)
    _run_phase(plan, o_order, sim, distance=distance, writeback=True)
    if cache is not None:
        refreshed = {g.key for g in o_order}
        for k in cache.keys():
            if k not in refreshed:
                report.violations.append(
                    ScheduleViolation(
                        "stale-residency",
                        "optimizer",
                        -1,
                        k,
                        "cached device copy not refreshed by the optimizer "
                        "writeback — later hits would read pre-update weights",
                    )
                )
        cache.unpin_all()
    if spill:
        _check_spill_keys(plan, report)
    return report


def analyze_serve_schedule(
    plan,
    *,
    distance: int,
    cached: bool = True,
    cache_capacity: Optional[int] = None,
    budget_bytes: Optional[int] = None,
    route_experts: bool = True,
    fan_in: Optional[int] = None,
    kv: Optional[dict] = None,
    flush_demotions: bool = True,
) -> ScheduleReport:
    """Symbolically execute prefill + steady-state decode (+ KV paging).

    ``kv`` describes the paged cache: ``dict(slots=, page_len=,
    hot_pages=, page_nbytes=, max_len=)``.  The hot-page reservation
    (``slots × (hot_pages + 2) × page_nbytes`` — the split ``ServeSession``
    carves off the budget) is a constant occupancy baseline for both
    phases; the page schedule itself is replayed per decode step to check
    per-slot hot residency and demotion/readmit RAW ordering
    (``flush_demotions=False`` models a pager that readmits without
    draining — the seeded-hazard configuration)."""
    if budget_bytes is None:
        budget_bytes = getattr(plan, "device_budget_bytes", None)
    report = ScheduleReport(
        kind="serve",
        name=getattr(getattr(plan, "cfg", None), "name", "?"),
        layout=plan.layout,
        distance=distance,
        budget_bytes=budget_bytes,
        cache_capacity_bytes=cache_capacity if cached else None,
        cached=cached,
    )
    hot_reserved = 0
    if kv:
        hot_reserved = int(
            kv["slots"] * (kv["hot_pages"] + 2) * kv["page_nbytes"]
        )
        if budget_bytes is not None and hot_reserved >= budget_bytes:
            report.violations.append(
                ScheduleViolation(
                    "kv-budget",
                    "kv",
                    -1,
                    f"slots={kv['slots']} hot_pages={kv['hot_pages']}",
                    f"hot-page reservation {hot_reserved / 1e6:.2f}MB "
                    f"consumes the whole budget "
                    f"{budget_bytes / 1e6:.2f}MB — nothing left for the "
                    "weight stream (lower --hot-pages / --param-cache-mb "
                    "or raise --device-budget-mb)",
                    occupancy_bytes=hot_reserved,
                    budget_bytes=budget_bytes,
                )
            )
    cache = _CacheSim(cache_capacity) if cached else None

    sim = _PhaseSim(
        report, "prefill", cache=cache, budget_bytes=budget_bytes,
        baseline_bytes=hot_reserved,
    )
    _run_phase(plan, list(plan.groups), sim, distance=distance)

    # steady-state decode program: embed, one leading group per unit
    # (router-first for moe), head.  Routed decode fetches only the top-k
    # experts per slot — the fan-in is a stage transient on the unit.
    groups = plan.groups
    prog = [groups[0]] + [groups[u.gidx[0]] for u in plan.units] + [groups[-1]]
    fan = None
    if plan.expert_stream:
        E = plan.cfg.n_experts
        if not route_experts:
            fan = E
        elif fan_in is not None:
            fan = min(E, fan_in)
        else:
            slots = kv["slots"] if kv else 1
            fan = min(E, max(1, getattr(plan.cfg, "moe_top_k", 2)) * slots)
        report.notes.append(
            f"expert fan-in per moe stage: {fan}/{E} experts "
            f"({fan * plan.per_expert_bytes / 1e6:.2f}MB transient)"
        )
    sim = _PhaseSim(
        report, "decode", cache=cache, budget_bytes=budget_bytes,
        baseline_bytes=hot_reserved,
    )
    _run_phase(plan, prog, sim, distance=distance, moe_fan=fan, units=False)

    if kv:
        _run_kv_pages(kv, report, flush_demotions=flush_demotions)
    return report


def _run_kv_pages(kv: dict, report: ScheduleReport, *, flush_demotions: bool) -> None:
    """Replay the pager's per-step page schedule: pages older than the hot
    window demote D2H; a page H2D-fetched (readmit) while its demotion
    writeback still pends is a RAW hazard; per-slot device pages must stay
    within ``hot_pages + 2`` (hot window + landing + draining)."""
    page_len = max(1, int(kv["page_len"]))
    hot = int(kv["hot_pages"])
    slots = int(kv["slots"])
    max_len = int(kv.get("max_len", page_len * (hot + 3)))
    wb_pending: set = set()
    device: dict = {s: set() for s in range(slots)}
    n_steps = 0
    for t in range(max_len):
        cur = t // page_len
        for s in range(slots):
            if cur not in device[s]:
                key = f"kv/s{s}/p{cur}"
                if key in wb_pending:
                    report.violations.append(
                        ScheduleViolation(
                            "kv-raw",
                            "kv",
                            t,
                            key,
                            "page readmitted H2D while its demotion "
                            "writeback is still in flight (drain the "
                            "demotion queue before readmission)",
                        )
                    )
                device[s].add(cur)
            floor = cur - hot
            for p in [p for p in device[s] if p < floor]:
                device[s].discard(p)
                wb_pending.add(f"kv/s{s}/p{p}")
            if len(device[s]) > hot + 2:
                report.violations.append(
                    ScheduleViolation(
                        "kv-residency",
                        "kv",
                        t,
                        f"slot {s}",
                        f"{len(device[s])} device pages exceed the "
                        f"hot_pages + 2 = {hot + 2} reservation",
                    )
                )
        n_steps += 1
        if flush_demotions:
            wb_pending.clear()
    # a readmit cycle after generation: every resident page demotes, then
    # the slot re-reads them (the evict → readmit path).  With unflushed
    # demotions this is the RAW the sanitizer also catches at runtime.
    for s in range(slots):
        for p in list(device[s]):
            device[s].discard(p)
            wb_pending.add(f"kv/s{s}/p{p}")
        if flush_demotions:
            wb_pending.clear()
        for p in range(max(0, max_len - 1) // page_len - hot, max_len // page_len):
            key = f"kv/s{s}/p{p}"
            if key in wb_pending:
                report.violations.append(
                    ScheduleViolation(
                        "kv-raw", "kv", max_len, key,
                        "readmit of an evicted slot re-fetches a page whose "
                        "demotion writeback was never drained",
                    )
                )
                wb_pending.discard(key)
    report.notes.append(
        f"kv pages: {n_steps} steps, {slots} slots, "
        f"hot window {hot}+2 pages/slot verified"
    )


def verify_schedule(report: ScheduleReport) -> ScheduleReport:
    """Raise :class:`ScheduleError` if the analysis found violations."""
    if not report.ok:
        raise ScheduleError(report)
    return report


# --------------------------------------------------------------------------
# runtime hazard sanitizer


def tree_fingerprint(tree: Any) -> tuple:
    """A cheap identity+content mark for a host-homed group tree: per leaf
    ``(id, shape, dtype, crc32 of the first 64 elements)``.  Identity
    catches in-place rebinding (restart without cache invalidation);
    the CRC catches mutation of the same buffer."""
    import numpy as np

    try:
        import jax

        leaves = jax.tree_util.tree_leaves(tree)
    except Exception:  # pragma: no cover - jax always present in-repo
        leaves = [tree]
    marks = []
    for x in leaves:
        shape = tuple(getattr(x, "shape", ()))
        dtype = str(getattr(x, "dtype", type(x).__name__))
        try:
            arr = np.asarray(x).reshape(-1)[:64]
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        except Exception:
            crc = 0
        marks.append((id(x), shape, dtype, crc))
    return tuple(marks)


class HazardSanitizer:
    """Dynamic counterpart of the static analyzer: records a
    happens-before edge per ticket and asserts, at each engine call, the
    same invariants the analyzer proves over the whole program.

    Thread-safe (transfer callbacks land off the compute thread).  Keys
    are caller-provided logical names (group keys, spill chunks, KV
    pages); ``key=None`` transfers are unchecked — exactly the transfers
    the static analyzer cannot name either."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._pending_wb: dict = {}  # key -> in-flight writeback count
        self._staging_marked: set = set()  # buffer ids currently acquired
        self.checks = 0
        self.hazards = 0

    # -- transfer ordering -------------------------------------------------
    def on_fetch(self, key: Optional[str]) -> None:
        if key is None:
            return
        with self._lock:
            self.checks += 1
            if self._pending_wb.get(key, 0) > 0:
                self.hazards += 1
                raise HazardError(
                    f"sanitizer: H2D fetch of {key!r} while {self._pending_wb[key]} "
                    "D2H writeback(s) of the same group are in flight — "
                    "drain_writebacks() must complete before re-fetching"
                )

    def on_writeback(self, key: Optional[str]) -> None:
        if key is None:
            return
        with self._lock:
            self._pending_wb[key] = self._pending_wb.get(key, 0) + 1

    def on_drained(self, keys: Iterable[Optional[str]]) -> None:
        with self._lock:
            for key in keys:
                if key is None:
                    continue
                n = self._pending_wb.get(key, 0) - 1
                if n > 0:
                    self._pending_wb[key] = n
                else:
                    self._pending_wb.pop(key, None)

    # -- staging pool lifetime --------------------------------------------
    def on_staging_acquire(self, buf_id: int, *, from_pool: bool) -> None:
        with self._lock:
            self.checks += 1
            if from_pool and buf_id in self._staging_marked:
                self.hazards += 1
                raise HazardError(
                    f"sanitizer: staging buffer {buf_id:#x} reacquired from "
                    "the free list while its previous ticket is still in "
                    "flight (released before block_until_ready?)"
                )
            self._staging_marked.add(buf_id)

    def on_staging_release(self, buf_id: int) -> None:
        with self._lock:
            if buf_id not in self._staging_marked:
                self.hazards += 1
                raise HazardError(
                    f"sanitizer: staging buffer {buf_id:#x} released twice "
                    "(or released without a matching acquire)"
                )
            self._staging_marked.discard(buf_id)


# --------------------------------------------------------------------------
# CI sweep: every supported config × layout × expert_stream


def _sweep() -> int:  # pragma: no cover - exercised by CI, not pytest
    import jax

    from repro.configs import ARCHS, get_smoke_config
    from repro.core.weightstream import WeightStreamPlan, weight_stream_support
    from repro.train.steps import abstract_params

    failures = 0
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        support = weight_stream_support(cfg)
        if not support.supported:
            print(f"schedcheck: {arch}: skipped ({support.reason})")
            continue
        variants = [False]
        if support.layout == "uniform" and getattr(cfg, "n_experts", 0):
            variants.append(True)
        for expert_stream in variants:
            params = abstract_params(cfg)
            base = WeightStreamPlan(
                cfg, params, expert_stream=expert_stream
            )
            budget_mb = base.peak_device_bytes(2) / 1e6
            for dbm in (None, budget_mb):
                plan = WeightStreamPlan(
                    cfg, params, device_budget_mb=dbm,
                    expert_stream=expert_stream,
                )
                d = plan.max_distance_for_budget()
                cache_cap = plan.residency_capacity_bytes()
                rep = analyze_train_schedule(
                    plan, distance=d, cache_capacity=cache_cap, spill=True
                )
                tag = (
                    f"{arch} expert_stream={int(expert_stream)} "
                    f"budget={'none' if dbm is None else f'{dbm:.2f}MB'}"
                )
                if not rep.ok:
                    failures += 1
                    print(f"schedcheck: FAIL train {tag}\n{rep}")
                else:
                    print(
                        f"schedcheck: ok train {tag} layout={plan.layout} "
                        f"d={d} peak={rep.peak_bytes / 1e6:.2f}MB"
                    )
                if support.serve_supported:
                    srep = analyze_serve_schedule(
                        plan, distance=d, cache_capacity=cache_cap
                    )
                    if not srep.ok:
                        failures += 1
                        print(f"schedcheck: FAIL serve {tag}\n{srep}")
                    else:
                        print(
                            f"schedcheck: ok serve {tag} "
                            f"peak={srep.peak_bytes / 1e6:.2f}MB"
                        )
    del jax
    return failures


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(1 if _sweep() else 0)

"""Paged hierarchical KV cache — the serving path's memory-kind consumer.

The paper's claim ("compute with data sets of arbitrarily large size" §3.1)
applied to decode: each request's KV cache is split along the context axis
into fixed-size **pages** (``page_len`` tokens, all layers — one transfer
group each).  Only a *hot window* — the page currently being written plus
the last ``hot_pages`` full pages — is device-resident between steps; cold
pages live at their home kind:

  ``Device``      pages stay ``jax.Array``s (nothing ever moves),
  ``PinnedHost``  host numpy trees (DMA-reachable DRAM),
  ``DiskHost``    :class:`repro.core.spillstore.SpillStore` memmap chunks
                  (one page group = one chunk file = one disk request).

Per decode step the :class:`PageStream` fetches every cold page of every
active request through the :class:`~repro.core.engine.TransferEngine` —
coalesced (one H2D request per page group), pipelined ahead of consumption
under a **per-request** :class:`~repro.core.engine.AdaptiveDistance`
window (``distance="auto"``), and speculatively prefetched for step ``t+1``
while step ``t``'s decode computes.  Pages crossing out of the hot window
are written back through the engine's pipelined D2H drain and re-homed.

The dense cache view the decode step consumes is rebuilt per step by
:func:`assemble_view` — a *separate* jit from the decode executable, so
paged decode runs the exact same program as unpaged decode and the two are
bitwise-equal by construction (pinned in ``tests/test_serve.py``); the
device only ever *retains* the hot window (``device_resident_bytes``),
which is how host/disk-homed caches decode contexts larger than the device
budget.

**Copy-on-write prefix sharing.**  A KV page strictly behind the write
position is immutable, and its content is a pure causal function of the
prompt prefix that produced it — so requests whose prompts share a
page-aligned prefix (the shared-system-prompt shape) can alias one cold
copy.  ``admit(..., prefix_keys=...)`` attaches refcounted
:class:`SharedPage` records keyed by *content digest* instead of by
``rid``: the first demotion writes the one host/spill chunk, later
demotions of aliasing records just drop their device reference, and the
per-step fetch is deduplicated by content key in :class:`PageStream`
(``stats.shared_hits``) — one fetch and one spill chunk per shared page
for the whole batch.  ``retire`` drops the chunk only at the last
reference.  Sharing never changes what the decode step reads, so every
schedule stays bitwise-equal to the unshared baseline.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import memkind as mk
from repro.core.engine import AdaptiveDistance, TransferEngine
from repro.core.hoststream import StreamStats
from repro.core.refspec import AUTO

__all__ = [
    "KVPagerConfig",
    "PageRecord",
    "PageTable",
    "PageStream",
    "SharedPage",
    "KVPager",
    "assemble_view",
    "page_template",
    "paged_cache_supported",
    "shared_prefix_keys",
]

Pytree = Any


# ---------------------------------------------------------------------------
# page math helpers
# ---------------------------------------------------------------------------


def _time_axis(leaf) -> int:
    """Context axis of a k/v cache leaf: (B, T, K, H) or stacked
    (L, B, T, K, H) — always third from the right + 1 head dims."""
    return np.ndim(leaf) - 3


def _batch_axis(leaf) -> int:
    return np.ndim(leaf) - 4


def paged_cache_supported(cache_template: Pytree) -> bool:
    """True iff every cache leaf is a pageable full-attention k/v tensor.

    Ring buffers (``slot_pos`` shared across the batch) and recurrent
    states (no context axis) cannot be paged along the context dimension;
    serving falls back to the unpaged path for those archs.
    """
    flat = jax.tree_util.tree_flatten_with_path(cache_template)[0]
    if not flat:
        return False
    for path, leaf in flat:
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        if name not in ("k", "v") or np.ndim(leaf) < 4:
            return False
    return True


def page_template(cache_template: Pytree, page_len: int) -> Pytree:
    """Abstract tree of ONE page: the cache template with its context axis
    cut to ``page_len`` (the shape the sharding rules — and the engine's
    per-device layouts — see for every transfer group)."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            tuple(
                page_len if d == _time_axis(l) else s
                for d, s in enumerate(l.shape)
            ),
            l.dtype,
        ),
        cache_template,
    )


def assemble_view(view) -> Pytree:
    """Concatenate a per-slot page view into the dense cache tree.

    ``view``: tuple (over batch slots) of tuples (over pages) of page
    pytrees.  Pages concatenate along the context axis, slots along the
    batch axis.  Pure concatenation — bit-exact reconstruction of the
    unpaged cache tensor.
    """
    slots = [
        jax.tree.map(lambda *ps: jnp.concatenate(ps, axis=_time_axis(ps[0])), *pages)
        for pages in view
    ]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=_batch_axis(xs[0])), *slots)


def shared_prefix_keys(prompt, page_len: int, shared_len: Optional[int] = None) -> list[str]:
    """Content-digest fetch/spill keys for the COW-shareable pages of a
    prompt: one key per page *fully covered* by the (shared prefix of the)
    prompt.

    KV content at position ``t`` is a pure causal function of tokens
    ``[0, t]``, so page ``p`` (tokens ``[pL, (p+1)L)``) is determined by
    ``prompt[:(p+1)L]`` — that prefix is what gets hashed.  Two requests
    produce the same key for page ``p`` iff their prompts agree on the
    first ``(p+1)*page_len`` tokens, which is exactly when their KV pages
    are bitwise-identical.  ``shared_len`` optionally caps keying to a
    known shared-prefix length (e.g. the system prompt) so private tails
    never enter the shared registry.
    """
    toks = np.asarray(prompt, np.int32).reshape(-1)
    n = len(toks) if shared_len is None else min(len(toks), int(shared_len))
    keys = []
    for p in range(n // page_len):
        digest = hashlib.sha1(toks[: (p + 1) * page_len].tobytes()).hexdigest()[:20]
        keys.append(f"kvshared/L{page_len}/{digest}")
    return keys


# ---------------------------------------------------------------------------
# configuration / page table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVPagerConfig:
    """Paging knobs for one serving session."""

    #: tokens per page (all layers of one page = one transfer group)
    page_len: int = 32
    #: full pages kept device-resident behind the write position (the hot
    #: attention window; the partially-written current page is always hot)
    hot_pages: int = 1
    #: home kind of cold pages (device | pinned_host | disk_host)
    kind: Union[mk.MemKind, str] = mk.DEVICE
    #: per-request in-flight fetch window: an int, or ``"auto"`` for a
    #: per-request AdaptiveDistance controller
    distance: Union[int, str] = AUTO
    min_distance: int = 1
    max_distance: int = 8
    wait_eps_s: float = 100e-6
    shrink_after: int = 4

    def __post_init__(self) -> None:
        if self.page_len < 1:
            raise ValueError("page_len must be >= 1")
        if self.hot_pages < 0:
            raise ValueError("hot_pages must be >= 0")


#: page residency states
_DEVICE, _COLD, _WB, _ZERO = "device", "cold", "wb", "zero"


@dataclasses.dataclass
class SharedPage:
    """One content-addressed cold page, aliased copy-on-write by every
    request whose prompt contains the same page-aligned prefix.

    The cold home copy (host tree / spill chunk) lives *here*, keyed by
    content digest instead of by ``rid``; per-request :class:`PageRecord`
    entries reference it and the last ``retire`` drops the chunk.  Pages
    behind the write position are never mutated, so aliasing is safe by
    construction — a decode step reads identical bytes whether the page
    came from its own spill chunk or a shared one.
    """

    key: str
    refs: int = 0
    host: Optional[Pytree] = None
    #: a writeback for this content is already in the engine's D2H queue:
    #: later demotions of aliasing records drop their device copy instead
    #: of queueing a duplicate writeback
    wb_pending: bool = False


@dataclasses.dataclass
class PageRecord:
    """One page's residency: device-resident pytree, cold home pytree
    (numpy / spill-store memmaps), in-flight writeback, or still-zero.
    ``shared`` aliases the cold home to a refcounted content-keyed
    :class:`SharedPage` (COW prefix sharing); the cold copy then lives on
    the shared record and ``host`` stays ``None``."""

    state: str = _ZERO
    dev: Optional[Pytree] = None
    host: Optional[Pytree] = None
    shared: Optional[SharedPage] = None


@dataclasses.dataclass
class PageTable:
    """Per-request page table: residency of every page of one request's
    cache, plus the next context position to write."""

    rid: int
    slot: Optional[int]  # batch slot; None while evicted
    pos: int  # next absolute position the decode step writes
    records: list[PageRecord]


# ---------------------------------------------------------------------------
# the fetch pipeline
# ---------------------------------------------------------------------------


class PageStream:
    """Pipelined cold-page fetcher over a :class:`TransferEngine`.

    Keys are the engine transfer keys (strings): per-request
    ``kv/{rid}/p{page}`` for private pages, content digests
    (``kvshared/...``) for COW-shared prefix pages.  ``push`` enqueues a
    key's group charged to an *owning* request (the first pusher; ``sync``
    re-assigns owners as requests retire); at most ``window(rid)`` groups
    per owner are submitted to the engine at once (the rest stay pending).
    ``pop`` waits the group's future, tops the windows back up, and returns
    the staged device tree; within one step, later pops of the *same* key
    (several requests aliasing one shared page) return the staged tree for
    free and count a ``stats.shared_hits`` instead of a fetch.  Under
    ``distance="auto"`` each request's :class:`AdaptiveDistance` controller
    observes the request's *per-step* aggregate stall (``step_done``), not
    per-group waits: a shrink that re-introduces a stall is then stalled on
    the very next observation, which is what arms the controller's sticky
    floor — per group, a clean in-window pop always lands between the
    shrink and the stall and the window oscillates forever.  Keys pushed
    speculatively for a step that never consumes them (the request finished
    or was evicted) are dropped by ``sync`` and counted.
    """

    def __init__(
        self,
        engine: TransferEngine,
        *,
        distance: Union[int, str] = AUTO,
        min_distance: int = 1,
        max_distance: int = 8,
        wait_eps_s: float = 100e-6,
        shrink_after: int = 4,
        device_shardings: Optional[Pytree] = None,
    ) -> None:
        self._engine = engine
        #: per-page placement (the serve plan's cache specs): the engine
        #: stages one buffer per addressable device per page group instead
        #: of falling back to default single-device placement
        self._shardings = device_shardings
        self._auto = distance == AUTO
        self._static = None if self._auto else max(1, int(distance))
        self._ctl_kw = dict(
            initial=min_distance,
            min_distance=min_distance,
            max_distance=max_distance,
            wait_eps_s=wait_eps_s,
            shrink_after=shrink_after,
        )
        self._controllers: dict[int, AdaptiveDistance] = {}
        self._pending: "OrderedDict[str, Pytree]" = OrderedDict()
        self._inflight: "OrderedDict[str, Any]" = OrderedDict()
        #: window accounting: each queued/in-flight key is charged to ONE
        #: request — the first pusher; ``sync`` re-assigns as owners retire
        self._owner: dict[str, int] = {}
        #: per-step memo of popped device trees: N sharers of one content
        #: key pay one fetch per step (cleared by ``step_done``)
        self._staged: dict[str, Pytree] = {}
        self._seq = 0
        #: per-request stall accumulated since the last ``step_done``
        self._step_waits: dict[int, float] = {}
        #: speculative pushes that were never consumed (waste metric)
        self.stale_drops = 0

    def window(self, rid: int) -> int:
        if not self._auto:
            return self._static
        ctl = self._controllers.get(rid)
        if ctl is None:
            ctl = self._controllers[rid] = AdaptiveDistance(**self._ctl_kw)
        return ctl.distance

    def _inflight_of(self, rid: int) -> int:
        return sum(1 for k in self._inflight if self._owner.get(k) == rid)

    def _submit(self, key: str, tree: Pytree):
        fut = self._engine.submit_group(
            self._seq, tree, device_shardings=self._shardings, key=key
        )
        self._seq += 1
        self._inflight[key] = fut
        return fut

    def _top_up(self) -> None:
        for key in list(self._pending):
            rid = self._owner.get(key)
            if self._inflight_of(rid) < self.window(rid):
                self._submit(key, self._pending.pop(key))

    def push(self, rid: int, key: str, tree: Pytree) -> None:
        if key in self._pending or key in self._inflight:
            return
        self._owner[key] = rid
        self._pending[key] = tree
        self._top_up()

    def pop(self, rid: int, key: str, tree: Pytree, stats: StreamStats) -> Pytree:
        staged = self._staged.get(key)
        if staged is not None:
            # an aliasing request already fetched this content this step
            stats.shared_hits += 1
            return staged
        fut = self._inflight.pop(key, None)
        if fut is None:
            # never prefetched (cold start / late table change): fetch now —
            # the paper's on-demand penalty, paid only at boundaries
            self._pending.pop(key, None)
            fut = self._submit(key, tree)
            self._inflight.pop(key)
        self._owner.pop(key, None)
        w = fut.wait()
        stats.n_transfers += 1
        stats.n_groups += 1
        stats.h2d_requests += fut.n_requests
        stats.bytes_h2d += fut.nbytes
        stats.disk_requests += fut.disk_requests
        stats.bytes_disk += fut.disk_nbytes
        stats.transfer_wait_s += w
        stats.wait_per_group.append(w)
        stats.disk_wait_s += fut.disk_wait_s
        stats.disk_wait_per_group.append(fut.disk_wait_s)
        stats.n_devices = max(stats.n_devices, fut.n_devices)
        stats.n_device_groups += fut.n_devices
        if fut.is_resident:
            stats.cache_hits += 1
        else:
            stats.cache_misses += 1
            stats.unique_group_fetches += 1
            stats.fetched_device_groups += fut.n_devices
        if self._auto:
            self._step_waits[rid] = self._step_waits.get(rid, 0.0) + w
        stats.distance_trace.append(self.window(rid))
        self._top_up()
        dev = fut.group()
        self._staged[key] = dev
        return dev

    def step_done(self) -> None:
        """Feed each request's controller its aggregate stall for the step
        just consumed (call after the step's pops, before the next
        ``push`` wave so the adapted window applies immediately), and
        release the step's staged shared trees."""
        self._staged.clear()
        if not self._auto:
            return
        for rid, w in self._step_waits.items():
            self.window(rid)  # ensure the controller exists
            self._controllers[rid].observe(w)
        self._step_waits.clear()
        self._top_up()

    def sync(self, valid: dict) -> None:
        """Drop queued/in-flight keys outside ``valid`` (stale speculation)
        and re-charge surviving keys to their current owners (``valid``
        maps key -> owning rid; a shared key outlives any one sharer).
        In-flight futures complete on the worker regardless; only the
        references are released."""
        for key in [k for k in self._pending if k not in valid]:
            del self._pending[key]
            self._owner.pop(key, None)
            self.stale_drops += 1
        for key in [k for k in self._inflight if k not in valid]:
            del self._inflight[key]
            self._owner.pop(key, None)
            self.stale_drops += 1
        for key, rid in valid.items():
            if key in self._pending or key in self._inflight:
                self._owner[key] = rid

    def forget(self, rid: int) -> None:
        """Release a finished request's controller state (the session
        serves unboundedly many requests; per-rid state must not grow
        with the request count)."""
        self._controllers.pop(rid, None)
        self._step_waits.pop(rid, None)


# ---------------------------------------------------------------------------
# the pager
# ---------------------------------------------------------------------------


class KVPager:
    """Per-request paged KV-cache manager over a batched decode cache.

    Owns the page tables of every live request, the residency state
    machine (hot device window / cold home kind / zero future pages), the
    fetch stream, and the demotion writebacks.  The serving loop drives it:

    ``admit``      split a prefilled per-slot cache into pages, demote the
                   pages behind the hot window to the home kind.
    ``prefetch``   push every cold page of every active request into the
                   stream (speculative for the next step; deduped).
    ``view``       pop this step's cold pages (waits only on groups the
                   window did not cover) and return the per-slot page view
                   for :func:`assemble_view` / the paged decode step.
    ``update_current`` re-slice each active slot's partially-written page
                   out of the decode step's cache output (the only page a
                   decode step mutates).
    ``advance``    after ``pos`` moves past a page boundary: demote pages
                   that fell out of the hot window (pipelined D2H).
    ``evict`` / ``readmit`` park a request's device pages at the host
                   (freeing its slot) and later resume it cold.
    """

    def __init__(
        self,
        cache_template: Pytree,
        config: KVPagerConfig,
        *,
        slots: int,
        engine: TransferEngine,
        store=None,
        device_shardings: Optional[Pytree] = None,
    ) -> None:
        """``cache_template``: abstract per-slot cache tree (batch dim 1,
        context dim = the padded maximum length, a multiple of
        ``page_len``).  ``device_shardings``: optional pytree (congruent
        with one page — see :func:`page_template`) of ``NamedSharding``s;
        fetched cold pages stage at these placements through the engine's
        sharding-aware coalescing (one H2D request per device per page
        group under ``--model-parallel``)."""
        if not paged_cache_supported(cache_template):
            raise ValueError(
                "paged KV serving requires a full-attention k/v cache "
                "(ring slot_pos / recurrent states cannot be paged)"
            )
        self.config = config
        self.kind = mk.as_kind(config.kind)
        self.slots = slots
        self.engine = engine
        self.store = store
        if self.kind == mk.DISK_HOST and store is None:
            raise ValueError("kind=disk_host requires a SpillStore")
        leaves = jax.tree.leaves(cache_template)
        self.max_len = leaves[0].shape[_time_axis(leaves[0])]
        if self.max_len % config.page_len != 0:
            raise ValueError(
                f"cache length {self.max_len} must be a multiple of "
                f"page_len {config.page_len}"
            )
        self.n_pages = self.max_len // config.page_len
        page_shapes = page_template(cache_template, config.page_len)
        self.page_nbytes = sum(
            int(np.prod(s.shape)) * s.dtype.itemsize
            for s in jax.tree.leaves(page_shapes)
        )
        self._zero_page = jax.jit(
            lambda: jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), page_shapes)
        )()
        self._split = jax.jit(self._split_fn)
        self._extract = jax.jit(self._extract_fn)
        self.tables: dict[int, PageTable] = {}
        self._by_slot: dict[int, PageTable] = {}
        self.stream = PageStream(
            engine,
            distance=config.distance,
            min_distance=config.min_distance,
            max_distance=config.max_distance,
            wait_eps_s=config.wait_eps_s,
            shrink_after=config.shrink_after,
            device_shardings=device_shardings,
        )
        self._wb_seq = 0
        self._pending_demotions: list[tuple[PageTable, int]] = []
        self.demoted_groups = 0
        self.peak_resident_bytes = 0
        #: content digest -> refcounted shared cold page (COW prefix sharing)
        self._shared: dict[str, SharedPage] = {}
        #: demotions satisfied by an existing shared cold copy — the COW
        #: spill win: D2H writebacks (and spill chunks) NOT paid because an
        #: aliasing request already homed the same content
        self.shared_skipped_writebacks = 0

    # -- jitted page plumbing ------------------------------------------------
    def _split_fn(self, cache_slot: Pytree) -> tuple:
        L = self.config.page_len

        def page(p):
            return jax.tree.map(
                lambda a: lax.slice_in_dim(
                    a, p * L, (p + 1) * L, axis=_time_axis(a)
                ),
                cache_slot,
            )

        return tuple(page(p) for p in range(self.n_pages))

    def _extract_fn(self, cache: Pytree, slot, start) -> Pytree:
        def leaf(a):
            starts = [jnp.zeros((), jnp.int32)] * a.ndim
            sizes = list(a.shape)
            starts[_batch_axis(a)] = slot
            sizes[_batch_axis(a)] = 1
            starts[_time_axis(a)] = start
            sizes[_time_axis(a)] = self.config.page_len
            return lax.dynamic_slice(a, starts, sizes)

        return jax.tree.map(leaf, cache)

    # -- page-table state machine --------------------------------------------
    def current_page(self, table: PageTable) -> int:
        return table.pos // self.config.page_len

    def _hot_floor(self, table: PageTable) -> int:
        return max(0, self.current_page(table) - self.config.hot_pages)

    def _page_key(self, rid: int, p: int) -> str:
        return f"kv/{rid}/p{p:05d}"

    def _fetch_key(self, table: PageTable, p: int) -> str:
        """Engine transfer/spill key of a page: the content digest for a
        COW-shared page (one key per content for the whole batch), the
        per-request key otherwise."""
        rec = table.records[p]
        if rec.shared is not None:
            return rec.shared.key
        return self._page_key(table.rid, p)

    @staticmethod
    def _cold_home(rec: PageRecord) -> Optional[Pytree]:
        """A cold page's home tree: the shared record's for aliased pages."""
        return rec.shared.host if rec.shared is not None else rec.host

    def admit(
        self,
        rid: int,
        slot: int,
        cache_slot: Pytree,
        n_tokens: int,
        prefix_keys: Optional[list[str]] = None,
    ) -> PageTable:
        """Install a freshly prefilled per-slot cache as a page table.
        Pages behind the hot window are demoted (caller flushes).

        ``prefix_keys`` (from :func:`shared_prefix_keys`): content keys for
        the leading pages fully covered by the prompt's shared prefix —
        those records alias the refcounted shared registry so the batch
        pays one spill chunk and one fetch per shared page.  Only pages
        strictly behind the write page are shareable (the current page is
        mutated by decode)."""
        pages = self._split(cache_slot)
        cur = n_tokens // self.config.page_len
        records = [
            PageRecord(_DEVICE, dev=pg) if p <= cur else PageRecord(_ZERO)
            for p, pg in enumerate(pages)
        ]
        if prefix_keys and self.kind != mk.DEVICE:
            for p, key in enumerate(prefix_keys):
                if p >= cur:
                    break
                sp = self._shared.get(key)
                if sp is None:
                    sp = self._shared[key] = SharedPage(key=key)
                sp.refs += 1
                records[p].shared = sp
        table = PageTable(rid=rid, slot=slot, pos=n_tokens, records=records)
        self.tables[rid] = table
        self._by_slot[slot] = table
        if self.kind != mk.DEVICE:
            for p in range(self._hot_floor(table)):
                self._demote(table, p)
        return table

    def _demote(self, table: PageTable, p: int) -> None:
        rec = table.records[p]
        sp = rec.shared
        if sp is not None:
            if sp.host is not None or sp.wb_pending:
                # an aliasing request already homed (or is homing) this
                # content: dropping the device reference IS the demotion —
                # the COW win: one D2H + one spill chunk per shared page
                # for the whole batch
                rec.dev = None
                rec.state = _COLD
                self.shared_skipped_writebacks += 1
                return
        elif rec.host is not None:
            # a promoted page still carries its cold home copy, and pages
            # behind the write head are never mutated — dropping the device
            # reference IS the demotion (no redundant D2H / store rewrite)
            rec.dev = None
            rec.state = _COLD
            return
        self.engine.submit_writeback(
            self._wb_seq, rec.dev, key=self._fetch_key(table, p)
        )
        self._wb_seq += 1
        if sp is not None:
            sp.wb_pending = True
        self._pending_demotions.append((table, p))
        rec.dev = None
        rec.state = _WB

    def flush_demotions(self, stats: StreamStats) -> None:
        """Drain pending page writebacks (pipelined D2H, in submit order)
        and re-home them at the cold kind."""
        if not self._pending_demotions:
            return
        pending, self._pending_demotions = self._pending_demotions, []
        t0 = time.perf_counter()
        hosts = self.engine.drain_writebacks()
        stats.writeback_drain_s += time.perf_counter() - t0
        for (table, p), host in zip(pending, hosts):
            nb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(host))
            stats.n_transfers += 1
            stats.d2h_requests += len(jax.tree.leaves(host))
            stats.bytes_d2h += nb
            rec = table.records[p]
            key = self._fetch_key(table, p)
            if self.kind == mk.DISK_HOST:
                self.store.put(key, host)
                host = self.store.get(key)
            if rec.shared is not None:
                rec.shared.host = host
                rec.shared.wb_pending = False
            else:
                rec.host = host
            rec.state = _COLD
            self.demoted_groups += 1

    def cold_keys(self) -> "OrderedDict[str, tuple]":
        """Every cold page of every active request, slot-major then page
        order (the stream's submission = consumption order).  Maps the
        engine fetch key -> ``(owning rid, home tree)``; a COW-shared
        content key appears ONCE, owned by the first slot consuming it."""
        out: "OrderedDict[str, tuple]" = OrderedDict()
        for slot in sorted(self._by_slot):
            table = self._by_slot[slot]
            for p, rec in enumerate(table.records):
                if rec.state == _COLD:
                    key = self._fetch_key(table, p)
                    if key not in out:
                        out[key] = (table.rid, self._cold_home(rec))
        return out

    def prefetch(self) -> None:
        """Speculatively push the current cold set (deduped; stale keys
        from retired/evicted requests are dropped)."""
        cold = self.cold_keys()
        self.stream.sync({key: rid for key, (rid, _t) in cold.items()})
        for key, (rid, tree) in cold.items():
            self.stream.push(rid, key, tree)

    def view(self, stats: StreamStats) -> tuple:
        """This step's per-slot page view: hot pages by reference, cold
        pages popped from the stream, future pages the shared zero page."""
        view = []
        for slot in range(self.slots):
            table = self._by_slot.get(slot)
            if table is None:
                view.append((self._zero_page,) * self.n_pages)
                continue
            pages = []
            for p, rec in enumerate(table.records):
                if rec.state == _DEVICE:
                    pages.append(rec.dev)
                elif rec.state == _ZERO:
                    pages.append(self._zero_page)
                else:
                    if rec.state == _WB or (
                        rec.shared is not None and rec.shared.host is None
                    ):
                        # demoted but never flushed (or aliasing a shared
                        # writeback still in the D2H queue) — should not
                        # happen in the serve loop; flush so the host
                        # bytes exist
                        self.flush_demotions(stats)
                    dev = self.stream.pop(
                        table.rid, self._fetch_key(table, p),
                        self._cold_home(rec), stats,
                    )
                    if self.kind == mk.DEVICE or p >= self._hot_floor(table):
                        # home tier is the device (or the page re-entered
                        # the hot window after a readmit): promote
                        rec.dev = dev
                        rec.state = _DEVICE
                    pages.append(dev)
            view.append(tuple(pages))
        # one controller observation per request per step (see PageStream)
        self.stream.step_done()
        return tuple(view)

    def update_current(self, new_cache: Pytree) -> None:
        """Re-slice each active slot's current page out of the decode
        output (the only page the step wrote)."""
        for slot, table in self._by_slot.items():
            p = self.current_page(table)
            rec = table.records[p]
            rec.dev = self._extract(
                new_cache,
                jnp.asarray(slot, jnp.int32),
                jnp.asarray(p * self.config.page_len, jnp.int32),
            )
            rec.host = None
            rec.state = _DEVICE
        self.peak_resident_bytes = max(
            self.peak_resident_bytes, self.device_resident_bytes()
        )

    def advance(self, table: PageTable) -> None:
        """Call after ``table.pos`` advanced: demote pages that fell out of
        the hot window (no-op for the device home kind)."""
        if self.kind == mk.DEVICE:
            return
        for p in range(self._hot_floor(table)):
            if table.records[p].state == _DEVICE:
                self._demote(table, p)

    # -- continuous batching -------------------------------------------------
    def evict(self, rid: int, stats: StreamStats) -> None:
        """Park every device page at the host (spill store for disk homes)
        and free the request's batch slot; the table survives for
        ``readmit``."""
        table = self.tables[rid]
        for p, rec in enumerate(table.records):
            if rec.state == _DEVICE:
                self._demote(table, p)
        self.flush_demotions(stats)
        if table.slot is not None:
            self._by_slot.pop(table.slot, None)
        table.slot = None
        self.prefetch()  # drop the evicted request's in-flight keys

    def readmit(self, rid: int, slot: int) -> PageTable:
        """Resume an evicted request in a (free) batch slot; its pages are
        cold and stream back in over the following steps."""
        if slot in self._by_slot:
            raise ValueError(f"slot {slot} is occupied")
        table = self.tables[rid]
        if table.slot is not None:
            raise ValueError(f"request {rid} is not evicted")
        table.slot = slot
        self._by_slot[slot] = table
        return table

    def retire(self, rid: int, stats: StreamStats) -> None:
        """Drop a finished request: device pages freed, spill chunks
        deleted, slot released."""
        # in-flight demotions must land before their records are dropped
        # (flush zips pending entries with drained tickets in order —
        # e.g. a gen==1 request retires straight from admission, with its
        # admission demotions still pending)
        self.flush_demotions(stats)
        table = self.tables.pop(rid)
        if table.slot is not None:
            self._by_slot.pop(table.slot, None)
        for rec in table.records:
            sp = rec.shared
            if sp is None:
                continue
            # drop the shared chunk only at the LAST reference: aliasing
            # requests still decode against it
            sp.refs -= 1
            if sp.refs <= 0:
                self._shared.pop(sp.key, None)
                if self.kind == mk.DISK_HOST and self.store is not None:
                    if sp.key in self.store:
                        self.store.delete(sp.key)
        if self.kind == mk.DISK_HOST and self.store is not None:
            for p in range(self.n_pages):
                key = self._page_key(rid, p)
                if key in self.store:
                    self.store.delete(key)
        table.records = []
        self.stream.forget(rid)
        self.prefetch()

    # -- accounting ----------------------------------------------------------
    def device_resident_bytes(self) -> int:
        """Bytes of cache the device *retains* between steps (hot pages +
        promoted pages + the shared zero page) — the working-set bound the
        hierarchy buys."""
        n_dev = sum(
            1
            for t in self.tables.values()
            for r in t.records
            if r.state == _DEVICE
        )
        return (n_dev + 1) * self.page_nbytes  # +1: the shared zero page

    def total_cache_bytes(self) -> int:
        """Bytes of the full dense cache across all slots (what an unpaged
        device-resident run retains)."""
        return self.slots * self.n_pages * self.page_nbytes

    def shared_pages(self) -> int:
        """Live entries in the COW shared-page registry."""
        return len(self._shared)

    def shared_refs(self) -> int:
        """Total references into the shared registry (>= shared_pages when
        any prefix is actually aliased by more than one request)."""
        return sum(sp.refs for sp in self._shared.values())

"""Mixtral-8x7B [arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1].

32L d_model=4096 32H (GQA kv=8) per-expert d_ff=14336 vocab=32000 — 8 experts
top-2, sliding-window attention (W=4096).  SWA makes long_500k decode
O(window): this arch RUNS the 500k cell (ring-buffer KV).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    pos_type="rope",
    rope_theta=1_000_000.0,
    attn_type="swa",
    window=4096,
    n_experts=8,
    moe_top_k=2,
    capacity_factor=1.25,
    moe_group_size=2048,
    fsdp=True,
    source="arXiv:2401.04088; hf",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, window=16, n_experts=4, moe_top_k=2,
        moe_group_size=64, fsdp=False, remat="none",
    )

"""Step functions: train / prefill / decode, built per (config, optimizer).

These are the functions the launcher jits with the sharding plan's
in/out-shardings and that the dry-run lowers for every (arch x shape x mesh)
cell.  All of them are pure: ``(state..., batch) -> (state..., outputs)``.

The streamed-optimizer path (``make_streamed_opt_updater`` /
``make_streamed_train_step``) is the paper's flagship pattern applied to the
largest state group of training: AdamW moments + f32 master live at the
*host* kind between steps and stream through the
:class:`~repro.core.engine.TransferEngine` group-wise during the update —
coalesced H2D, ``rw`` write-back pipelined off the compute path, prefetch
distance adaptive when ``PrefetchSpec(distance="auto")``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import TransferEngine
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import PrefetchSpec
from repro.models import transformer
from repro.optim.adamw import (
    AdamWConfig,
    adamw_globals,
    adamw_init,
    adamw_leaf_update,
    adamw_update,
)

Pytree = Any


def make_train_step(
    cfg: ModelConfig, opt_cfg: AdamWConfig, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree, dict]]:
    """``(params, opt_state, batch) -> (params, opt_state, metrics)``."""

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(transformer.lm_loss, argnums=1, has_aux=True)(
            cfg, params, batch, mesh, sharder
        )
        if sharder is not None:
            grads = sharder.grads(grads)  # ZeRO grad layout (see Sharder.grads)
        new_params, new_opt, om = adamw_update(
            opt_cfg, grads, opt_state, compute_dtype=cfg.compute_dtype
        )
        metrics = {"loss": loss, **aux, **om}
        return new_params, new_opt, metrics

    return train_step


def make_grad_step(
    cfg: ModelConfig, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree], tuple[jax.Array, dict, Pytree]]:
    """``(params, batch) -> (loss, aux, grads)`` — the forward/backward half
    of the train step, split out so the optimizer half can run through the
    host-streaming engine."""

    def grad_step(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            transformer.lm_loss, argnums=1, has_aux=True
        )(cfg, params, batch, mesh, sharder)
        if sharder is not None:
            grads = sharder.grads(grads)
        return loss, aux, grads

    return grad_step


# ---------------------------------------------------------------------------
# streamed optimizer update (host-resident AdamW state, paper 'rw' streaming)
# ---------------------------------------------------------------------------


def _to_host(x):
    """numpy view of a concrete array; abstract values pass through so the
    driver's ``jax.eval_shape(init_state)`` restore template still works."""
    return x if isinstance(x, jax.core.Tracer) else np.asarray(x)


def host_opt_state(params: Pytree) -> dict:
    """Fresh AdamW state resident at the host kind (numpy leaves).

    This is the home representation the streamed updater maintains: the
    moments never hold device memory between steps.
    """
    dev = adamw_init(params)
    return {
        "leaves": jax.tree.map(_to_host, dev["leaves"]),
        "step": _to_host(dev["step"]),
    }


def _group_bounds(n: int, n_groups: int) -> np.ndarray:
    """Contiguous leaf-group boundaries — shared by the streamed updater and
    the spill partitioner so both see the same groups."""
    return np.linspace(0, n, min(n_groups, n) + 1).astype(int)


def _opt_group_key(i: int) -> str:
    return f"opt_g{i:04d}"


def spill_opt_state(
    host_state: dict,
    store,
    *,
    n_groups: int = 4,
    host_budget_bytes: Optional[int] = None,
) -> dict:
    """Move trailing moment groups to the ``DiskHost`` tier under a host-RAM
    budget.

    Groups (the same contiguous leaf groups the streamed updater transfers)
    are kept in host RAM front-to-back while they fit ``host_budget_bytes``;
    the rest are written to ``store`` (one chunk per group — one disk
    request per group when streamed) and replaced by memory-mapped views.
    ``host_budget_bytes=None`` or 0 spills everything.  Abstract leaves
    (``jax.eval_shape`` templates, driver restore) pass through untouched.
    """
    flat_s, treedef = jax.tree.flatten(
        host_state["leaves"],
        is_leaf=lambda x: isinstance(x, dict) and {"master", "m", "v"} <= set(x),
    )
    if not all(
        isinstance(v, np.ndarray) for s in flat_s for v in jax.tree.leaves(s)
    ):
        return host_state  # abstract template (eval_shape) — nothing to spill
    bounds = _group_bounds(len(flat_s), n_groups)
    budget = host_budget_bytes or 0
    used = 0
    out: list = []
    for i in range(len(bounds) - 1):
        chunk = tuple(flat_s[bounds[i] : bounds[i + 1]])
        nbytes = sum(v.nbytes for s in chunk for v in jax.tree.leaves(s))
        if used + nbytes <= budget:
            used += nbytes
            out.extend(chunk)
        else:
            store.put(_opt_group_key(i), chunk)
            out.extend(store.get(_opt_group_key(i)))
    return {
        "leaves": jax.tree.unflatten(treedef, out),
        "step": host_state["step"],
    }


def make_streamed_opt_updater(
    opt_cfg: AdamWConfig,
    *,
    compute_dtype=jnp.bfloat16,
    n_groups: int = 4,
    prefetch: Optional[PrefetchSpec] = None,
    mode: str = "prefetch",
    engine: Optional[TransferEngine] = None,
    spill_store=None,
    state_shardings: Optional[Pytree] = None,
) -> Callable[..., tuple[Pytree, dict, dict]]:
    """Build ``update(grads, host_state, stats=None) -> (new_params,
    new_host_state, metrics)`` with host-resident optimizer state.

    Parameter leaves are partitioned into ``n_groups`` contiguous groups.
    Per group, the state leaves stream H2D through the engine (coalesced:
    one request per group) while the previous group's update computes;
    gradients are already device-resident and pass through by reference.
    New moments stream back D2H asynchronously (``rw`` write-back) and the
    new master-derived params stay on device.  The math is exactly
    :func:`repro.optim.adamw.adamw_update` (same leaf function, same
    globals); results agree to float32 rounding (the group-wise jit fuses
    differently than a whole-tree program), and the transfer schedule is
    the only structural difference.

    Groups whose ``host_state`` leaves live at the ``DiskHost`` tier
    (memory-mapped spill-store chunks — see :func:`spill_opt_state`) stream
    in through the engine's two-stage disk->host->device pipeline, and
    their updated moments are written back to ``spill_store`` after the
    D2H drain, so the state never occupies more host RAM than the budgeted
    groups plus the engine's staging pools.

    ``state_shardings`` (a pytree congruent with ``host_state["leaves"]``:
    one device ``NamedSharding`` per master/m/v leaf — the sharding plan's
    opt-state specs) places each streamed moment group at its planned
    multi-device layout instead of default single-device placement, via
    the engine's sharding-aware coalescing (one H2D request per
    addressable device per group).
    """
    prefetch = prefetch or PrefetchSpec(buffer_size=n_groups, distance=1)

    @jax.jit
    def _globals(grads, step):
        return adamw_globals(opt_cfg, grads, step)

    @jax.jit
    def _group_update(glob, gs, ss):
        out = [adamw_leaf_update(opt_cfg, glob, g, s) for g, s in zip(gs, ss)]
        new_p = tuple(p.astype(compute_dtype) for p, _ in out)
        new_s = tuple(s for _, s in out)
        return new_p, new_s

    own_engine = engine
    executor_box: list = []  # lazily built so the updater is picklable-ish
    #: per-group sharding lists, keyed by the grads treedef (static across
    #: steps — rebuilt only when the param structure changes)
    group_shardings_cache: dict = {}

    def _executor() -> HostStreamExecutor:
        if not executor_box:
            new_params_box: list = []

            def apply(glob, group):
                new_p, new_s = _group_update(glob, group["g"], group["s"])
                new_params_box.append(new_p)
                return glob, new_s

            ex = HostStreamExecutor(apply, writeback=True, engine=own_engine)
            executor_box.append((ex, new_params_box))
        return executor_box[0]

    def update(grads, host_state, stats: Optional[StreamStats] = None):
        from repro.core.spillstore import is_disk_leaf

        ex, new_params_box = _executor()
        new_params_box.clear()
        step = int(host_state["step"]) + 1
        glob = _globals(grads, step)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(host_state["leaves"])
        n = len(flat_g)
        bounds = _group_bounds(n, n_groups)
        groups = [
            {
                "g": tuple(flat_g[bounds[i] : bounds[i + 1]]),
                "s": tuple(flat_s[bounds[i] : bounds[i + 1]]),
            }
            for i in range(len(bounds) - 1)
        ]
        group_shardings = None
        if state_shardings is not None:
            # per-group layouts mirroring the group partition: grads are
            # device-resident (pass-by-reference; None = no placement),
            # moments stage at the plan's opt specs
            group_shardings = group_shardings_cache.get(treedef)
            if group_shardings is None:
                flat_sh = treedef.flatten_up_to(state_shardings)
                group_shardings = [
                    {
                        "g": tuple([None] * (bounds[i + 1] - bounds[i])),
                        "s": tuple(flat_sh[bounds[i] : bounds[i + 1]]),
                    }
                    for i in range(len(bounds) - 1)
                ]
                group_shardings_cache[treedef] = group_shardings

        _, state_outs = ex.run(
            glob,
            groups,
            mode=mode,
            prefetch=prefetch,
            stats=stats,
            group_shardings=group_shardings,
        )

        # disk-homed groups go back to their home tier: write the updated
        # moments to the spill store and keep only the memmap views
        for i, grp in enumerate(groups):
            if any(is_disk_leaf(v) for s in grp["s"] for v in jax.tree.leaves(s)):
                if spill_store is None:
                    raise RuntimeError(
                        "optimizer state group streamed from the DiskHost "
                        "tier but no spill_store was given to write it back"
                    )
                spill_store.put(_opt_group_key(i), state_outs[i])
                state_outs[i] = spill_store.get(_opt_group_key(i))

        flat_new_p = [p for chunk in new_params_box for p in chunk]
        flat_new_s = [s for chunk in state_outs for s in chunk]
        new_params = treedef.unflatten(flat_new_p)
        new_state = {
            "leaves": treedef.unflatten(flat_new_s),
            "step": np.asarray(step, np.int32),
        }
        metrics = {"grad_norm": glob["grad_norm"], "lr": glob["lr"]}
        return new_params, new_state, metrics

    update.close = lambda: executor_box and executor_box[0][0].close()  # type: ignore[attr-defined]
    return update


def make_streamed_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh=None,
    sharder=None,
    *,
    n_groups: int = 4,
    prefetch: Optional[PrefetchSpec] = None,
    engine: Optional[TransferEngine] = None,
    stats: Optional[StreamStats] = None,
    spill_store=None,
    state_shardings: Optional[Pytree] = None,
) -> Callable[[dict, Pytree], tuple[dict, dict]]:
    """``(state, batch) -> (state, metrics)`` with host-resident optimizer.

    ``state = {"params": device pytree, "opt": host_opt_state(...)}``.  The
    forward/backward half is jitted; the AdamW half streams the host-kind
    moments through the transfer engine (see ``make_streamed_opt_updater``).
    With ``spill_store``, moment groups spilled to the ``DiskHost`` tier
    (see :func:`spill_opt_state`) stream disk->host->device and write back
    to disk.  ``state_shardings`` places the streamed moment groups at the
    sharding plan's opt specs (one coalesced H2D request per device per
    group under a mesh).
    """
    grad_fn = jax.jit(make_grad_step(cfg, mesh, sharder))
    updater = make_streamed_opt_updater(
        opt_cfg,
        compute_dtype=cfg.compute_dtype,
        n_groups=n_groups,
        prefetch=prefetch,
        engine=engine,
        spill_store=spill_store,
        state_shardings=state_shardings,
    )

    def step_fn(state, batch):
        loss, aux, grads = grad_fn(state["params"], batch)
        new_params, new_opt, om = updater(grads, state["opt"], stats=stats)
        metrics = {"loss": loss, **aux, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    step_fn.close = updater.close  # type: ignore[attr-defined]
    return step_fn


def make_prefill_step(
    cfg: ModelConfig, batch_size: int, seq_len: int, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree], tuple[jax.Array, Pytree]]:
    """``(params, batch) -> (last-token logits, caches)``.

    Caches are created inside the step (zeros) so the step's out-shardings
    place them; context length is the shape's ``seq_len``.
    """

    def prefill_step(params, batch):
        caches = transformer.init_caches(cfg, batch_size, seq_len, cfg.compute_dtype)
        return transformer.prefill(cfg, params, batch, caches, mesh, sharder)

    return prefill_step


def make_decode_step(
    cfg: ModelConfig, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]]:
    """``(params, caches, batch, pos) -> (logits, caches)`` — one new token
    against a populated decode state (KV cache / recurrent state)."""

    def decode_step(params, caches, batch, pos):
        return transformer.decode_step(cfg, params, batch, caches, pos, sharder)

    return decode_step


def make_paged_decode_step(
    cfg: ModelConfig, mesh=None, sharder=None, *, donate_cache: bool = True
) -> Callable[[Pytree, Any, Pytree, jax.Array], tuple[jax.Array, Pytree]]:
    """``(params, view, batch, pos) -> (logits, caches)`` over a paged KV
    cache (see :mod:`repro.core.kvpager`).

    ``view`` is the pager's per-slot tuple of page pytrees; ``pos`` is the
    (B,) vector of per-slot context positions.  Assembly (pure page
    concatenation) is a *separate* jit from the decode executable, so the
    paged step runs the exact same decode program as
    :func:`make_decode_step` on the exact same cache values — paged and
    unpaged decode are bitwise-equal by construction.  The assembled dense
    view is donated into the step (``donate_cache``): it is a per-step
    transient, never the pager's retained hot pages (concatenation always
    produces a fresh buffer).
    """
    from repro.core import kvpager

    decode_fn = jax.jit(
        make_decode_step(cfg, mesh, sharder),
        donate_argnums=(1,) if donate_cache else (),
    )
    assemble = jax.jit(kvpager.assemble_view)

    def paged_decode_step(params, view, batch, pos):
        return decode_fn(params, assemble(view), batch, pos)

    paged_decode_step.decode_fn = decode_fn  # type: ignore[attr-defined]
    paged_decode_step.assemble = assemble  # type: ignore[attr-defined]
    return paged_decode_step


def init_train_state(
    key: jax.Array, cfg: ModelConfig
) -> tuple[Pytree, Pytree]:
    """(bf16 params, AdamW state with f32 master) for a fresh run."""
    params_f32 = transformer.init_model(key, cfg)
    opt_state = adamw_init(params_f32)
    params = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), params_f32)
    return params, opt_state


def abstract_train_state(cfg: ModelConfig) -> tuple[Pytree, Pytree]:
    """ShapeDtypeStruct pytrees of (params, opt_state) — no allocation."""
    def build():
        return init_train_state(jax.random.PRNGKey(0), cfg)

    return jax.eval_shape(build)


def abstract_params(cfg: ModelConfig) -> Pytree:
    def build():
        p = transformer.init_model(jax.random.PRNGKey(0), cfg)
        return jax.tree.map(lambda x: x.astype(cfg.compute_dtype), p)

    return jax.eval_shape(build)


def abstract_caches(cfg: ModelConfig, batch: int, seq_len: int) -> Pytree:
    return jax.eval_shape(
        lambda: transformer.init_caches(cfg, batch, seq_len, cfg.compute_dtype)
    )

"""Logical-axis sharding rules: param/optimizer/batch/cache PartitionSpecs.

Mesh axes:
  ``("data", "model")``           single pod (16 x 16)
  ``("pod", "data", "model")``    multi-pod  (2 x 16 x 16)

Parallelism scheme (see DESIGN.md §4):
  * batch/activations  -> all non-``model`` axes (``pod`` is pure DP),
  * TP: one tensor dim per leaf over ``model`` (first divisible candidate),
  * FSDP (train plan): one further dim over ``data`` — params + optimizer
    state fully sharded; XLA all-gathers per layer inside the scan (ZeRO-3),
  * serve plan: TP everywhere; ``data``-axis sharding only for MoE expert
    leaves (expert weights are the one state group that can exceed HBM under
    pure TP); KV caches shard batch over ``data`` and heads/head_dim over
    ``model``.

Every rule is divisibility-checked against the actual leaf shape; dims that
cannot be evenly sharded fall through to the next candidate (or stay
replicated), so *any* architecture lowers on *any* mesh — sharding quality,
not correctness, is what the rules tune.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

Pytree = Any


# ---------------------------------------------------------------------------
# leaf rules: name -> (tp_candidates, fsdp_candidates) as dim indices
# (negative = from the right, applied after stripping a stacked layer dim).
# Order within each list = preference; first divisible dim wins.
# ---------------------------------------------------------------------------

# (name, base_ndim) -> rule; base_ndim=None matches any rank
_RULES: dict[tuple[str, Optional[int]], tuple[tuple[int, ...], tuple[int, ...]]] = {
    # embeddings / head: vocab over model; NO data-sharding — an FSDP-sharded
    # contraction dim on the head makes GSPMD all-reduce the full (B,S,V)
    # logits over data (measured 12.3 GiB/step on olmo-1b; §Dry-run).
    # The table itself is ALSO vocab-sharded: a D-sharded table makes the
    # tied-embedding head (bsd,vd->bsv) partial-sum a full (B,S,V) f32 tensor
    # over model (same 12.3 GiB); vocab-sharding turns the token gather into
    # a masked local gather + one small (B,S,D) psum instead.
    ("tok", None): ((0,), ()),
    ("out", None): ((-1,), ()),
    # attention
    ("wq", None): ((-2, -1), (-3,)),
    ("wk", None): ((-2, -1), (-3,)),
    ("wv", None): ((-2, -1), (-3,)),
    ("wo", 3): ((-3, -2), (-1,)),  # attn out-proj (N, H, D)
    ("bq", None): ((), ()),
    ("bk", None): ((), ()),
    ("bv", None): ((), ()),
    # dense MLP
    ("wi", 2): ((-1,), (-2,)),
    ("wg", 2): ((-1,), (-2,)),
    ("wo", 2): ((-2,), (-1,)),
    # MoE (E, D, F) / (E, F, D): prefer EP over model, else TP on F
    ("wi", 3): ((0, -1), (-2,)),
    ("wg", 3): ((0, -1), (-2,)),
    ("wo_moe", 3): ((0, -2), (-1,)),
    ("router", None): ((), (-2,)),
    # RG-LRU
    ("w_in", None): ((-1,), (-2,)),
    ("w_x", None): ((-1,), (-2,)),
    ("w_a", None): ((-1,), (-2,)),
    ("w_gate", None): ((-1,), (-2,)),
    ("w_out", None): ((-2,), (-1,)),
    ("conv", None): ((-1,), ()),
    ("b_a", 1): ((-1,), ()),
    ("b_x", 1): ((-1,), ()),
    ("lambda", None): ((-1,), ()),
    # xLSTM mLSTM
    ("w_up", None): ((-1,), (-2,)),
    ("w_down", None): ((-2,), (-1,)),
    ("w_if", None): ((-2,), ()),
    # slstm input/recurrent
    ("w_z", None): ((-1,), (-2,)),
    ("w_i", None): ((-1,), (-2,)),
    ("w_f", None): ((-1,), (-2,)),
    ("w_o", None): ((-1,), (-2,)),
    ("r_z", None): ((-1,), ()),
    ("r_i", None): ((-1,), ()),
    ("r_f", None): ((-1,), ()),
    ("r_o", None): ((-1,), ()),
    # vision/audio frontend stubs
    ("merge_w", None): ((-1,), (-2,)),
    ("merge_b", None): ((), ()),
}

# per-head-dim wq/wk/wv under xlstm use different shapes; the generic rules
# above still apply (last dim = per-head feature).

_REPLICATED_NAMES = {"scale", "bias", "gn_scale", "b_f", "b_i", "b_o", "b_z", "slot_pos"}


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Resolved axis names for one mesh + execution mode."""

    mesh: Mesh
    mode: str = "train"  # train | serve
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = None  # present on multi-pod meshes
    #: when n_heads doesn't divide the model axis: fall back to head_dim
    #: sharding ("head_dim", baseline — partial-sum ARs of attention scores)
    #: or replicate the attention projections over model ("replicate" —
    #: relies on sequence-parallel activations; §Perf knob)
    attn_indivisible: str = "head_dim"
    #: serve plans data-shard MoE expert weights (needed when experts exceed
    #: HBM under pure TP, e.g. qwen3-235b) at the cost of a per-layer expert
    #: all-gather on every decode step — turn off for models that fit
    #: (mixtral: measured 0.35 GB/layer-step of pure-overhead AG; §Perf knob)
    serve_expert_fsdp: bool = True
    #: thread the explicit Sharder constraints through the model (per-layer
    #: FSDP gather + activation pins).  Serve plans have no FSDP on dense
    #: weights, so the constraints are layout no-ops — but each in-scan wsc
    #: can still materialize a parameter-sized copy (§Perf knob)
    use_sharder: bool = True
    #: pure data parallelism: batch over EVERY mesh axis, weights replicated
    #: over model — for small models whose TP activation resharding dwarfs
    #: compute (musicgen-medium: 250 GB/step of AG/AR/A2A vs 63 GFLOP; §Perf)
    pure_dp: bool = False
    #: ZeRO-3 FSDP param sharding over data (train plans).  Off = params
    #: replicated (no per-layer gathers); optimizer state follows the param
    #: spec so turning this off also replicates m/v (only sensible for
    #: small models; §Perf knob)
    fsdp: bool = True

    @property
    def batch_axes(self) -> tuple[str, ...]:
        axes = ((self.pod_axis,) if self.pod_axis else ()) + (self.data_axis,)
        if self.pure_dp:
            axes = axes + (self.model_axis,)
        return axes

    @property
    def data_size(self) -> int:
        return self.mesh.shape[self.data_axis]

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def batch_size_divisor(self) -> int:
        n = self.data_size
        if self.pod_axis:
            n *= self.mesh.shape[self.pod_axis]
        return n

    def fsdp_enabled_for(self, path_names: tuple[str, ...]) -> bool:
        if self.mode == "train":
            return self.fsdp
        # serve: only MoE expert weights get data-axis sharding, and only
        # when the model actually needs it to fit (serve_expert_fsdp)
        return self.serve_expert_fsdp and "moe" in path_names


def make_plan(mesh: Mesh, mode: str = "train", **kw) -> ShardingPlan:
    axes = tuple(mesh.axis_names)
    pod = "pod" if "pod" in axes else None
    return ShardingPlan(mesh=mesh, mode=mode, pod_axis=pod, **kw)


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------

def _path_names(path) -> tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return tuple(names)


def _rule_for(names: tuple[str, ...], base_ndim: int, mode: str = "train"):
    leaf = names[-1]
    if leaf in _REPLICATED_NAMES:
        return ((), ())
    # NOTE a stationary-experts serve rule (E over data, F over model, tokens
    # all-to-all to the experts) was tried and REFUTED: GSPMD still chooses
    # to all-gather the expert weights over data for the dispatch einsum
    # (qwen3 decode coll 1143 -> 1206 ms) and prefill token movement explodes
    # (11.9 -> 86.6 s).  See EXPERIMENTS.md §Perf.
    # moe wo disambiguation: parent 'moe' + 3 base dims
    if leaf == "wo" and "moe" in names and base_ndim == 3:
        return _RULES[("wo_moe", 3)]
    for key in ((leaf, base_ndim), (leaf, None)):
        if key in _RULES:
            return _RULES[key]
    return ((), ())


def _spec_for_leaf(
    plan: ShardingPlan,
    names: tuple[str, ...],
    shape: tuple[int, ...],
    stacked: bool,
) -> P:
    """Greedy axis assignment with divisibility checks."""
    base_ndim = len(shape) - (1 if stacked else 0)
    off = 1 if stacked else 0
    tp_pref, fsdp_pref = _rule_for(names, base_ndim, plan.mode)

    assign: dict[int, str] = {}

    def norm(i: int) -> int:
        return off + (i if i >= 0 else base_ndim + i)

    is_attn_proj = names[-1] in ("wq", "wk", "wv") or (
        names[-1] == "wo" and base_ndim == 3 and "moe" not in names
    )
    cands = tp_pref
    if plan.pure_dp:
        cands = ()  # no tensor parallelism: weights replicated over model
    elif is_attn_proj and plan.attn_indivisible == "replicate":
        cands = tp_pref[:1]  # heads-or-nothing: no head_dim fallback
    for i in cands:
        d = norm(i)
        if d not in assign and shape[d] % plan.model_size == 0 and shape[d] > 1:
            assign[d] = plan.model_axis
            break
    if plan.fsdp_enabled_for(names):
        for i in fsdp_pref:
            d = norm(i)
            if d not in assign and shape[d] % plan.data_size == 0 and shape[d] > 1:
                assign[d] = plan.data_axis
                break
    return P(*(assign.get(d, None) for d in range(len(shape))))


def param_specs(plan: ShardingPlan, params: Pytree) -> Pytree:
    """PartitionSpec pytree matching a model param tree."""

    def leaf(path, x):
        names = _path_names(path)
        stacked = "blocks" in names and (
            "periods" in names
            or not any(n.startswith(("layer_", "tail_")) for n in names)
        )
        return _spec_for_leaf(plan, names, tuple(x.shape), stacked)

    return jax.tree_util.tree_map_with_path(leaf, params)


def opt_state_specs(
    plan: ShardingPlan, p_specs: Pytree, params: Optional[Pytree] = None
) -> Pytree:
    """Optimizer-state specs: master/m/v share the param leaf's spec.

    ZeRO-1 mode (train plan with ``fsdp=False`` and ``params`` given):
    params stay replicated but the f32 master/m/v shard their leading dim
    over ``data`` where divisible — each rank owns 1/data of the optimizer
    and the updated params are all-gathered once per step.
    """
    zero1 = plan.mode == "train" and not plan.fsdp and params is not None

    if not zero1:
        leaves_specs = jax.tree.map(
            lambda s: {"master": s, "m": s, "v": s},
            p_specs,
            is_leaf=lambda s: isinstance(s, P),
        )
        return {"leaves": leaves_specs, "step": P()}

    def leaf(spec, p):
        shape = tuple(p.shape)
        if (
            shape
            and spec == P(*([None] * len(shape)))
            and shape[0] % plan.data_size == 0
            and shape[0] > 1
        ):
            spec = P(plan.data_axis, *([None] * (len(shape) - 1)))
        return {"master": spec, "m": spec, "v": spec}

    leaves_specs = jax.tree.map(
        leaf, p_specs, params, is_leaf=lambda s: isinstance(s, P)
    )
    return {"leaves": leaves_specs, "step": P()}


def named_shardings(mesh: Mesh, specs: Pytree) -> Pytree:
    """``NamedSharding`` tree (device memory) from a ``PartitionSpec`` tree.

    The device-placement form every streaming consumer hands the transfer
    engine (``device_shardings`` / ``state_shardings``): sharding-aware
    coalescing stages one buffer per addressable device from these, so a
    group costs ``n_devices`` H2D requests instead of one per leaf shard.
    """
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )


def batch_spec(plan: ShardingPlan) -> P:
    """(batch, ...) leading-dim spec."""
    return P(plan.batch_axes)


def batch_specs(plan: ShardingPlan, batch: Pytree, global_batch: int) -> Pytree:
    """Shard the batch dim over (pod, data) when divisible, else replicate."""
    ok = global_batch % plan.batch_size_divisor == 0
    ok_data_only = global_batch % plan.data_size == 0

    def leaf(x):
        nd = len(x.shape)
        if ok:
            return P(plan.batch_axes, *([None] * (nd - 1)))
        if ok_data_only:
            return P(plan.data_axis, *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree.map(leaf, batch)


def cache_specs_tree(plan: ShardingPlan, caches: Pytree, global_batch: int) -> Pytree:
    """Decode-state specs.

    k/v caches ``(..., B, T, K, H)``: batch over data axes when divisible;
    heads over model when divisible else head_dim over model.  Recurrent /
    matrix states: batch over data, feature dim over model.
    """
    b_ok = global_batch % plan.data_size == 0
    b_axes = plan.data_axis if b_ok else None

    def leaf(path, x):
        names = _path_names(path)
        shape = tuple(x.shape)
        nd = len(shape)
        leafname = names[-1]
        if leafname in ("k", "v"):
            # (B, T, K, H) or stacked (L, B, T, K, H).
            # Preference: KV heads over model (fully local attention) when
            # divisible; else the *sequence* dim (flash-decode style — scores
            # and the softmax combine are partial-reduced over model, which
            # for single-token queries is KBs, vs the involuntary full cache
            # rematerialization GSPMD falls back to otherwise — measured
            # 54 GB/step on internlm2 decode_32k); head_dim as last resort.
            off = nd - 4
            spec = [None] * nd
            spec[off + 0] = b_axes
            if shape[off + 2] % plan.model_size == 0 and shape[off + 2] > 1:
                spec[off + 2] = plan.model_axis
            elif shape[off + 1] % plan.model_size == 0 and shape[off + 1] > 1:
                spec[off + 1] = plan.model_axis
            elif shape[off + 3] % plan.model_size == 0:
                spec[off + 3] = plan.model_axis
            return P(*spec)
        if leafname == "slot_pos":
            return P(*([None] * nd))
        # recurrent states: (B, W) / (B, NH, DH, DH) / (L, B, ...) stacked
        # batch dim = first dim whose size matches a batch multiple; we use
        # a convention: hetero states are (B, ...), stacked are (L, B, ...).
        off = 1 if (("blocks" in names or nd >= 2) and shape[0] != global_batch and nd >= 2 and shape[min(1, nd - 1)] == global_batch) else 0
        spec = [None] * nd
        if shape[off] == global_batch:
            spec[off] = b_axes
        # shard the largest remaining dim over model if divisible
        rest = [(shape[d], d) for d in range(nd) if d != off]
        for size, d in sorted(rest, reverse=True):
            if size % plan.model_size == 0 and size > 1:
                spec[d] = plan.model_axis
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, caches)


def recommended_plan(mesh: Mesh, cfg, mode: str = "train") -> ShardingPlan:
    """Plan with the §Perf lessons codified:

    * small models (full f32 train state fits a fraction of HBM) train pure-DP
      with remat="dots" semantics — TP activation resharding dwarfs their
      compute (musicgen-medium: 19x roofline-fraction win, xlstm: similar);
    * serve plans use stationary experts (rule-level) and skip expert FSDP
      when the experts fit pure TP.
    """
    plan = make_plan(mesh, mode=mode)
    total, _ = cfg.param_count()
    if mode == "train" and total * 14 <= 6 * 2**30:
        plan = dataclasses.replace(plan, pure_dp=True)
    if mode == "serve" and total * 2 / plan.model_size <= 10 * 2**30:
        plan = dataclasses.replace(plan, serve_expert_fsdp=False)
    return plan


# ---------------------------------------------------------------------------
# explicit FSDP gather + activation constraints (the Sharder)
# ---------------------------------------------------------------------------

def _strip_axes(spec: P, drop: tuple[str, ...]) -> P:
    def strip(ax):
        if ax is None:
            return None
        if isinstance(ax, (tuple, list)):
            kept = tuple(a for a in ax if a not in drop)
            return kept if kept else None
        return None if ax in drop else ax

    return P(*(strip(a) for a in spec))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _resharded(a, fwd_sharding, bwd_sharding):
    """FSDP gather with an explicit backward layout.

    Forward: constrain to the TP-only (gathered) layout — the per-layer
    all-gather.  Backward: constrain the cotangent to the FSDP layout — the
    per-layer reduce-scatter.  A plain with_sharding_constraint transposes
    to itself, which leaves the scan's stacked gradient accumulator
    UNSHARDED over data (measured 80 GiB of f32 grads on internlm2-20b).
    """
    return jax.lax.with_sharding_constraint(a, fwd_sharding)


def _resharded_fwd(a, fwd_sharding, bwd_sharding):
    return _resharded(a, fwd_sharding, bwd_sharding), None


def _resharded_bwd(fwd_sharding, bwd_sharding, _, g):
    return (jax.lax.with_sharding_constraint(g, bwd_sharding),)


_resharded.defvjp(_resharded_fwd, _resharded_bwd)


def _prune_to(tree, specs):
    """Restrict a spec dict-tree to the keys present in ``tree`` (identity
    when the structures already match)."""
    if isinstance(tree, dict) and isinstance(specs, dict):
        return {k: _prune_to(v, specs[k]) for k, v in tree.items()}
    return specs


@dataclasses.dataclass
class Sharder:
    """Explicit sharding control threaded through the model.

    * ``acts(x)`` pins block-boundary activations to (batch-sharded,
      replicated-feature) — stops GSPMD propagating pathological reshards.
    * ``block(p, name)`` pins a layer's parameter slice to its **TP-only**
      spec.  For FSDP('data')-sharded params this inserts the per-layer
      all-gather *inside* the scan body (ZeRO-3); the backward pass dually
      reduce-scatters the layer gradient.  This makes the FSDP schedule
      explicit and deterministic instead of propagation-dependent.
    """

    mesh: Mesh
    plan: ShardingPlan
    act_spec: P
    block_specs: Any  # TP-only per-layer spec tree (hetero: {layer_name: tree})
    fsdp_specs: Any = None  # per-layer FSDP spec tree (backward layout)
    full_specs: Any = None  # whole-params spec tree (FSDP layout)
    uniform: bool = True

    def _ns(self, spec: P):
        return jax.sharding.NamedSharding(self.mesh, spec)

    def grads(self, g):
        """Pin gradients to the params' (FSDP) layout.  Without this the
        cotangent of the in-scan TP-only constraint accumulates the stacked
        layer gradients UNSHARDED in f32 (measured 249 GiB/dev on
        qwen2-vl-72b train_4k); pinning here makes the backward emit
        per-layer reduce-scatters instead (ZeRO grad sharding)."""
        if self.full_specs is None:
            return g
        return jax.tree.map(
            lambda a, s: jax.lax.with_sharding_constraint(a, self._ns(s)),
            g,
            self.full_specs,
        )

    def acts(self, x):
        nd = len(x.shape)
        spec = P(*self.act_spec, *([None] * (nd - len(self.act_spec))))
        return jax.lax.with_sharding_constraint(x, self._ns(spec))

    def block(self, p, name=None):
        """``name``: None (uniform stacked layer slice), a key string, or a
        tuple path into the blocks subtree (period scan: ('periods','pos_k')).
        ``p`` may be a key-subset of the block structure (the expert-stream
        decode path shards a block's NON-expert group alone); specs are
        pruned to the keys present."""
        specs, bwd = self.block_specs, self.fsdp_specs
        if name is not None:
            for part in (name,) if isinstance(name, str) else name:
                specs = specs[part]
                bwd = bwd[part] if bwd is not None else None
        specs = _prune_to(p, specs)
        bwd = _prune_to(p, bwd) if bwd is not None else None
        if bwd is None:
            return jax.tree.map(
                lambda a, s: jax.lax.with_sharding_constraint(a, self._ns(s)), p, specs
            )
        return jax.tree.map(
            lambda a, s, b: _resharded(a, self._ns(s), self._ns(b)), p, specs, bwd
        )


def make_sharder(
    plan: ShardingPlan,
    params: Pytree,
    global_batch: Optional[int] = None,
    *,
    seq_len: Optional[int] = None,
    seq_shard: bool = False,
) -> Sharder:
    """Build the Sharder for a param tree (abstract or concrete).

    ``seq_shard=True`` enables sequence parallelism for the *block-boundary*
    activation constraint: the residual stream (and therefore every
    remat-saved per-layer residual) is sharded over the model axis on the
    sequence dim.  Without it, saved residuals are (B_loc, S, D) bf16 per
    layer — 0.8 GiB x 48 layers on internlm2-20b train_4k, which can never
    fit 16 GiB HBM; with it they shrink by the TP degree (Megatron-SP).
    """
    if global_batch is None or global_batch % plan.batch_size_divisor == 0:
        b_axes = plan.batch_axes
    elif global_batch % plan.data_size == 0:
        b_axes = plan.data_axis
    else:
        b_axes = None
    seq_ok = seq_shard and seq_len is not None and seq_len % plan.model_size == 0
    act_spec = P(b_axes, plan.model_axis if seq_ok else None)
    specs = param_specs(plan, params)
    drop = tuple(a for a in (plan.data_axis, plan.pod_axis) if a)
    blocks = specs.get("blocks", {})
    uniform = not any(
        str(k).startswith(("layer_", "tail_", "periods")) for k in blocks
    )
    is_spec = lambda s: isinstance(s, P)

    def per_layer(path, s):
        # stacked leaves (uniform stack or 'periods' position stacks) drop
        # their leading scan dim; unrolled leaves keep their full spec
        names = _path_names(path)
        stacked = uniform or "periods" in names
        return P(*s[1:]) if stacked else s

    fsdp_specs = jax.tree_util.tree_map_with_path(per_layer, blocks, is_leaf=is_spec)
    block_specs = jax.tree.map(
        lambda s: _strip_axes(s, drop), fsdp_specs, is_leaf=is_spec
    )
    return Sharder(
        mesh=plan.mesh,
        plan=plan,
        act_spec=act_spec,
        block_specs=block_specs,
        fsdp_specs=fsdp_specs,
        full_specs=specs,
        uniform=uniform,
    )


# ---------------------------------------------------------------------------
# debugging / reporting helpers
# ---------------------------------------------------------------------------

def sharding_report(plan: ShardingPlan, params: Pytree, specs: Pytree) -> str:
    """Human-readable table: leaf path, shape, spec, per-device bytes."""
    rows = []
    total_bytes = 0
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    for (path, x), spec in zip(flat_p, flat_s):
        names = "/".join(_path_names(path))
        shard_elems = np.prod(x.shape) if x.shape else 1
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            for a in axes:
                shard_elems //= plan.mesh.shape[a]
        nbytes = int(shard_elems) * x.dtype.itemsize
        total_bytes += nbytes
        rows.append(f"  {names:55s} {str(x.shape):26s} {str(spec):36s} {nbytes/2**20:9.2f} MiB")
    header = f"per-device param bytes: {total_bytes/2**30:.3f} GiB ({plan.mode} plan)"
    return header + "\n" + "\n".join(rows)

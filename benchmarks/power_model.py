"""Paper Table 1 analogue: throughput / power across hardware classes.

The paper measures LINPACK MFLOPs and Watts on Epiphany / MicroBlaze /
Cortex-A9 and situates micro-cores against embedded and HPC parts.  This
container has no power meter; we reproduce the *table structure* with:
  * measured: matmul GFLOP/s of this container's CPU backend (per-core),
  * derived:  the dry-run roofline's projected per-chip utilization for
    TPU v5e (197 TFLOP/s bf16 peak, ~O(100)W class per chip),
  * cited:    the paper's own rows, for context.

GFLOPs/Watt for TPU rows use the public ~200W-class chip envelope — the
point of the table (orders-of-magnitude separation between hardware classes,
with efficiency rankings stable) is what carries over, as in the paper.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.roofline.hw import V5E


def measured_matmul_gflops(n: int = 1024, repeats: int = 5) -> float:
    x = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(repeats):
        x = f(x)
    jax.block_until_ready(x)
    dt = (time.perf_counter() - t0) / repeats
    return 2 * n ** 3 / dt / 1e9


def main() -> int:
    cpu = measured_matmul_gflops()
    rows = [
        # measured here
        {"technology": "container CPU core (measured f32)", "gflops": round(cpu, 1),
         "watts": "n/a", "gflops_per_watt": "n/a"},
        # roofline-derived target hardware (see EXPERIMENTS.md §Roofline)
        {"technology": "TPU v5e chip (peak bf16)", "gflops": V5E.peak_flops_bf16 / 1e9,
         "watts": 200.0, "gflops_per_watt": V5E.peak_flops_bf16 / 1e9 / 200.0},
        # the paper's own Table 1 rows (cited)
        {"technology": "Epiphany-III (paper)", "gflops": 1.508, "watts": 0.90,
         "gflops_per_watt": 1.676},
        {"technology": "MicroBlaze+FPU (paper)", "gflops": 0.0472, "watts": 0.18,
         "gflops_per_watt": 0.262},
        {"technology": "Cortex A-9 (paper)", "gflops": 0.0332, "watts": 0.60,
         "gflops_per_watt": 0.055},
        {"technology": "Pascal GPU (paper, cited)", "gflops": None, "watts": 250.0,
         "gflops_per_watt": 42.0},
    ]
    C.print_table("paper Table 1 analogue: throughput / power", rows,
                  ["technology", "gflops", "watts", "gflops_per_watt"])
    C.save_rows("table1_power", rows)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Host-side prefetching data loader — the paper's prefetch at the input level.

Batches are produced on the host (the paper's ``Host`` memory kind: a level
the accelerator cannot address) and transferred with a bounded look-ahead of
``distance`` batches, so H2D input copies overlap the previous step's compute.
``distance=0`` is the paper's on-demand mode (the step stalls on its input).
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Optional

import jax

Pytree = Any


class PrefetchLoader:
    def __init__(
        self,
        make_batch: Callable[[int], Pytree],
        *,
        shardings: Optional[Pytree] = None,
        distance: int = 2,
        start_step: int = 0,
    ) -> None:
        self._make = make_batch
        self._sh = shardings
        self._distance = max(distance, 0)
        self._next = start_step
        self._ring: deque[tuple[int, Pytree]] = deque()

    def _put(self, step: int) -> Pytree:
        batch = self._make(step)
        if self._sh is not None:
            batch = jax.device_put(batch, self._sh)
        else:
            batch = jax.device_put(batch)
        return batch

    def __call__(self, step: int) -> Pytree:
        """Batch for ``step``; issues transfers up to ``step + distance``."""
        # drop stale entries (restart / out-of-order resume)
        while self._ring and self._ring[0][0] < step:
            self._ring.popleft()
        if not self._ring or self._ring[0][0] != step:
            self._ring.clear()
            self._next = step
        while self._next <= step + self._distance:
            self._ring.append((self._next, self._put(self._next)))
            self._next += 1
        s, batch = self._ring.popleft()
        assert s == step
        return batch

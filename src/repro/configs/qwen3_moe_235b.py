"""Qwen3-235B-A22B MoE [hf:Qwen/Qwen3-235B-A22B; family ref Qwen/Qwen3-30B-A3B].

94L d_model=4096 64H (GQA kv=4, head_dim=128 — q proj 4096->8192) per-expert
d_ff=1536, vocab=151936, MoE 128 experts top-8, qk-norm.  The flagship cell
for the paper's technique: expert weights dominate (~227B routed params) and
are the state class host-offload + streaming target.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151_936,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    pos_type="rope",
    rope_theta=1_000_000.0,
    qk_norm=True,
    n_experts=128,
    moe_top_k=8,
    capacity_factor=1.25,
    moe_group_size=2048,
    fsdp=True,
    source="hf:Qwen/Qwen3-235B-A22B",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, n_experts=8, moe_top_k=2, moe_group_size=64,
        fsdp=False, remat="none",
    )

"""Kernel-level prefetch study: streamed matmul DMA schedule (TPU-native).

The in-kernel analogue of the paper's §3.1 knobs: the weight operand stays
in HBM and is DMA'd through a VMEM ring.  On this CPU container the kernel
runs in interpret mode, so wall-clock is NOT the metric — the recorded
schedule statistics are: number of DMA issues, bytes per issue, ring
occupancy, and the (distance=0) on-demand stall structure.  On TPU hardware
the same sweep measures real overlap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core.refspec import PrefetchSpec
from repro.kernels.streamed_matmul import matmul_ref, streamed_matmul


def main() -> int:
    m = k = n = 512
    bk = 128
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    ref = matmul_ref(x, w)
    n_tiles_k = k // bk
    n_tiles = (m // 128) * (n // 128) * n_tiles_k
    rows = []
    for dist, slots in [(0, 1), (1, 2), (2, 3), (4, 5)]:
        spec = PrefetchSpec(buffer_size=slots, elements_per_fetch=1, distance=dist)
        out = streamed_matmul(x, w, spec=spec, block_k=bk)
        ok = bool(jnp.allclose(out, ref, atol=1e-3))
        rows.append(
            {
                "distance": dist,
                "ring_slots": slots,
                "dma_issues": n_tiles,
                "bytes_per_dma": bk * 128 * 4,
                "vmem_ring_bytes": slots * bk * 128 * 4,
                "overlapped": dist > 0,
                "matches_oracle": ok,
            }
        )
    C.print_table("streamed matmul DMA schedule (paper §3.1 knobs, kernel level)",
                  rows, ["distance", "ring_slots", "dma_issues", "bytes_per_dma",
                         "vmem_ring_bytes", "overlapped", "matches_oracle"])
    C.save_rows("kernel_streaming", rows)
    return 0 if all(r["matches_oracle"] for r in rows) else 1


if __name__ == "__main__":
    raise SystemExit(main())

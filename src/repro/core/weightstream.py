"""Streamed model parameters: host/disk-homed weights under a device budget.

The paper's flagship claim ("compute with data sets of arbitrarily large
size", §3.1) applied to the largest pytree in the system — the model
weights.  A :class:`WeightStreamPlan` partitions a uniform-scan model's
parameter tree into **transfer groups**:

  group 0         the *embed* group (token/audio embedding + vision merger)
  groups 1..G     *layer groups*: contiguous slices ``[lo:hi)`` of the
                  stacked ``blocks`` leaves (``layers_per_group`` layers,
                  all leaves of those layers = ONE coalesced H2D request)
  group G+1       the *head* group (final norm + LM head; tied/codebook
                  heads re-read the embedding table, so their *fetch*
                  group also references the embed home leaves)

Between steps the weights live at their **home kind** — host numpy
(``pinned_host``) or :class:`~repro.core.spillstore.SpillStore` memmap
chunks (``disk_host``, one chunk per group = one disk request) — and
stream group-wise through the :class:`~repro.core.engine.TransferEngine`
while the previous group's compute runs:

  forward    fetch order ``embed, L0, .., Ln, head``; the head stage also
             computes the head/loss gradients (its params are in hand).
  backward   **reverse** fetch order ``Ln, .., L0, embed`` — each group is
             re-fetched and its vjp recomputes the group forward from the
             saved boundary activation (activation checkpointing at group
             granularity), so backward peak residency equals forward's.
  optimizer  home order; each group streams ``{grads, moments}`` H2D and
             its updated ``{params, moments}`` ride ONE pipelined D2H
             drain back to the home kind (the params writeback shares the
             drain with the streamed-AdamW moments).

The plan is also the **device-budget model**: ``peak_device_bytes(d)`` is
the sliding-window maximum of ``d + 2`` consecutive fetch-group byte
counts (``d`` prefetched + 1 landing + 1 being consumed), and
``max_distance_for_budget`` caps the adaptive prefetch window so the
streamed residency can never exceed ``--device-budget-mb`` no matter what
the controller learns.  Both take a ``cached_bytes`` term for the
:class:`~repro.core.residency.ResidencyCache` that keeps recently fetched
groups device-resident: window + cached bytes share one budget, and
``residency_capacity_bytes`` is the slack left above the widest allowed
window — the cache's byte ceiling (zero slack = cache inert = the plain
streaming schedule).

Where data lives never changes what is computed: every consumer runs the
same jitted per-group programs on the same values for every kind, so
streamed runs are bitwise-equal to the device-resident run (gated in
``benchmarks/weight_stream.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

__all__ = [
    "WeightGroup",
    "WeightStreamPlan",
    "weight_stream_supported",
    "PARAM_KINDS",
]

Pytree = Any

#: the CLI surface of ``--param-kind``
PARAM_KINDS = ("device", "pinned_host", "disk_host")

#: spill-store key namespace for parameter group chunks
_KEY_PREFIX = "wp"


def weight_stream_supported(cfg) -> bool:
    """True iff the arch's parameters can stream layer-group-wise: uniform
    blocks executed as a scan over stacked ``(L, ...)`` leaves.  Hetero
    (hybrid/ssm) stacks would need per-kind group programs — they keep the
    device-resident path."""
    return bool(cfg.uniform_blocks and cfg.use_scan)


def _tree_bytes(tree: Pytree) -> int:
    return sum(
        int(np.prod(np.shape(x), dtype=np.int64))
        * np.dtype(getattr(x, "dtype", np.float32)).itemsize
        for x in jax.tree.leaves(tree)
    )


def _to_host(x):
    """numpy view of a concrete leaf; tracers/ShapeDtypeStructs pass through
    so ``jax.eval_shape`` templates (driver restore) survive homing."""
    if isinstance(x, (jax.core.Tracer, jax.ShapeDtypeStruct)):
        return x
    return np.asarray(x)


def _concrete(tree: Pytree) -> bool:
    return all(
        not isinstance(x, (jax.core.Tracer, jax.ShapeDtypeStruct))
        for x in jax.tree.leaves(tree)
    )


@dataclasses.dataclass(frozen=True)
class WeightGroup:
    """One home group of the partition (a transfer group when fetched)."""

    index: int
    key: str  # pytree key in the home dict (sorted == home order)
    kind: str  # "embed" | "layers" | "head"
    lo: int = 0  # layer range for kind == "layers"
    hi: int = 0


class WeightStreamPlan:
    """Partition of a model parameter tree into transfer groups.

    Parameters
    ----------
    cfg:
        the :class:`~repro.configs.base.ModelConfig` (must satisfy
        :func:`weight_stream_supported`).
    abstract_params:
        ``jax.eval_shape`` tree of the *compute-dtype* params (what
        ``repro.train.steps.abstract_params`` returns) — shapes/dtypes
        drive the byte accounting and the group templates.
    layers_per_group:
        layers per stacked layer group.  ``None`` picks the largest count
        whose distance-1 peak fits ``device_budget_mb`` (falling back to 1).
    device_budget_mb:
        device-residency budget for streamed weights.  Enforced two ways:
        construction fails if even ``layers_per_group=1`` at distance 1
        cannot fit, and :meth:`max_distance_for_budget` caps the prefetch
        window at run time.  ``None`` = unbounded.
    """

    def __init__(
        self,
        cfg,
        abstract_params: Pytree,
        *,
        layers_per_group: Optional[int] = None,
        device_budget_mb: Optional[float] = None,
    ) -> None:
        if not weight_stream_supported(cfg):
            raise ValueError(
                f"{cfg.name}: weight streaming requires uniform scanned "
                "blocks (hybrid/ssm stacks keep the device-resident path)"
            )
        if "blocks" not in abstract_params:
            raise ValueError("param tree has no 'blocks' subtree")
        self.cfg = cfg
        self.n_layers = cfg.n_layers
        keys = set(abstract_params)
        self.embed_keys = tuple(k for k in ("embed", "vision") if k in keys)
        self.head_home_keys = tuple(k for k in ("ln_f", "head") if k in keys)
        #: tied / codebook heads read the embedding table at the head stage
        self.head_reads_embed = "head" not in keys or bool(cfg.n_codebooks)

        blocks_abs = abstract_params["blocks"]
        self._blocks_template = blocks_abs
        total_block_bytes = _tree_bytes(blocks_abs)
        self.per_layer_bytes = total_block_bytes // max(1, self.n_layers)
        self.embed_bytes = _tree_bytes(
            {k: abstract_params[k] for k in self.embed_keys}
        )
        head_home_bytes = _tree_bytes(
            {k: abstract_params[k] for k in self.head_home_keys}
        )
        embed_table_bytes = (
            _tree_bytes(abstract_params.get("embed", {}))
            if self.head_reads_embed
            else 0
        )
        self.head_home_bytes = head_home_bytes
        self.embed_table_bytes = embed_table_bytes
        self.head_fetch_bytes = head_home_bytes + embed_table_bytes
        self.total_param_bytes = (
            self.embed_bytes + head_home_bytes + total_block_bytes
        )

        budget = (
            int(device_budget_mb * 1e6) if device_budget_mb is not None else None
        )
        self.device_budget_bytes = budget
        if layers_per_group is None:
            layers_per_group = self._fit_layers_per_group(budget)
        if layers_per_group < 1:
            raise ValueError("layers_per_group must be >= 1")
        self.layers_per_group = min(layers_per_group, self.n_layers)

        groups: list[WeightGroup] = []
        groups.append(WeightGroup(0, "g000_embed", "embed"))
        lo = 0
        while lo < self.n_layers:
            hi = min(lo + self.layers_per_group, self.n_layers)
            i = len(groups)
            groups.append(
                WeightGroup(i, f"g{i:03d}_layers_{lo:03d}_{hi:03d}", "layers", lo, hi)
            )
            lo = hi
        groups.append(WeightGroup(len(groups), f"g{len(groups):03d}_head", "head"))
        self.groups = tuple(groups)
        self.layer_groups = tuple(g for g in groups if g.kind == "layers")
        self.n_groups = len(groups)

        if budget is not None and self.peak_device_bytes(1) > budget:
            raise ValueError(
                f"--device-budget-mb {device_budget_mb} cannot hold even a "
                f"distance-1 weight stream (peak "
                f"{self.peak_device_bytes(1) / 1e6:.1f} MB with "
                f"layers_per_group={self.layers_per_group}); raise the budget"
            )

    # ------------------------------------------------------------ byte model
    @staticmethod
    def _window_peak(
        embed_bytes: int,
        head_fetch_bytes: int,
        per_layer_bytes: int,
        n_layers: int,
        lpg: int,
        distance: int,
    ) -> int:
        """Sliding-window residency peak for a hypothetical ``lpg`` —
        shared by :meth:`peak_device_bytes` and the auto group-sizing so
        the fit can never pick a group size the validation then rejects."""
        seq = [embed_bytes]
        lo = 0
        while lo < n_layers:
            hi = min(lo + lpg, n_layers)
            seq.append((hi - lo) * per_layer_bytes)
            lo = hi
        seq.append(head_fetch_bytes)
        w = max(1, distance + 2)
        return max(sum(seq[i : min(i + w, len(seq))]) for i in range(len(seq)))

    def group_bytes(self, g: WeightGroup, *, fetch: bool = True) -> int:
        if g.kind == "embed":
            return self.embed_bytes
        if g.kind == "head":
            # home bytes exclude the tied embed-table re-read (which is the
            # embed TABLE, not the whole embed group — vision towers ride
            # the embed group but are never re-read at the head stage)
            return self.head_fetch_bytes if fetch else self.head_home_bytes
        return (g.hi - g.lo) * self.per_layer_bytes

    def fetch_sequence_bytes(self) -> list[int]:
        """Per-group H2D bytes in forward fetch order."""
        return [self.group_bytes(g) for g in self.groups]

    def peak_device_bytes(self, distance: int, cached_bytes: int = 0) -> int:
        """Streamed-weight residency model: with ``distance`` groups
        prefetched, at most ``distance + 2`` consecutive fetch groups are
        device-resident at once (in flight + landing + being consumed).
        The backward pass walks the same sequence reversed, so the same
        sliding-window maximum bounds both passes.

        ``cached_bytes`` adds a residency-cache ceiling on top of the
        window: cached groups are extra device residency the stream does
        not see (a cache hit transfers zero bytes, so it never lands in
        the window term — the sum is a conservative bound, never an
        undercount)."""
        seq = self.fetch_sequence_bytes()
        w = max(1, distance + 2)
        return cached_bytes + max(
            sum(seq[i : min(i + w, len(seq))]) for i in range(len(seq))
        )

    def _peak_for_lpg(self, lpg: int, distance: int) -> int:
        return self._window_peak(
            self.embed_bytes,
            self.head_fetch_bytes,
            self.per_layer_bytes,
            self.n_layers,
            lpg,
            distance,
        )

    def max_distance_for_budget(self, cap: int = 8, cached_bytes: int = 0) -> int:
        """Largest prefetch distance whose modeled peak fits the budget —
        the engine's ``max_distance`` so the adaptive controller can never
        learn its way past the budget.  ``cached_bytes`` reserves residency
        for the group cache: window + cached bytes share the one budget, so
        a caller pinning cache capacity gets a correspondingly narrower
        window cap."""
        if self.device_budget_bytes is None:
            return cap
        d = 1
        while (
            d < cap
            and self.peak_device_bytes(d + 1, cached_bytes)
            <= self.device_budget_bytes
        ):
            d += 1
        return d

    def residency_capacity_bytes(self, cap: int = 8) -> Optional[int]:
        """Byte ceiling for the weight-residency group cache: the budget
        slack ABOVE the widest allowed prefetch window, so streaming keeps
        its latency-optimal window and cached + streamed bytes still can
        never exceed the budget.  ``None`` (no budget) = unbounded; zero
        slack = an inert cache = exactly the uncached schedule."""
        if self.device_budget_bytes is None:
            return None
        return max(
            0,
            self.device_budget_bytes
            - self.peak_device_bytes(self.max_distance_for_budget(cap)),
        )

    def _fit_layers_per_group(self, budget: Optional[int]) -> int:
        if budget is None:
            return max(1, self.n_layers // 4)
        for lpg in range(self.n_layers, 1, -1):
            # the EXACT distance-1 sliding-window peak (not a per-group
            # approximation — a window holds up to 3 consecutive groups)
            if self._peak_for_lpg(lpg, 1) <= budget:
                return lpg
        return 1

    def grouping(self) -> list[dict]:
        """JSON-serializable description of the group partition.  Recorded
        in checkpoint/run metadata; the elastic resharder compares it (via
        the group keys, which encode kind + layer bounds) against a
        restored checkpoint's to decide whether host/disk-homed state must
        be re-partitioned."""
        return [
            {"key": g.key, "kind": g.kind, "lo": g.lo, "hi": g.hi}
            for g in self.groups
        ]

    # ------------------------------------------------------------- slicing
    def home_group(self, params: Pytree, g: WeightGroup) -> Pytree:
        """The group's slice of a *full* param tree (views, no copies)."""
        if g.kind == "embed":
            return {k: params[k] for k in self.embed_keys}
        if g.kind == "head":
            return {k: params[k] for k in self.head_home_keys}
        return jax.tree.map(lambda a: a[g.lo : g.hi], params["blocks"])

    def init_home(self, params: Pytree) -> dict:
        """Home representation: ``{"groups": {key: group_tree}}`` with
        host-numpy leaves (a plain pytree — checkpointable as-is).
        Abstract leaves pass through for ``eval_shape`` templates."""
        return {
            "groups": {
                g.key: jax.tree.map(_to_host, self.home_group(params, g))
                for g in self.groups
            }
        }

    def assemble(self, home: dict) -> Pytree:
        """Full host param tree from a home (layer groups concatenated) —
        for conversion/export; the streamed paths never call this."""
        out: dict = {}
        for g in self.groups:
            if g.kind == "layers":
                continue
            out.update({k: v for k, v in home["groups"][g.key].items()})
        parts = [home["groups"][g.key] for g in self.layer_groups]
        out["blocks"] = jax.tree.map(
            lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0), *parts
        )
        return out

    # ------------------------------------------------------------- fetching
    def fetch_group(self, home: dict, g: WeightGroup, cache=None) -> Pytree:
        """The pytree actually streamed for a stage.  Identical to the home
        group except the head stage of tied/codebook archs, whose fetch
        group additionally references the embed home leaves (coalesced into
        the same staging buffer — still ONE H2D request per device).

        ``cache`` (a :class:`~repro.core.residency.ResidencyCache` keyed by
        group key, holding device-resident HOME trees) substitutes resident
        groups in place: a whole-group hit hands back committed
        ``jax.Array`` leaves that pass through the engine at zero H2D
        requests.  The tied head's embed-table leaf is borrowed from the
        resident embed group even on a head miss, so the table's bytes are
        never re-read across the link while its source group is resident."""
        tree = cache.lookup(g.key) if cache is not None else None
        if tree is None:
            tree = home["groups"][g.key]
        if g.kind == "head" and self.head_reads_embed:
            tree = dict(tree)
            emb = cache.peek(self.groups[0].key) if cache is not None else None
            tree["embed"] = (
                emb["embed"]
                if emb is not None
                else home["groups"][self.groups[0].key]["embed"]
            )
        return tree

    def fetch_groups_forward(self, home: dict, cache=None) -> list:
        return [self.fetch_group(home, g, cache) for g in self.groups]

    def fetch_thunks_forward(self, home: dict, cache) -> list:
        """Forward fetch sequence as zero-arg thunks, resolved by the
        executor at SUBMIT time: residency decisions must see the cache as
        it is when the transfer would be issued, not when the step was
        scheduled (the embed group a head fetch wants to borrow from may
        only become resident mid-pass)."""
        return [
            (lambda g=g: self.fetch_group(home, g, cache)) for g in self.groups
        ]

    def cache_home_tree(self, g: WeightGroup, fetched: Pytree) -> Pytree:
        """The cacheable HOME part of a landed fetch group: the tied head's
        borrowed embed-table leaf belongs to the embed group's entry, so it
        is stripped rather than double-counted (and double-retained)."""
        if g.kind == "head" and self.head_reads_embed:
            return {k: fetched[k] for k in self.head_home_keys}
        return fetched

    def split_head_grads(self, dp_head: Pytree) -> tuple[Pytree, Optional[Pytree]]:
        """Split the head *fetch* group's grads into (head-home part, embed
        table part or None) — tied archs sum the embed part into the embed
        stage's gradient."""
        home = {k: dp_head[k] for k in self.head_home_keys}
        embed = dp_head.get("embed") if self.head_reads_embed else None
        return home, embed

    # ------------------------------------------------------------ shardings
    def group_shardings(self, p_shardings: Optional[Pytree]):
        """Per-fetch-group sharding trees from a full-params sharding tree
        (slicing a stacked leaf keeps its rank, so the blocks leaf sharding
        applies to every layer-group slice unchanged)."""
        if p_shardings is None:
            return None
        out = []
        for g in self.groups:
            if g.kind == "embed":
                out.append({k: p_shardings[k] for k in self.embed_keys})
            elif g.kind == "head":
                tree = {k: p_shardings[k] for k in self.head_home_keys}
                if self.head_reads_embed:
                    tree = dict(tree)
                    tree["embed"] = p_shardings["embed"]
                out.append(tree)
            else:
                out.append(p_shardings["blocks"])
        return out

    def home_group_shardings(self, p_shardings: Optional[Pytree]):
        """Home-order sharding trees (no tied-embed aliasing) — the layout
        the optimizer phase stages grads/moments at."""
        if p_shardings is None:
            return None
        out = []
        for g in self.groups:
            if g.kind == "embed":
                out.append({k: p_shardings[k] for k in self.embed_keys})
            elif g.kind == "head":
                out.append({k: p_shardings[k] for k in self.head_home_keys})
            else:
                out.append(p_shardings["blocks"])
        return out

    # ------------------------------------------------------------- spilling
    def spill_key(self, g: WeightGroup) -> str:
        return f"{_KEY_PREFIX}/{g.key}"

    def spill_home(self, home: dict, store) -> dict:
        """Re-home every group at the ``DiskHost`` tier: one spill-store
        chunk per group (= one disk request per fetch), leaves replaced by
        memmap views.  Abstract templates pass through; groups already
        disk-resident are not rewritten."""
        from repro.core.spillstore import is_disk_leaf

        groups = {}
        for g in self.groups:
            tree = home["groups"][g.key]
            if not _concrete(tree):
                return home
            if all(is_disk_leaf(v) for v in jax.tree.leaves(tree)):
                groups[g.key] = tree
                continue
            store.put(self.spill_key(g), tree)
            groups[g.key] = store.get(self.spill_key(g))
        return {"groups": groups}

    def is_spilled(self, home: dict) -> bool:
        from repro.core.spillstore import is_disk_leaf

        return any(
            is_disk_leaf(v)
            for v in jax.tree.leaves(home["groups"])
        )

    def device_home(self, home: dict, p_shardings: Optional[Pytree] = None) -> dict:
        """Place every home group on device (the ``param_kind=device``
        baseline: fetch groups pass through the engine by reference)."""
        shardings = self.home_group_shardings(p_shardings)
        groups = {}
        for i, g in enumerate(self.groups):
            tree = home["groups"][g.key]
            if shardings is None:
                groups[g.key] = jax.device_put(tree)
            else:
                groups[g.key] = jax.device_put(tree, shardings[i])
        return {"groups": groups}

    def __repr__(self) -> str:  # pragma: no cover - trivial
        return (
            f"WeightStreamPlan({self.cfg.name}, n_groups={self.n_groups}, "
            f"layers_per_group={self.layers_per_group}, "
            f"total={self.total_param_bytes / 1e6:.1f}MB)"
        )

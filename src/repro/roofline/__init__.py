from repro.roofline.hw import V5E
from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    cost_terms,
    roofline_report,
)

__all__ = ["V5E", "cost_terms", "collective_bytes_from_hlo", "roofline_report"]

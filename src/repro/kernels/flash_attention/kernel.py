"""Flash attention (blockwise online-softmax) with bounded VMEM working set.

The VMEM discipline is the paper's on-core buffer discipline: only
``(block_q x H)`` of queries and ``(block_kv x H)`` of keys/values are ever
resident in fast memory; K/V blocks stream through the implicit BlockSpec
grid pipeline (the TPU's hardware analogue of the paper's prefetch ring —
Mosaic double-buffers grid operands automatically, i.e. ``distance=1``).

Causal + sliding-window masking; fully-masked K/V blocks are skipped via the
grid (we never *launch* them) for the causal lower triangle, and cheaply
via ``pl.when`` for window-expired blocks.

GQA: grid is over KV heads; the q block holds all ``G = N/KH`` query heads of
the group, folded into the row dimension (``block_q * G`` rows), so the MXU
matmul is dense and KV is never replicated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jaxcompat import tpu_compiler_params

NEG_INF = -1e30
LANES = 128


def _flash_kernel(
    q_ref,  # (1, block_q, G, H)
    k_ref,  # (1, block_kv, 1, H)
    v_ref,  # (1, block_kv, 1, H)
    o_ref,  # (1, block_q, G, H)
    m_ref,  # (block_q * G, LANES) f32 — running max
    l_ref,  # (block_q * G, LANES) f32 — running sum
    acc_ref,  # (block_q * G, H) f32
    *,
    causal: bool,
    window: int,
    q_offset: int,
    block_q: int,
    block_kv: int,
    n_kv_blocks: int,
    sm_scale: float,
):
    qi = pl.program_id(2)  # query block index
    ki = pl.program_id(3)  # kv block index

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = q_ref.shape[2]
    h = q_ref.shape[3]
    rows = block_q * g

    q_start = qi * block_q + q_offset  # absolute position of query row 0
    k_start = ki * block_kv

    # Skip blocks that the mask fully excludes. Two cases:
    #   causal:   k_start > q_end  (block strictly above the diagonal)
    #   windowed: k_end <= q_start - window + 1 (block entirely expired)
    q_end = q_start + block_q - 1
    run = jnp.asarray(True)
    if causal:
        run &= k_start <= q_end
    if window:
        run &= (k_start + block_kv - 1) > (q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].reshape(rows, h)  # (bq*G, H) — group heads folded into rows
        k = k_ref[0, :, 0, :]  # (bkv, H)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (rows, bkv)
        s = s * sm_scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (rows, block_kv), 0) // g
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (rows, block_kv), 1)
        mask = jnp.ones((rows, block_kv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (rows, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (all NEG_INF): keep exp finite
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zero output
        out = (acc_ref[...] / l).astype(o_ref.dtype)
        o_ref[0] = out.reshape(block_q, g, h)


def flash_attention_p(
    q: jax.Array,  # (BKH, S, G, H)  — batch*kv_heads flattened, G query heads
    k: jax.Array,  # (BKH, T, 1, H)
    v: jax.Array,  # (BKH, T, 1, H)
    *,
    causal: bool,
    window: int,
    q_offset: int,
    block_q: int,
    block_kv: int,
    interpret: bool,
) -> jax.Array:
    bkh, s, g, h = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_kv == 0, (q.shape, k.shape, block_q, block_kv)
    n_kv_blocks = t // block_kv

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_kv=block_kv,
        n_kv_blocks=n_kv_blocks,
        sm_scale=h ** -0.5,
    )
    grid = (bkh, 1, s // block_q, n_kv_blocks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, g, h), lambda b, _, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, h), lambda b, _, i, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, h), lambda b, _, i, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, g, h), lambda b, _, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, LANES), jnp.float32),
            pltpu.VMEM((block_q * g, LANES), jnp.float32),
            pltpu.VMEM((block_q * g, h), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
    )(q, k, v)

"""GQA attention: full / sliding-window, with ring-buffer KV caches.

Design notes (sharding-aware):
  * qkv/o weights are kept 3-D ``(D, N, H)`` / ``(N, H, D)`` so heads are an
    einsum dim — no reshapes across sharded axes, GSPMD shards heads (or
    head_dim for archs whose kv-head count doesn't divide the model axis)
    without data movement.
  * GQA is computed grouped: q ``(B,S,K,G,H)`` against k/v ``(B,T,K,H)`` —
    KV heads are never materialized ``G``-fold.
  * softmax in f32; scores dtype f32.

Cache layout:
  full attention: ``{"k": (B, T, K, H), "v": ...}`` — slot ``t`` holds
  position ``t``; validity is ``slot <= pos``.
  sliding window:  ``{"k": (B, W, K, H), "v": ..., "slot_pos": (W,) int32}``
  — ring buffer; ``slot_pos[j]`` is the absolute position held in slot ``j``
  (-1 = empty).  This is what makes 500k-token decode O(window) for SWA
  archs (mixtral) and O(window=2048) for RecurrentGemma's local attention.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig) -> Params:
    d, n, k, h = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p: Params = {
        "wq": layers.fan_in_init(ks[0], (d, n, h), d),
        "wk": layers.fan_in_init(ks[1], (d, k, h), d),
        "wv": layers.fan_in_init(ks[2], (d, k, h), d),
        "wo": layers.fan_in_init(ks[3], (n, h, d), n * h),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n, h), jnp.float32)
        p["bk"] = jnp.zeros((k, h), jnp.float32)
        p["bv"] = jnp.zeros((k, h), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rms_head_norm(ks[4], h)
        p["k_norm"] = layers.init_rms_head_norm(ks[5], h)
    return p


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16) -> Params:
    k, h = cfg.n_kv_heads, cfg.head_dim
    cache: Params = {
        "k": jnp.zeros((batch, cache_len, k, h), dtype),
        "v": jnp.zeros((batch, cache_len, k, h), dtype),
    }
    if cfg.attn_type == "swa" or (cfg.family == "hybrid" and cfg.window):
        cache["slot_pos"] = jnp.full((cache_len,), -1, jnp.int32)
    return cache


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for the dry-run."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len, dtype))


# ---------------------------------------------------------------------------
# qkv projection (shared by all modes)
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array):
    dt = x.dtype
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = layers.head_norm_apply(p["q_norm"], q)
        k = layers.head_norm_apply(p["k_norm"], k)
    return q, k, v


def _gqa_attend(
    cfg: ModelConfig,
    q: jax.Array,  # (B, S, N, H)
    k: jax.Array,  # (B, T, K, H)
    v: jax.Array,  # (B, T, K, H)
    mask: jax.Array,  # (S, T) or (B, S, T) bool — True = attend
) -> jax.Array:
    b, s, n, h = q.shape
    kh = k.shape[2]
    g = n // kh
    qg = q.reshape(b, s, kh, g, h)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (h ** -0.5)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, n, h)


def _gqa_attend_chunked(
    cfg: ModelConfig,
    q: jax.Array,  # (B, S, N, H)
    k: jax.Array,  # (B, T, K, H)
    v: jax.Array,
    *,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Blockwise-over-queries attention: an XLA-level flash analogue.

    Peak score memory is ``(B, K, G, block_q, T)`` instead of
    ``(B, K, G, S, T)`` — the same bounded-working-set discipline the paper
    applies to on-core buffers, needed for the 32k-sequence shapes.  Numerics
    match :func:`_gqa_attend` exactly (each row's softmax sees its full T).
    """
    b, s, n, h = q.shape
    t = k.shape[1]
    bq = cfg.attn_chunk_q or 512
    if s <= bq or s % bq != 0:
        mask = causal_mask(s, window, q_offset)
        return _gqa_attend(cfg, q, k, v, mask)
    nb = s // bq
    qb = jnp.moveaxis(q.reshape(b, nb, bq, n, h), 1, 0)  # (nb, B, bq, N, H)
    kpos = jnp.arange(t)[None, :]

    @jax.checkpoint
    def block(i, qblk):
        # remat per q-block: otherwise the scan's backward saves every
        # block's (B, H_loc, bq, T) f32 score tensor (GBs per layer).
        qpos = i * bq + jnp.arange(bq)[:, None] + q_offset
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        return _gqa_attend(cfg, qblk, k, v, mask)

    def body(_, args):
        i, qblk = args
        return None, block(i, qblk)

    _, out = jax.lax.scan(body, None, (jnp.arange(nb), qb))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, n, h)


def causal_mask(s: int, window: int = 0, offset: int = 0) -> jax.Array:
    """(S, S+offset) causal (optionally banded) mask.  ``offset`` supports
    attending over a prefix (queries start at position ``offset``)."""
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(s + offset)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


# ---------------------------------------------------------------------------
# modes
# ---------------------------------------------------------------------------

def attention_train(
    cfg: ModelConfig, p: Params, x: jax.Array, angles: Optional[jax.Array]
) -> jax.Array:
    """Full-sequence causal attention (training / scoring)."""
    from repro.models import rope as _rope

    q, k, v = _project_qkv(cfg, p, x)
    if angles is not None:
        q = _rope.apply_rope(q, angles)
        k = _rope.apply_rope(k, angles)
    window = cfg.window if cfg.attn_type == "swa" else 0
    if cfg.attn_impl == "pallas" and window == 0:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=True)
    elif cfg.attn_impl == "chunked":
        out = _gqa_attend_chunked(cfg, q, k, v, window=window)
    else:
        mask = causal_mask(x.shape[1], window)
        out = _gqa_attend(cfg, q, k, v, mask)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))


def _window_of(cfg: ModelConfig) -> int:
    return cfg.window if (cfg.attn_type == "swa" or cfg.family == "hybrid") else 0


def attention_prefill(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    angles: Optional[jax.Array],
    cache: Params,
) -> tuple[jax.Array, Params]:
    """Causal attention over the prompt + populate the KV cache.

    The prompt length S may exceed a windowed cache (W slots): only the last
    W keys/values are retained, matching ring-buffer decode.
    """
    from repro.models import rope as _rope

    q, k, v = _project_qkv(cfg, p, x)
    if angles is not None:
        q = _rope.apply_rope(q, angles)
        k = _rope.apply_rope(k, angles)
    window = _window_of(cfg)
    if cfg.attn_impl == "pallas" and not window:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=True)
    elif cfg.attn_impl == "chunked":
        out = _gqa_attend_chunked(cfg, q, k, v, window=window)
    else:
        out = _gqa_attend(cfg, q, k, v, causal_mask(x.shape[1], window))
    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))

    s = x.shape[1]
    cache_len = cache["k"].shape[1]
    if "slot_pos" in cache:
        # keep the last `cache_len` tokens, placed at their ring slots
        take = min(s, cache_len)
        positions = jnp.arange(s - take, s)
        slots = positions % cache_len
        new_k = cache["k"].at[:, slots].set(k[:, s - take :].astype(cache["k"].dtype))
        new_v = cache["v"].at[:, slots].set(v[:, s - take :].astype(cache["v"].dtype))
        slot_pos = cache["slot_pos"].at[slots].set(positions.astype(jnp.int32))
        cache = {"k": new_k, "v": new_v, "slot_pos": slot_pos}
    else:
        take = min(s, cache_len)
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k[:, :take].astype(cache["k"].dtype), 0, axis=1
        )
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v[:, :take].astype(cache["v"].dtype), 0, axis=1
        )
        cache = {"k": new_k, "v": new_v}
    return out, cache


def attention_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, 1, D)
    angles: Optional[jax.Array],  # (B, 1, H/2) for this position
    cache: Params,
    pos: jax.Array,  # scalar int32 — next position to write; or (B,) per-slot
) -> tuple[jax.Array, Params]:
    """One decode step with KV-cache append (ring for windowed archs).

    ``pos`` may be a per-batch-slot vector (the serving path's continuous
    batching: every slot decodes its own context position).  Vector ``pos``
    requires a full-attention cache (no ring ``slot_pos``, which is shared
    across the batch); the scalar path is unchanged.
    """
    from repro.models import rope as _rope

    q, k, v = _project_qkv(cfg, p, x)
    if angles is not None:
        q = _rope.apply_rope(q, angles)
        k = _rope.apply_rope(k, angles)

    cache_len = cache["k"].shape[1]
    if jnp.ndim(pos) == 1:
        if "slot_pos" in cache:
            raise NotImplementedError(
                "per-slot decode positions require a full-attention cache "
                "(ring slot_pos is shared across the batch)"
            )
        rows = jnp.arange(cache["k"].shape[0])
        new_k = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
        new_v = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
        mask = jnp.arange(cache_len)[None, :] <= pos[:, None]  # (B, T)
        new_cache = {"k": new_k, "v": new_v}
        out = _gqa_attend(
            cfg, q, new_k.astype(q.dtype), new_v.astype(q.dtype), mask[:, None, :]
        )
        out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
        return out, new_cache
    if "slot_pos" in cache:
        slot = jnp.mod(pos, cache_len)
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1
        )
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1
        )
        slot_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], pos[None].astype(jnp.int32), slot, axis=0
        )
        valid = (slot_pos >= 0) & (slot_pos >= pos - cache_len + 1) & (slot_pos <= pos)
        new_cache = {"k": new_k, "v": new_v, "slot_pos": slot_pos}
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, axis=1
        )
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, axis=1
        )
        valid = jnp.arange(cache_len) <= pos
        new_cache = {"k": new_k, "v": new_v}

    mask = valid[None, None, :]  # (1, 1, T) -> broadcast (B, S=1, T)
    out = _gqa_attend(cfg, q, new_k.astype(q.dtype), new_v.astype(q.dtype), mask[0])
    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache

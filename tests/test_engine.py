"""Transfer-engine tests: coalescing, buffer reuse, pipelined writeback,
adaptive prefetch distance (ISSUE 1 tentpole).

The invariants the paper's runtime depends on:
  * coalescing never changes bytes — packed/unpacked leaves are bitwise
    identical to per-leaf transfers, for every dtype,
  * the engine's schedule never changes results — every (config, mode,
    distance) setting equals the seed executor and plain numpy,
  * 'rw' write-back preserves group order even when pipelined,
  * the adaptive controller converges instead of oscillating.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    AdaptiveDistance,
    EngineConfig,
    GroupLayout,
    LinkModel,
    TransferEngine,
    static_auto_distance,
)
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import AUTO, PrefetchSpec

SEED_CONFIG = EngineConfig(coalesce=False, async_writeback=False)


def _mixed_group(rng):
    return {
        "f32": rng.standard_normal((5, 7)).astype(np.float32),
        "f16": rng.standard_normal((3, 4)).astype(np.float16),
        "i32": rng.integers(-1000, 1000, (11,)).astype(np.int32),
        "u8": rng.integers(0, 255, (13,)).astype(np.uint8),
        "bool": rng.integers(0, 2, (9,)).astype(bool),
    }


# ---------------------------------------------------------------------------
# coalescing: pack/unpack is bitwise exact
# ---------------------------------------------------------------------------

def test_layout_pack_unpack_bitwise_roundtrip():
    rng = np.random.default_rng(0)
    group = _mixed_group(rng)
    layout = GroupLayout(group)
    leaves = jax.tree.leaves(group)
    staging = layout.new_staging()
    layout.pack_into(leaves, staging)
    flat = jax.device_put(staging)
    out = layout.unpack(flat, leaves)
    for a, b in zip(jax.tree.leaves(group), jax.tree.leaves(out)):
        assert np.asarray(b).dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(b), a)


def test_coalesced_equals_per_leaf_transfer_bitwise():
    rng = np.random.default_rng(1)
    group = _mixed_group(rng)
    results = {}
    for name, cfg in (("coalesced", EngineConfig()), ("per_leaf", SEED_CONFIG)):
        with TransferEngine(cfg) as eng:
            fut = eng.submit_group(0, group)
            fut.wait()
            results[name] = jax.tree.map(np.asarray, fut.group())
    for a, b in zip(
        jax.tree.leaves(results["coalesced"]), jax.tree.leaves(results["per_leaf"])
    ):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


def test_request_accounting_and_passthrough():
    """Coalescing: 1 request per group regardless of leaf count; leaves
    already device-resident are passed by reference, never re-sent."""
    rng = np.random.default_rng(2)
    host = {"a": rng.standard_normal((4, 4)).astype(np.float32),
            "b": rng.standard_normal((8,)).astype(np.float32)}
    with TransferEngine() as eng:
        fut = eng.submit_group(0, host)
        fut.wait()
        assert fut.n_requests == 1

        dev_leaf = jnp.arange(6.0)
        mixed = {"host": host["a"], "dev": dev_leaf}
        fut2 = eng.submit_group(1, mixed)
        fut2.wait()
        assert fut2.n_requests == 1
        staged = fut2.group()
        assert staged["dev"] is dev_leaf  # by reference, not copied
    with TransferEngine(SEED_CONFIG) as eng:
        fut = eng.submit_group(0, host)
        fut.wait()
        assert fut.n_requests == 2  # one per host leaf (the seed's cost)


def test_coalesced_canonicalizes_wide_dtypes_like_device_put():
    """float64/int64 host leaves must coalesce to the same (canonical f32/
    i32) result the per-leaf device_put path produces (found in review)."""
    group = (np.arange(6, dtype=np.float64).reshape(2, 3),
             np.arange(4, dtype=np.int64))
    results = {}
    for name, cfg in (("coalesced", EngineConfig()), ("per_leaf", SEED_CONFIG)):
        with TransferEngine(cfg) as eng:
            fut = eng.submit_group(0, group)
            fut.wait()
            results[name] = jax.tree.map(np.asarray, fut.group())
    for a, b in zip(
        jax.tree.leaves(results["coalesced"]), jax.tree.leaves(results["per_leaf"])
    ):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


def test_failed_run_does_not_leak_writebacks_into_next_run():
    """An exception mid-run leaves pending writeback tickets; the next run
    on the same executor must not drain them (found in review)."""
    calls = {"n": 0}

    def apply(carry, g):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected")
        return carry, g * 2.0

    groups = [np.full((2,), float(i), np.float32) for i in range(4)]
    with HostStreamExecutor(apply, writeback=True) as ex:
        with pytest.raises(RuntimeError):
            ex.run(jnp.zeros(()), groups, mode="prefetch")
        _, outs = ex.run(jnp.zeros(()), [groups[3], groups[2]], mode="prefetch")
    assert len(outs) == 2
    np.testing.assert_array_equal(outs[0], groups[3] * 2.0)
    np.testing.assert_array_equal(outs[1], groups[2] * 2.0)


def test_adaptive_controller_persists_across_runs():
    """The train loop issues one short run() per step; the learned window
    must carry over instead of restarting at min_distance (found in
    review)."""
    @jax.jit
    def apply(carry, g):
        return carry + jnp.sum(g)

    groups = [np.ones((32, 32), np.float32)] * 4
    link = LinkModel(request_s=1e-4, bandwidth_Bps=1e9, latency_s=2e-3)
    with HostStreamExecutor(apply, engine_config=EngineConfig(link=link)) as ex:
        st = StreamStats()
        for _ in range(6):  # six "training steps" of 4 groups each
            ex.run(jnp.zeros(()), groups, mode="prefetch",
                   prefetch=PrefetchSpec(buffer_size=10, distance=AUTO), stats=st)
        trace = list(st.distance_trace)
    # with a fresh controller per run the window could never exceed ~3 for
    # 4-group runs; persistence lets later steps start where earlier ended
    assert trace[-4] > 1


def test_staging_pool_is_reused_not_grown():
    """Buffer reuse: many groups of one layout allocate O(slots) staging
    buffers, not O(groups)."""
    rng = np.random.default_rng(3)
    groups = [
        {"x": rng.standard_normal((16,)).astype(np.float32)} for _ in range(32)
    ]
    with TransferEngine() as eng:
        for i, grp in enumerate(groups):
            eng.submit_group(i, grp).wait()
        assert eng.staging_allocs <= eng.config.staging_slots + 1


# ---------------------------------------------------------------------------
# executor: every (config, mode, distance) setting is value-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["eager", "on_demand", "prefetch"])
@pytest.mark.parametrize("config", [None, SEED_CONFIG], ids=["engine", "seed"])
def test_executor_schedule_invariance(mode, config):
    @jax.jit
    def apply(carry, g):
        x, w = g
        return carry + jnp.sum(x @ w)

    rng = np.random.default_rng(4)
    groups = [
        (rng.standard_normal((4, 8)).astype(np.float32),
         rng.standard_normal((8, 2)).astype(np.float32))
        for _ in range(7)
    ]
    expected = sum(float(np.sum(x @ w)) for x, w in groups)
    with HostStreamExecutor(apply, engine_config=config) as ex:
        st = StreamStats()
        out, _ = ex.run(
            jnp.zeros(()), groups, mode=mode,
            prefetch=PrefetchSpec(buffer_size=4, distance=2), stats=st,
        )
    np.testing.assert_allclose(float(out), expected, rtol=1e-5)
    assert st.n_groups == 7
    if config is None:
        assert st.requests_per_group == 1.0  # the tentpole claim
    else:
        assert st.requests_per_group == 2.0  # one per leaf


@pytest.mark.parametrize("distance", [1, 3, AUTO])
def test_executor_distance_sweep_matches_eager(distance):
    @jax.jit
    def apply(carry, g):
        return carry + jnp.sum(g)

    groups = [np.full((3, 3), float(i), np.float32) for i in range(9)]
    with HostStreamExecutor(apply) as ex:
        ref, _ = ex.run(jnp.zeros(()), groups, mode="eager")
        out, _ = ex.run(
            jnp.zeros(()), groups, mode="prefetch",
            prefetch=PrefetchSpec(buffer_size=10, distance=distance),
        )
    assert float(out) == float(ref)


# ---------------------------------------------------------------------------
# pipelined writeback ('rw' access)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["eager", "on_demand", "prefetch"])
def test_async_writeback_preserves_group_order(mode):
    @jax.jit
    def apply(carry, g):
        return carry, g * 2.0

    groups = [np.full((2, 2), float(i), np.float32) for i in range(8)]
    outs = {}
    for name, cfg in (("async", EngineConfig()), ("sync", SEED_CONFIG)):
        with HostStreamExecutor(apply, writeback=True, engine_config=cfg) as ex:
            st = StreamStats()
            _, o = ex.run(
                jnp.zeros(()), groups, mode=mode,
                prefetch=PrefetchSpec(buffer_size=3, distance=2), stats=st,
            )
            outs[name] = o
            assert st.d2h_requests > 0
    for i in range(8):
        np.testing.assert_array_equal(outs["async"][i], groups[i] * 2.0)
        np.testing.assert_array_equal(outs["async"][i], outs["sync"][i])


def test_writeback_drain_returns_host_arrays():
    @jax.jit
    def apply(carry, g):
        return carry, {"y": g["x"] + 1.0}

    groups = [{"x": np.full((4,), float(i), np.float32)} for i in range(5)]
    with HostStreamExecutor(apply, writeback=True) as ex:
        _, outs = ex.run(jnp.zeros(()), groups, mode="prefetch")
    assert len(outs) == 5
    for i, o in enumerate(outs):
        assert isinstance(o["y"], np.ndarray)
        np.testing.assert_array_equal(o["y"], groups[i]["x"] + 1.0)


# ---------------------------------------------------------------------------
# adaptive prefetch distance
# ---------------------------------------------------------------------------

def test_adaptive_distance_grows_on_stall():
    c = AdaptiveDistance(initial=1, max_distance=8, wait_eps_s=1e-4)
    for _ in range(5):
        c.observe(1e-2)  # heavy stalls
    assert c.distance > 1


def test_adaptive_distance_shrinks_when_idle():
    c = AdaptiveDistance(initial=6, max_distance=8, wait_eps_s=1e-4, shrink_after=2)
    for _ in range(20):
        c.observe(0.0)
    assert c.distance == c.min_distance


def test_adaptive_distance_sticky_floor_prevents_oscillation():
    c = AdaptiveDistance(initial=3, max_distance=8, wait_eps_s=1e-4, shrink_after=1)
    c.observe(0.0)  # shrink 3 -> 2
    assert c.distance == 2
    c.observe(1e-2)  # stall right after shrinking: 3 was minimal
    assert c.distance == 3
    for _ in range(10):
        c.observe(0.0)
    assert c.distance == 3  # floor holds: no repeated shrink/stall cycle


def test_auto_distance_converges_with_emulated_link():
    """distance='auto' on a slow emulated link: window grows off 1, waits
    after convergence are lower than the steady distance=1 waits."""
    @jax.jit
    def apply(carry, g):
        return carry + jnp.sum(g * g)

    rng = np.random.default_rng(5)
    groups = [rng.standard_normal((64, 64)).astype(np.float32) for _ in range(24)]
    link = LinkModel(request_s=1e-4, bandwidth_Bps=2e9, latency_s=2e-3)
    waits = {}
    vals = {}
    for dist in (1, AUTO):
        with HostStreamExecutor(
            apply, engine_config=EngineConfig(link=link)
        ) as ex:
            st = StreamStats()
            out, _ = ex.run(
                jnp.zeros(()), groups, mode="prefetch",
                prefetch=PrefetchSpec(buffer_size=16, distance=dist), stats=st,
            )
            waits[dist] = list(st.wait_per_group)
            vals[dist] = float(out)
    assert vals[1] == vals[AUTO]  # schedule never changes values
    # steady state: second half of the run
    tail = lambda w: sum(w[len(w) // 2:])
    assert tail(waits[AUTO]) < tail(waits[1])


def test_prefetch_spec_auto_validation():
    s = PrefetchSpec(buffer_size=4, distance=AUTO)
    assert s.is_auto and not s.on_demand
    assert s.numeric_distance(3) == 3
    assert PrefetchSpec(distance=2).numeric_distance(3) == 2
    with pytest.raises(ValueError):
        PrefetchSpec(distance="nonsense")
    assert static_auto_distance(10) == 4
    assert static_auto_distance(2) == 1


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------

def test_stream_stats_row_is_json_serializable():
    @jax.jit
    def apply(carry, g):
        return carry + jnp.sum(g)

    groups = [np.ones((2, 2), np.float32)] * 4
    with HostStreamExecutor(apply) as ex:
        st = StreamStats()
        ex.run(jnp.zeros(()), groups, mode="prefetch", stats=st)
    row = st.as_row()
    json.dumps(row)  # must not raise
    assert row["requests_per_group"] == 1.0
    assert sum(row["wait_hist"].values()) == 4


def test_stream_stats_reset():
    st = StreamStats(mode="prefetch")
    st.n_transfers = 5
    st.wait_per_group.append(0.1)
    st.reset()
    assert st.mode == "prefetch"
    assert st.n_transfers == 0 and len(st.wait_per_group) == 0


# ---------------------------------------------------------------------------
# streamed optimizer update (the train-loop wiring)
# ---------------------------------------------------------------------------

def test_streamed_adamw_matches_reference():
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    from repro.train.steps import host_opt_state, make_streamed_opt_updater

    key = jax.random.PRNGKey(0)
    params = {
        "a": jax.random.normal(key, (16, 8)),
        "b": {"w": jax.random.normal(key, (8,)), "u": jax.random.normal(key, (4, 4))},
    }
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=2, total_steps=20)
    ref_step = jax.jit(lambda g, o: adamw_update(cfg, g, o, compute_dtype=jnp.float32))

    p_ref, opt_ref = params, adamw_init(params)
    p_st, opt_h = params, host_opt_state(params)
    upd = make_streamed_opt_updater(
        cfg, compute_dtype=jnp.float32, n_groups=2,
        prefetch=PrefetchSpec(buffer_size=4, distance=AUTO),
    )
    st = StreamStats()
    try:
        for i in range(5):
            g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1 * (i + 1), params)
            p_ref, opt_ref, m_ref = ref_step(g, opt_ref)
            p_st, opt_h, m_st = upd(g, opt_h, stats=st)
    finally:
        upd.close()
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_st)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-7)
    for a, b in zip(jax.tree.leaves(opt_ref["leaves"]), jax.tree.leaves(opt_h["leaves"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-7)
    # state home is the host: plain numpy leaves, coalesced single requests
    assert all(isinstance(x, np.ndarray) for x in jax.tree.leaves(opt_h["leaves"]))
    assert st.requests_per_group == 1.0
    np.testing.assert_allclose(float(m_ref["lr"]), float(m_st["lr"]), rtol=1e-6)


def test_host_opt_state_is_eval_shape_safe():
    """The driver's restore path builds its template with
    ``jax.eval_shape(init_state)`` — host_opt_state must trace cleanly
    (found by verification: np.asarray on tracers)."""
    from repro.train.steps import host_opt_state

    def build():
        return host_opt_state({"w": jnp.ones((3, 2)) * 2.0})

    tpl = jax.eval_shape(build)
    assert tpl["leaves"]["w"]["m"].shape == (3, 2)
    concrete = build()
    assert isinstance(concrete["leaves"]["w"]["m"], np.ndarray)


def test_offload_stream_host_matches_compiled_paths():
    from repro.core import memkind as mk
    from repro.core.offload import offload
    from repro.core.refspec import OffloadRef

    spec = PrefetchSpec(buffer_size=4, elements_per_fetch=4, distance=2)

    @offload(refs=dict(
        a=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec),
        b=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec),
    ))
    def k(a, b):
        return a * 2.0 + b

    rng = np.random.default_rng(6)
    a = rng.standard_normal((16, 8)).astype(np.float32)
    b = rng.standard_normal((16, 8)).astype(np.float32)
    st = StreamStats()
    out = k.stream_host(a, b, stats=st)
    np.testing.assert_allclose(out, np.asarray(k(a, b)), rtol=1e-6)
    np.testing.assert_allclose(out, np.asarray(k.eager(a, b)), rtol=1e-6)
    assert st.requests_per_group == 1.0  # blocks of (a, b) coalesce

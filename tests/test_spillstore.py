"""SpillStore unit tests: the DiskHost tier's chunk format and lifecycle."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spillstore import SpillStore, is_disk_leaf
from repro.data.loader import DiskShardLoader, PrefetchLoader


def _chunk(rng):
    return {
        "f32": rng.standard_normal((5, 3)).astype(np.float32),
        "bf16": np.asarray(jnp.asarray(rng.standard_normal((7,)), jnp.bfloat16)),
        "i32": rng.integers(-9, 9, (2, 2)).astype(np.int32),
        "empty": np.zeros((0, 4), np.float32),
        "nested": (rng.standard_normal((1,)).astype(np.float64),),
    }


def test_put_get_roundtrip_bitwise(tmp_path):
    rng = np.random.default_rng(0)
    chunk = _chunk(rng)
    store = SpillStore(tmp_path)
    store.put("c0", chunk)
    out = store.get("c0")
    assert jax.tree.structure(out) == jax.tree.structure(chunk)
    for got, src in zip(jax.tree.leaves(out), jax.tree.leaves(chunk)):
        # zero-length leaves have no bytes to map: they come back as plain
        # (empty) ndarrays, which every consumer treats as host-resident
        assert is_disk_leaf(got) or got.size == 0
        assert got.dtype == src.dtype  # incl. bf16 via the re-view trick
        np.testing.assert_array_equal(np.asarray(got), src)
    assert store.nbytes("c0") == sum(x.nbytes for x in jax.tree.leaves(chunk))


def test_atomic_overwrite_keeps_old_mapping_valid(tmp_path):
    store = SpillStore(tmp_path)
    store.put("k", {"x": np.arange(8, dtype=np.float32)})
    old = store.get("k")
    old_copy = np.array(old["x"])
    store.put("k", {"x": np.arange(8, dtype=np.float32) * 10})
    # the old mapping still reads the old bytes (open fd holds the inode)
    np.testing.assert_array_equal(np.asarray(old["x"]), old_copy)
    np.testing.assert_array_equal(np.array(store.get("k")["x"]), old_copy * 10)


def test_fresh_process_restart_needs_template(tmp_path):
    """The manifest survives on disk; a fresh store instance (new process)
    reconstructs chunks against a template — or flags the missing treedef."""
    rng = np.random.default_rng(1)
    chunk = _chunk(rng)
    SpillStore(tmp_path).put("c", chunk)
    fresh = SpillStore(tmp_path)
    assert "c" in fresh
    with pytest.raises(KeyError, match="template"):
        fresh.get("c")
    out = fresh.get("c", template=chunk)
    for got, src in zip(jax.tree.leaves(out), jax.tree.leaves(chunk)):
        np.testing.assert_array_equal(np.asarray(got), src)
    # single-leaf chunks need no template at all
    SpillStore(tmp_path).put("single", np.arange(4.0, dtype=np.float32))
    fresh2 = SpillStore(tmp_path)
    np.testing.assert_array_equal(
        np.asarray(fresh2.get("single")), np.arange(4.0, dtype=np.float32)
    )


def test_delete_and_manifest_consistency(tmp_path):
    store = SpillStore(tmp_path)
    store.put("a", np.ones(3, np.float32))
    store.put("b", np.zeros(5, np.float32))
    assert list(store.keys()) == ["a", "b"]
    store.delete("a")
    assert list(store.keys()) == ["b"]
    with pytest.raises(KeyError):
        store.get("a")
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert set(manifest) == {"b"}


def test_sanitized_keys_never_collide(tmp_path):
    """Regression: 'g/1' and 'g__1' both sanitize to 'g__1' — the digest
    suffix must keep their chunk files distinct."""
    store = SpillStore(tmp_path)
    a = np.full(4, 1.0, np.float32)
    b = np.full(4, 2.0, np.float32)
    store.put("g/1", a)
    store.put("g__1", b)
    np.testing.assert_array_equal(np.asarray(store.get("g/1")), a)
    np.testing.assert_array_equal(np.asarray(store.get("g__1")), b)


def test_all_empty_chunk_get_does_not_mmap_empty_file(tmp_path):
    """A chunk whose leaves total zero bytes writes an empty file; get()
    must not try to mmap it (mmap rejects empty files)."""
    store = SpillStore(tmp_path)
    chunk = {"a": np.zeros((0, 3), np.float32), "b": np.zeros((0,), np.int32)}
    store.put("empty", chunk)
    out = store.get("empty")
    for got, src in zip(jax.tree.leaves(out), jax.tree.leaves(chunk)):
        assert got.shape == src.shape and got.dtype == src.dtype


def test_ephemeral_store_skips_manifest_flush_and_deletes_on_close(tmp_path):
    d = tmp_path / "eph"
    store = SpillStore(d, ephemeral=True)
    store.put("k", np.ones(4, np.float32))
    assert not (d / "manifest.json").exists()  # no per-put flush
    store.close()
    assert not d.exists()  # run-private contents removed
    # durable stores keep files and manifest by default
    d2 = tmp_path / "dur"
    s2 = SpillStore(d2)
    s2.put("k", np.ones(4, np.float32))
    s2.close()
    assert d2.exists() and (d2 / "manifest.json").exists()


def test_offload_close_never_deletes_user_spill_dir(tmp_path):
    """Regression: after a close() of a private temp store, a later call
    with an explicit spill_dir must not inherit the delete-on-close."""
    from repro.core import memkind as mk
    from repro.core.offload import offload
    from repro.core.refspec import OffloadRef, PrefetchSpec

    spec = PrefetchSpec(buffer_size=4, elements_per_fetch=2, distance=1)

    @offload(refs=dict(x=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec)))
    def k(x):
        return x * 3.0

    x = np.arange(8, dtype=np.float32).reshape(8, 1)
    k.stream_host(x, policy=mk.DISK_PARAMS)  # private temp store
    tmp_store_dir = k._spill_store.dir
    k.close()
    assert not tmp_store_dir.exists()
    user_dir = tmp_path / "precious"
    k.stream_host(x, policy=mk.DISK_PARAMS, spill_dir=user_dir)
    k.close()
    assert user_dir.exists()  # user data survives close()


def test_disk_shard_loader_streams_without_host_copy(tmp_path):
    """Disk-resident data shards: memmap views all the way to device_put;
    round-robin over shards; composes with PrefetchLoader."""
    store = SpillStore(tmp_path)
    rng = np.random.default_rng(2)
    shards = [
        {"tokens": rng.integers(0, 100, (2, 8)).astype(np.int32)} for _ in range(3)
    ]
    loader = DiskShardLoader.write_shards(store, lambda i: shards[i], 3)
    got = loader(1)
    assert is_disk_leaf(got["tokens"])  # no host materialization
    np.testing.assert_array_equal(np.asarray(got["tokens"]), shards[1]["tokens"])
    np.testing.assert_array_equal(  # round-robin reuse
        np.asarray(loader(4)["tokens"]), shards[1]["tokens"]
    )
    pre = PrefetchLoader(loader, distance=2)
    for step in range(5):
        batch = pre(step)
        np.testing.assert_array_equal(
            np.asarray(batch["tokens"]), shards[step % 3]["tokens"]
        )

"""rglru_scan kernel: allclose sweeps vs oracle + block-level integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels.rglru_scan import linear_recurrence, linear_recurrence_ref
from repro.models import rglru


CASES = [
    # (B, S, W)
    (2, 128, 256),
    (1, 64, 128),
    (3, 100, 130),   # unaligned S and W (padding path)
    (2, 8, 512),
    (1, 256, 64),
]


@pytest.mark.parametrize("b,s,w", CASES)
def test_linear_recurrence_matches_oracle(b, s, w):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    # a in (0, 1) like the RG-LRU decay; b arbitrary
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, w)) + 2.0)
    bb = jax.random.normal(k2, (b, s, w)) * 0.5
    ref = linear_recurrence_ref(a, bb)
    out = linear_recurrence(a, bb, chunk_t=32, block_w=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk_t,block_w", [(8, 128), (64, 128), (128, 256)])
def test_linear_recurrence_block_invariance(chunk_t, block_w):
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(1), (2, 128, 256)))
    b = jax.random.normal(jax.random.PRNGKey(2), (2, 128, 256))
    base = linear_recurrence_ref(a, b)
    out = linear_recurrence(a, b, chunk_t=chunk_t, block_w=block_w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), rtol=1e-5, atol=1e-5)


def test_rglru_block_kernel_path_matches_xla_path():
    """rglru_block_train(use_kernel=True) == associative-scan path."""
    cfg = get_smoke_config("recurrentgemma-2b")
    p = rglru.init_rglru_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model), jnp.float32) * 0.5
    y0, s0 = rglru.rglru_block_train(cfg, p, x, use_kernel=False)
    y1, s1 = rglru.rglru_block_train(cfg, p, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(s0["h"]), np.asarray(s1["h"]), rtol=2e-4, atol=2e-4
    )


def test_linear_recurrence_decay_semantics():
    """a=0 forgets everything (h=b); a=1 integrates (h=cumsum b)."""
    b = jnp.ones((1, 16, 128))
    out0 = linear_recurrence(jnp.zeros_like(b), b)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(b))
    out1 = linear_recurrence(jnp.ones_like(b), b)
    np.testing.assert_allclose(
        np.asarray(out1[0, :, 0]), np.arange(1, 17, dtype=np.float32)
    )

"""Qwen2-VL-72B [arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE (sections
16/24/24), qkv bias, dynamic-resolution vision (STUB frontend per assignment:
``input_specs`` provides precomputed patch embeddings; 1/8 of the sequence is
vision prefix).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152_064,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    pos_type="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    vision_embed=True,
    fsdp=True,
    source="arXiv:2409.12191; hf",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        mrope_sections=(2, 3, 3), d_ff=128, vocab_size=256, fsdp=False, remat="none",
    )

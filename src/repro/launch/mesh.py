"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any JAX
import, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax

from repro.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256-chip pod (``data x model``) or 2x16x16 = 512-chip
    two-pod mesh (``pod x data x model``; ``pod`` is the DCN axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (CPU tests / single host)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))

"""Public jit'd wrapper for flash attention (GQA, causal, sliding window)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_p


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S, N, H)
    k: jax.Array,  # (B, T, KH, H)
    v: jax.Array,  # (B, T, KH, H)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """GQA flash attention; matches ``ref.attention_ref`` semantics.

    Queries/keys are padded up to block multiples; padded keys are masked out
    via the causal/validity structure (pad queries produce garbage rows that
    are sliced away; pad keys sit at positions > every real query position so
    the causal mask removes them — for non-causal use, an explicit validity
    bound is applied by padding `q_offset`-relative masking).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, s, n, h = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = n // kh

    bq = min(block_q, _ceil_to(s, 8))
    bkv = min(block_kv, _ceil_to(t, 128))
    sp, tp = _ceil_to(s, bq), _ceil_to(t, bkv)

    # Fold (B, KH) into one grid axis; q heads of each group ride with q.
    qg = q.reshape(b, s, kh, g, h).transpose(0, 2, 1, 3, 4).reshape(b * kh, s, g, h)
    kg = k.transpose(0, 2, 1, 3).reshape(b * kh, t, 1, h)
    vg = v.transpose(0, 2, 1, 3).reshape(b * kh, t, 1, h)

    # Padding: pad keys land at positions >= t; causal masking vs real query
    # positions (< t for self-attention) excludes them. Pad queries are
    # sliced off after the call.
    qg = jnp.pad(qg, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kg = jnp.pad(kg, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    vg = jnp.pad(vg, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    if not causal and tp != t:
        raise NotImplementedError(
            "non-causal flash attention requires block-aligned key length "
            f"(T={t}, block_kv={bkv})"
        )

    out = flash_attention_p(
        qg,
        kg,
        vg,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=bq,
        block_kv=bkv,
        interpret=interpret,
    )
    out = out[:, :s].reshape(b, kh, s, g, h).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, s, n, h)

"""End-to-end driver: train a ~100M-param model for a few hundred steps.

Exercises the full production stack on local devices: config -> mesh ->
sharding plan -> jitted fsdp train step -> prefetching loader -> fault-
tolerant driver with atomic checkpoints — including a mid-run restart to
prove recovery (loss curve continues bit-identically).

Run:  PYTHONPATH=src:. python examples/train_e2e.py [--steps 200]
(~100M params via a reduced-width smollm family config; on the CPU
container this takes a few minutes.)
"""
import argparse
import dataclasses
import logging

import jax

from repro.configs import get_smoke_config
from repro.launch.train import build_trainer
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.driver import DriverConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_e2e")
    ap.add_argument("--big", action="store_true",
                    help="~100M params (default: fast ~10M smoke width)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(levelname)s %(message)s")

    cfg = get_smoke_config("smollm-360m")
    if args.big:  # ~100M-param variant of the same family
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab_size=32000,
        )
    mesh = make_local_mesh()

    driver = build_trainer(
        cfg,
        mesh,
        global_batch=args.batch,
        seq_len=args.seq,
        opt_cfg=AdamWConfig(peak_lr=3e-3, warmup_steps=20, total_steps=args.steps),
        driver_cfg=DriverConfig(
            total_steps=args.steps,
            checkpoint_every=50,
            checkpoint_dir=args.ckpt,
            log_every=25,
        ),
        fail_at={args.steps // 2},  # prove fault tolerance mid-run
    )
    driver.run()
    losses = [h["loss"] for h in driver.history]
    print(
        f"\ntrained {len(driver.history)} logged steps "
        f"(restarts: {driver.restarts}); loss {losses[0]:.3f} -> {losses[-1]:.3f}"
    )
    assert driver.restarts >= 1, "failure injection should have triggered a restart"
    assert losses[-1] < losses[0], "loss should decrease"
    print("fault-tolerant e2e training: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

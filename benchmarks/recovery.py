"""Recovery study: what the self-healing runtime costs and guarantees.

Four gates, all on the deterministic modeled links (meaningful on noisy
CI runners):

  * **clean-path overhead**: enabling the transient-retry budget
    (``max_attempts=3``) on a fault-free streamed run adds <= 1% to the
    median compute-thread transfer wait vs the fail-fast engine — the
    retry machinery must be free when nothing fails,
  * **transient faults**: one injected H2D fault and one injected
    disk-staging fault each complete **bitwise-equal** to the unfaulted
    run with retry counters equal to the injected fault count; a
    permanent fault surfaces after exactly ``max_attempts`` tries,
  * **spill integrity**: a flipped byte in a spill chunk is detected by
    CRC on fetch and recovered from the durable home within the gate
    time — values bitwise the originals,
  * **restart latency**: a driver-level fault -> restore -> first resumed
    step completes within the recovery-time gate.

Emits ``results/bench/BENCH_recovery.json``.

``REPRO_BENCH_SMOKE=1`` (set by ``benchmarks/run.py --smoke``) shrinks the
workload for CI.
"""
from __future__ import annotations

import os
import statistics
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.engine import EngineConfig, LinkModel, TransferEngine
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.spillstore import SpillStore

SMOKE = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))

N_GROUPS = 12 if SMOKE else 24
REPEATS = 3 if SMOKE else 5
GROUP_SHAPE = (64, 64)

HOST_LINK = LinkModel(request_s=0.1e-3, bandwidth_Bps=500e6, latency_s=0.0)
DISK_LINK = LinkModel(request_s=0.3e-3, bandwidth_Bps=500e6, latency_s=4e-3)

#: clean-path gate: retry-enabled wait <= this ratio of fail-fast wait
CLEAN_OVERHEAD_RATIO = 1.01
CLEAN_OVERHEAD_ABS_S = 2e-3  # noise floor for near-zero waits
#: recovery-time gates (wall clock, generous for shared runners)
CRC_RECOVER_GATE_S = 5.0
RESTART_GATE_S = 5.0


def _host_groups(n=N_GROUPS):
    rng = np.random.default_rng(0)
    return [
        {"w": rng.standard_normal(GROUP_SHAPE).astype(np.float32)}
        for _ in range(n)
    ]


@jax.jit
def _apply(carry, g):
    return carry + jnp.sum(g["w"]), {"w": g["w"] * 1.0001}


def _run_stream(groups, cfg):
    st = StreamStats()
    with HostStreamExecutor(_apply, writeback=True, engine_config=cfg) as ex:
        _, outs = ex.run(jnp.zeros(()), groups, mode="prefetch", stats=st)
    return st, outs


# ---------------------------------------------------------------------------
# clean-path overhead of the retry machinery
# ---------------------------------------------------------------------------


def bench_clean_overhead():
    groups = _host_groups()
    waits = {1: [], 3: []}
    for _ in range(REPEATS):
        for attempts in (1, 3):
            cfg = EngineConfig(link=HOST_LINK, max_attempts=attempts)
            st, _ = _run_stream(groups, cfg)
            assert st.retries == 0 and st.give_ups == 0
            waits[attempts].append(st.transfer_wait_s)
    base = statistics.median(waits[1])
    retry = statistics.median(waits[3])
    ok = retry <= base * CLEAN_OVERHEAD_RATIO + CLEAN_OVERHEAD_ABS_S
    return {
        "case": "clean_overhead",
        "wait_fail_fast_s": base,
        "wait_retry_enabled_s": retry,
        "ratio": retry / base if base else 1.0,
        "gate_ratio": CLEAN_OVERHEAD_RATIO,
        "retries": 0,
        "pass": bool(ok),
    }


# ---------------------------------------------------------------------------
# injected transient / permanent faults
# ---------------------------------------------------------------------------


def bench_transient_h2d():
    groups = _host_groups()
    ref = [np.asarray(g["w"]) * 1.0001 for g in groups]
    real_put = jax.device_put
    faults = {"n": 0}

    def flaky(x, *a, **kw):
        if faults["n"] == 0:
            faults["n"] += 1
            raise RuntimeError("bench: transient H2D fault")
        return real_put(x, *a, **kw)

    jax.device_put = flaky
    try:
        t0 = time.perf_counter()
        st, outs = _run_stream(
            groups, EngineConfig(max_attempts=3, retry_backoff_s=1e-4)
        )
        dt = time.perf_counter() - t0
    finally:
        jax.device_put = real_put
    bitwise = all(
        np.array_equal(np.asarray(o["w"]), r) for o, r in zip(outs, ref)
    )
    ok = st.retries == faults["n"] == 1 and st.give_ups == 0 and bitwise
    return {
        "case": "transient_h2d",
        "injected": faults["n"],
        "retries": st.retries,
        "give_ups": st.give_ups,
        "bitwise_equal": bool(bitwise),
        "run_s": dt,
        "pass": bool(ok),
    }


def bench_transient_disk():
    with tempfile.TemporaryDirectory() as d:
        store = SpillStore(d)
        host = _host_groups()
        disk = []
        for i, g in enumerate(host):
            store.put(f"g{i:04d}", g)
            disk.append(store.get(f"g{i:04d}"))
        ref = [g["w"] * 1.0001 for g in host]

        real = TransferEngine._acquire_disk_staging
        faults = {"n": 0}

        def flaky(self, dsig, layout):
            if faults["n"] == 0:
                faults["n"] += 1
                raise RuntimeError("bench: transient disk-stage fault")
            return real(self, dsig, layout)

        TransferEngine._acquire_disk_staging = flaky
        try:
            st, outs = _run_stream(
                disk,
                EngineConfig(
                    disk_link=DISK_LINK, max_attempts=3, retry_backoff_s=1e-4
                ),
            )
        finally:
            TransferEngine._acquire_disk_staging = real
        store.close()
    bitwise = all(
        np.array_equal(np.asarray(o["w"]), r) for o, r in zip(outs, ref)
    )
    ok = st.retries == faults["n"] == 1 and st.give_ups == 0 and bitwise
    return {
        "case": "transient_disk",
        "injected": faults["n"],
        "retries": st.retries,
        "give_ups": st.give_ups,
        "bitwise_equal": bool(bitwise),
        "pass": bool(ok),
    }


def bench_permanent_fault():
    real_put = jax.device_put
    calls = {"n": 0}

    def dead(x, *a, **kw):
        calls["n"] += 1
        raise RuntimeError("bench: permanent H2D fault")

    surfaced = False
    st = StreamStats()
    jax.device_put = dead
    try:
        with HostStreamExecutor(
            lambda c, g: c, engine_config=EngineConfig(
                max_attempts=3, retry_backoff_s=1e-4
            )
        ) as ex:
            try:
                ex.run(jnp.zeros(()), _host_groups(2), mode="on_demand", stats=st)
            except RuntimeError:
                surfaced = True
    finally:
        jax.device_put = real_put
    ok = surfaced and calls["n"] == 3 and st.give_ups == 1
    return {
        "case": "permanent_fault",
        "max_attempts": 3,
        "tries": calls["n"],
        "surfaced": bool(surfaced),
        "give_ups": st.give_ups,
        "pass": bool(ok),
    }


# ---------------------------------------------------------------------------
# spill integrity: CRC detect + recover
# ---------------------------------------------------------------------------


def bench_crc_detect_recover():
    with tempfile.TemporaryDirectory() as d:
        store = SpillStore(d)
        host = _host_groups(4)
        disk = []
        for i, g in enumerate(host):
            store.put(f"g{i:04d}", g)
            disk.append(store.get(f"g{i:04d}"))
        store.set_recovery(lambda key: host[int(key[1:])])
        entry = store._entry("g0001")
        path = store.dir / entry["file"]
        raw = bytearray(path.read_bytes())
        raw[16] ^= 0xFF
        path.write_bytes(bytes(raw))

        t0 = time.perf_counter()
        st, outs = _run_stream(disk, EngineConfig())
        dt = time.perf_counter() - t0
        bitwise = all(
            np.array_equal(np.asarray(o["w"]), g["w"] * 1.0001)
            for o, g in zip(outs, host)
        )
        ok = (
            store.crc_failures >= 1
            and store.recoveries == 1
            and bitwise
            and dt < CRC_RECOVER_GATE_S
        )
        row = {
            "case": "crc_detect_recover",
            "crc_failures": store.crc_failures,
            "recoveries": store.recoveries,
            "bitwise_equal": bool(bitwise),
            "run_s": dt,
            "gate_s": CRC_RECOVER_GATE_S,
            "pass": bool(ok),
        }
        store.close()
    return row


# ---------------------------------------------------------------------------
# driver restart latency
# ---------------------------------------------------------------------------


def bench_driver_restart():
    from repro.runtime.driver import DriverConfig, TrainDriver

    marks = {}

    def step_fn(state, batch):
        if batch == 6 and "fault" not in marks:
            marks["fault"] = time.perf_counter()
            raise RuntimeError("bench: injected driver fault")
        if batch == 6 and "resumed" not in marks:
            marks["resumed"] = time.perf_counter()
        x = state["x"] + 1.0
        return {"x": x}, {"loss": float(np.sum(x))}

    with tempfile.TemporaryDirectory() as d:
        cfg = DriverConfig(
            total_steps=10, checkpoint_every=2, checkpoint_dir=d, log_every=0
        )
        drv = TrainDriver(
            cfg, step_fn, lambda i: i, lambda: {"x": np.zeros(64, np.float32)}
        )
        drv.run()
    recover_s = marks["resumed"] - marks["fault"]
    ok = drv.restarts == 1 and recover_s < RESTART_GATE_S
    return {
        "case": "driver_restart",
        "restarts": drv.restarts,
        "fault_to_resume_s": recover_s,
        "gate_s": RESTART_GATE_S,
        "pass": bool(ok),
    }


def main() -> int:
    rows = [
        bench_clean_overhead(),
        bench_transient_h2d(),
        bench_transient_disk(),
        bench_permanent_fault(),
        bench_crc_detect_recover(),
        bench_driver_restart(),
    ]
    C.print_table(
        "recovery: retry / integrity / restart gates",
        rows,
        ["case", "retries", "give_ups", "bitwise_equal", "run_s", "pass"],
    )
    out = C.save_rows("BENCH_recovery", rows)
    print(f"saved {out}")
    failed = [r["case"] for r in rows if not r["pass"]]
    if failed:
        print(f"FAILED gates: {failed}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Streamed model parameters: host/disk-homed weights under a device budget.

The paper's §3.1 claim applied to the weights (the largest pytree in the
system): home the params on ``pinned_host`` or ``disk_host``, stream them
layer-group-wise through the transfer engine for the forward pass, the
reverse-order backward pass, and the optimizer update (whose D2H params
writeback rides the same drain as the AdamW moments), and bound the peak
streamed device residency with an explicit ``--device-budget-mb``.

Gates (the ISSUE 5 acceptance), on a modeled Epiphany-class link:

  * **bitwise**: the streamed train step (loss series + updated params)
    and the streamed paged decode (generated tokens) equal the
    device-resident run for every ``param_kind`` × distance 0/1/auto;
  * **budget**: peak streamed param bytes stay under the device budget
    while the total param bytes exceed it (streaming is actually forced);
  * **requests**: exactly 1 H2D request per FETCHED (device, layer group)
    — residency-cache pass-throughs cost zero requests;
  * **overlap**: steady-state compute wait at ``distance="auto"`` is
    >= 2x lower than ``distance=0`` (the paper's on-demand penalty).

Residency gates (the ISSUE 7 acceptance):

  * **zero slack**: at the tight budget the weight-residency cache has no
    capacity and degenerates to the plain streaming schedule — every
    consumed group is a unique fetch (``unique_group_fetches ==
    n_groups``), exactly the pre-cache traffic;
  * **steady state**: with budget slack the cache keeps groups resident —
    steady-state H2D traffic collapses (>= 2x fewer requests than the
    zero-slack run; in practice ~0 once the model is resident) while the
    run stays bitwise-equal to the device-resident reference;
  * **cached budget**: peak streamed bytes + peak cache-resident bytes
    stay under the slack budget (window and cache share one budget);
  * **decode residency**: a serving session with slack stops re-fetching
    the model each decode step (per-step unique fetches -> 0), while
    ``param_cache_mb=0`` pays the full ``n_groups`` every step.

Expert gates (the ISSUE 8 acceptance):

  * **routed traffic**: router-first decode on a top-2-of-8 MoE fetches
    >= 2x fewer expert weight bytes per step than the all-expert
    baseline, with identical routed traffic for every home kind ×
    distance;
  * **routed bitwise**: routed and all-expert streamed decode tokens
    equal the device-resident run;
  * **expert requests**: exactly 1 H2D request per FETCHED
    (device, expert group).

Sanitizer gate (the ISSUE 9 acceptance):

  * **overhead**: the runtime hazard sanitizer (``REPRO_SANITIZE=1`` —
    happens-before edges per keyed transfer, home fingerprints per cache
    decision) costs <= 5% median per-step wall time on the streamed train
    path, bitwise-identically.

Emits ``results/bench/BENCH_weights.json``.  ``REPRO_BENCH_SMOKE=1``
(set by ``benchmarks/run.py --smoke``) shrinks the workload for CI.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks import common as C

SMOKE = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))

N_LAYERS = 12 if SMOKE else 16
LAYERS_PER_GROUP = 2
STEPS = 4 if SMOKE else 6
BATCH, SEQ = 2, 64
#: request+latency-dominated link (the paper's regime): the latency tail is
#: the overlappable term the prefetch window hides.  Bandwidth is kept high
#: so the serial occupancy of the backward pass's grad writebacks does not
#: saturate the link — a saturated link turns pipelining into pure queueing
#: and the stall comparison measures backlog, not overlap.
LINK_KW = dict(request_s=0.15e-3, bandwidth_Bps=5e9, latency_s=2.5e-3)

KINDS = ("pinned_host", "disk_host")
DISTANCES = (0, 1, "auto")


def _build(cfg):
    from repro.core.weightstream import WeightStreamPlan
    from repro.train import steps as st

    plan = WeightStreamPlan(
        cfg, st.abstract_params(cfg), layers_per_group=LAYERS_PER_GROUP
    )
    # a budget that forces streaming: holds a distance-2 window (so the
    # adaptive controller has room to grow) but NOT the whole model
    budget_bytes = plan.peak_device_bytes(2)
    budget_mb = budget_bytes / 1e6
    assert plan.total_param_bytes > budget_bytes, (
        plan.total_param_bytes, budget_bytes,
    )
    plan = WeightStreamPlan(
        cfg,
        st.abstract_params(cfg),
        layers_per_group=LAYERS_PER_GROUP,
        device_budget_mb=budget_mb,
    )
    # a slack budget for the residency runs: holds the widest window PLUS
    # every home group, so the cache reaches steady-state full residency
    slack_bytes = sum(plan.fetch_sequence_bytes()) + plan.total_param_bytes
    slack_plan = WeightStreamPlan(
        cfg,
        st.abstract_params(cfg),
        layers_per_group=LAYERS_PER_GROUP,
        device_budget_mb=slack_bytes / 1e6,
    )
    assert (slack_plan.residency_capacity_bytes() or 0) >= plan.total_param_bytes
    return plan, budget_bytes, slack_plan, slack_bytes


def _train_run(cfg, plan, budget_bytes, kind, distance):
    """K streamed train steps at (kind, distance); returns (losses, final
    params home as numpy, stats row)."""
    from repro.core.engine import EngineConfig, LinkModel, TransferEngine
    from repro.core.refspec import PrefetchSpec
    from repro.core.spillstore import SpillStore
    from repro.data.synthetic import SyntheticConfig, synthetic_batch
    from repro.optim.adamw import AdamWConfig
    from repro.train import steps as st

    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=64)
    engine = TransferEngine(
        EngineConfig(
            link=LinkModel(**LINK_KW),
            max_distance=plan.max_distance_for_budget(),
        )
    )
    tmp = None
    store = None
    if kind == "disk_host":
        tmp = tempfile.mkdtemp(prefix="repro-bench-wp-")
        store = SpillStore(tmp, ephemeral=True)
    prefetch = PrefetchSpec(
        buffer_size=plan.n_groups + 2,
        distance=distance if distance == "auto" else int(distance),
    )
    step = st.make_weight_streamed_train_step(
        cfg,
        opt_cfg,
        plan=plan,
        prefetch=prefetch,
        engine=engine,
        spill_store=store,
        param_kind=kind,
    )
    state = st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    if kind == "disk_host":
        state = st.spill_weight_streamed_state(plan, state, store)
    elif kind == "device":
        state = {
            "params": plan.device_home(state["params"]),
            "opt": {
                "groups": jax.device_put(state["opt"]["groups"]),
                "step": state["opt"]["step"],
            },
        }
    sc = SyntheticConfig(cfg.vocab_size, SEQ, BATCH, seed=0)

    # one compile step, then reset so the counters cover the timed steps
    state, m0 = step(state, synthetic_batch(cfg, sc, 0))
    losses = [float(m0["loss"])]
    step.param_stats.reset()
    step.opt_stats.reset()
    step_wall_s = []
    for k in range(1, STEPS):
        t0 = time.perf_counter()
        state, m = step(state, synthetic_batch(cfg, sc, k))
        step_wall_s.append(time.perf_counter() - t0)
        losses.append(float(m["loss"]))
    stats = step.param_stats
    waits = list(stats.wait_per_group)
    steady = waits[len(waits) // 2 :] or [0.0]
    final = {
        key: jax.tree.map(np.asarray, tree)
        for key, tree in state["params"]["groups"].items()
    }
    residency = step.residency
    row = {
        "phase": "train",
        "param_kind": kind,
        "distance": str(distance),
        "losses": losses,
        "h2d_requests": stats.h2d_requests,
        "n_groups": stats.n_groups,
        "requests_per_fetched_device_group": stats.per_tier()["h2d"][
            "requests_per_fetched_device_group"
        ],
        "unique_group_fetches": stats.unique_group_fetches,
        "cache_hits": stats.cache_hits,
        "cache_capacity_bytes": (
            residency.capacity_bytes if residency is not None else None
        ),
        "cache_peak_resident_bytes": (
            residency.peak_resident_bytes if residency is not None else 0
        ),
        "disk_requests": stats.disk_requests,
        "peak_inflight_bytes": stats.peak_inflight_bytes,
        "budget_bytes": budget_bytes,
        "total_param_bytes": plan.total_param_bytes,
        "steady_wait_per_group_s": float(np.median(steady)),
        "step_wall_s": step_wall_s,
        "transfer_wait_s": stats.transfer_wait_s,
        "final_distance": stats.distance_trace[-1] if stats.distance_trace else None,
    }
    step.close()
    if store is not None:
        store.close()
    return losses, final, row


def _decode_run(cfg, kind, distance, budget_mb, param_cache_mb=None):
    from repro.launch import serve as sv
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    res = sv.serve(
        cfg,
        mesh,
        batch=2,
        prompt_len=12,
        gen=6,
        kv_kind="pinned_host",
        kv_page_len=4,
        seed=7,
        param_kind=kind,
        device_budget_mb=None if kind == "device" else budget_mb,
        param_layers_per_group=LAYERS_PER_GROUP,
        param_distance=distance,
        param_cache_mb=param_cache_mb,
    )
    ps = res["param_stats"]
    row = {
        "phase": "decode",
        "param_kind": kind,
        "distance": str(distance),
        "generated": res["generated"].tolist(),
        "h2d_requests": ps.h2d_requests,
        "requests_per_fetched_device_group": (
            ps.per_tier()["h2d"]["requests_per_fetched_device_group"]
        ),
        "unique_group_fetches": ps.unique_group_fetches,
        "cache_hits": ps.cache_hits,
        "step_fetches": res.get("param_step_fetches", []),
        "peak_inflight_bytes": ps.peak_inflight_bytes,
    }
    return res["generated"], row


def _expert_decode_run(cfg, kind, distance, route=True):
    """One unpaged streamed-serve run with expert-split groups; returns
    (tokens, row) with the expert-group decode-loop traffic."""
    from repro.launch import serve as sv
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    res = sv.serve(
        cfg,
        mesh,
        batch=2,
        prompt_len=8,
        gen=6,
        kv_page_len=0,
        seed=7,
        warmup=False,
        param_kind=kind,
        param_distance=distance,
        param_cache_mb=0.0,
        expert_stream=True,
        route_experts=route,
    )
    es = res["expert_stats"]
    row = {
        "phase": "decode_experts",
        "param_kind": kind,
        "distance": str(distance),
        "route_experts": route,
        "generated": res["generated"].tolist(),
        "expert_decode_bytes": res["expert_decode_bytes"],
        "expert_decode_fetches": res["expert_decode_fetches"],
        "expert_bytes_per_step": res["expert_decode_bytes"] / max(res["n_steps"], 1),
        "requests_per_fetched_device_group": (
            es.per_tier()["h2d"]["requests_per_fetched_device_group"]
        ),
    }
    return res["generated"], row


def main() -> int:
    from repro.configs import get_smoke_config

    cfg = dataclasses.replace(get_smoke_config("smollm-360m"), n_layers=N_LAYERS)
    plan, budget_bytes, slack_plan, slack_bytes = _build(cfg)
    budget_mb = budget_bytes / 1e6
    print(
        f"plan: {plan.n_groups} groups x {plan.layers_per_group} layers, "
        f"total {plan.total_param_bytes} B, budget {budget_bytes} B "
        f"(slack run: {slack_bytes} B), "
        f"max distance {plan.max_distance_for_budget()}"
    )

    rows: list[dict] = []

    # ---- train: bitwise vs the device-resident run -------------------------
    ref_losses, ref_params, ref_row = _train_run(
        cfg, plan, budget_bytes, "device", 1
    )
    ref_row["reference"] = True
    rows.append(ref_row)
    bitwise_ok = True
    budget_ok = True
    requests_ok = True
    zero_slack_ok = True
    for kind in KINDS:
        for dist in DISTANCES:
            losses, params, row = _train_run(cfg, plan, budget_bytes, kind, dist)
            row["bitwise_equal_to_device"] = losses == ref_losses and all(
                np.array_equal(a, b)
                for key in ref_params
                for a, b in zip(
                    jax.tree.leaves(params[key]), jax.tree.leaves(ref_params[key])
                )
            )
            bitwise_ok &= row["bitwise_equal_to_device"]
            row["under_budget"] = (
                row["peak_inflight_bytes"] <= budget_bytes
                and plan.total_param_bytes > budget_bytes
            )
            budget_ok &= row["under_budget"]
            requests_ok &= row["requests_per_fetched_device_group"] == 1.0
            # zero budget slack -> the residency cache has no capacity and
            # the schedule degenerates to plain streaming: every consumed
            # group crosses the link (the pre-cache request count, exactly)
            zero_slack_ok &= (
                row["cache_capacity_bytes"] == 0
                and row["unique_group_fetches"] == row["n_groups"]
            )
            rows.append(row)

    # ---- train under budget slack: steady-state weight residency -----------
    residency_ok = True
    cached_budget_ok = True
    for kind in KINDS:
        losses, params, row = _train_run(
            cfg, slack_plan, slack_bytes, kind, "auto"
        )
        row["phase"] = "train_slack"
        row["bitwise_equal_to_device"] = losses == ref_losses and all(
            np.array_equal(a, b)
            for key in ref_params
            for a, b in zip(
                jax.tree.leaves(params[key]), jax.tree.leaves(ref_params[key])
            )
        )
        bitwise_ok &= row["bitwise_equal_to_device"]
        tight = next(
            r for r in rows
            if r["phase"] == "train"
            and r["param_kind"] == kind and r["distance"] == "auto"
        )
        # steady state (counters reset after the compile step): the model is
        # resident, so the re-fetch traffic collapses vs the zero-slack run
        row["traffic_reduction_vs_zero_slack"] = tight["h2d_requests"] / max(
            row["h2d_requests"], 1
        )
        residency_ok &= (
            2 * row["h2d_requests"] <= tight["h2d_requests"]
            and row["cache_hits"] > 0
        )
        # window + cache share the one budget
        row["under_budget"] = (
            row["peak_inflight_bytes"] + row["cache_peak_resident_bytes"]
            <= slack_bytes
        )
        cached_budget_ok &= row["under_budget"]
        rows.append(row)

    # ---- overlap: distance="auto" vs the on-demand schedule ----------------
    by = {(r["param_kind"], r["distance"]): r for r in rows if r["phase"] == "train"}
    w0 = by[("pinned_host", "0")]["steady_wait_per_group_s"]
    wa = by[("pinned_host", "auto")]["steady_wait_per_group_s"]
    collapse = w0 / max(wa, 1e-9)
    overlap_ok = collapse >= 2.0

    # ---- sanitizer overhead: REPRO_SANITIZE=1 on the clean streamed path ---
    # the happens-before tracking is a dict op per keyed transfer — gate its
    # median per-step cost at <= 5% over the plain run (plus a 5 ms jitter
    # floor so a shared runner's scheduling noise cannot flake the gate)
    os.environ["REPRO_SANITIZE"] = "1"
    try:
        san_losses, _, san_row = _train_run(
            cfg, plan, budget_bytes, "pinned_host", "auto"
        )
    finally:
        os.environ.pop("REPRO_SANITIZE", None)
    san_row["phase"] = "train_sanitized"
    san_row["bitwise_equal_to_device"] = san_losses == ref_losses
    bitwise_ok &= san_row["bitwise_equal_to_device"]
    plain_step_s = float(
        np.median(by[("pinned_host", "auto")]["step_wall_s"])
    )
    san_step_s = float(np.median(san_row["step_wall_s"]))
    san_row["overhead_vs_plain"] = san_step_s / max(plain_step_s, 1e-9)
    sanitize_overhead_ok = san_step_s <= plain_step_s * 1.05 + 0.005
    rows.append(san_row)

    # ---- paged decode: tokens bitwise vs the device-resident serve ---------
    ref_tokens, dref_row = _decode_run(cfg, "device", "auto", budget_mb)
    dref_row["reference"] = True
    rows.append(dref_row)
    for kind in KINDS:
        for dist in DISTANCES:
            toks, row = _decode_run(cfg, kind, dist, budget_mb)
            row["bitwise_equal_to_device"] = bool(np.array_equal(toks, ref_tokens))
            bitwise_ok &= row["bitwise_equal_to_device"]
            requests_ok &= row["requests_per_fetched_device_group"] == 1.0
            rows.append(row)

    # ---- decode residency: resident weights across decode steps ------------
    # unbounded cache (no budget): after the first fetch the model stays
    # device-resident — later decode steps issue ZERO weight fetches.
    # param_cache_mb=0 is the pre-cache schedule: n_groups fetches per step.
    decode_residency_ok = True
    n_groups = plan.n_groups
    for cache_mb, expect_tail in ((None, 0), (0.0, n_groups)):
        toks, row = _decode_run(
            cfg, "pinned_host", "auto", None, param_cache_mb=cache_mb
        )
        row["phase"] = "decode_residency"
        row["param_cache_mb"] = cache_mb
        row["bitwise_equal_to_device"] = bool(np.array_equal(toks, ref_tokens))
        bitwise_ok &= row["bitwise_equal_to_device"]
        tail = row["step_fetches"][len(row["step_fetches"]) // 2 :]
        row["steady_step_fetches"] = tail
        decode_residency_ok &= bool(tail) and all(
            f == expect_tail for f in tail
        )
        rows.append(row)

    # ---- expert streaming: routed decode fetches only the top-k experts ----
    # top-2-of-8 MoE: the router-first schedule fetches the union of routed
    # experts per (layer, step) instead of all 8 — gate >= 2x fewer expert
    # weight bytes per decode step than the all-expert baseline, bitwise
    # tokens for every kind x distance, 1 request per fetched expert group.
    from repro.launch import serve as sv
    from repro.launch.mesh import make_local_mesh

    ecfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"), n_experts=8)
    e_mesh = make_local_mesh()
    e_ref = sv.serve(
        ecfg, e_mesh, batch=2, prompt_len=8, gen=6, kv_page_len=0, seed=7,
        warmup=False,
    )["generated"]
    expert_bitwise_ok = True
    expert_requests_ok = True
    routed_bytes = {}
    for kind in KINDS:
        for dist in DISTANCES:
            toks, row = _expert_decode_run(ecfg, kind, dist, route=True)
            row["bitwise_equal_to_device"] = bool(np.array_equal(toks, e_ref))
            expert_bitwise_ok &= row["bitwise_equal_to_device"]
            expert_requests_ok &= (
                row["requests_per_fetched_device_group"] == 1.0
            )
            routed_bytes[(kind, str(dist))] = row["expert_decode_bytes"]
            rows.append(row)
    a_toks, a_row = _expert_decode_run(ecfg, "pinned_host", "auto", route=False)
    a_row["bitwise_equal_to_device"] = bool(np.array_equal(a_toks, e_ref))
    expert_bitwise_ok &= a_row["bitwise_equal_to_device"]
    rows.append(a_row)
    routed = routed_bytes[("pinned_host", "auto")]
    expert_traffic_ok = 2 * routed <= a_row["expert_decode_bytes"] and all(
        b == routed for b in routed_bytes.values()
    )
    expert_ratio = a_row["expert_decode_bytes"] / max(routed, 1)

    C.print_table(
        "streamed weights (modeled link): train + paged decode",
        [r for r in rows if r["phase"] in ("train", "train_slack")],
        ["phase", "param_kind", "distance",
         "requests_per_fetched_device_group", "unique_group_fetches",
         "cache_hits", "peak_inflight_bytes", "steady_wait_per_group_s",
         "final_distance", "bitwise_equal_to_device"],
    )
    C.save_rows("BENCH_weights", rows)
    print(
        f"bitwise (train params + decode tokens, every kind x distance): "
        f"{bitwise_ok}; peak streamed {by[('pinned_host', 'auto')]['peak_inflight_bytes']} B "
        f"<= budget {budget_bytes} B < total {plan.total_param_bytes} B: {budget_ok}; "
        f"1 req/fetched (device,group): {requests_ok}; "
        f"steady wait on-demand/auto = {collapse:.1f}x (gate >= 2x)"
    )
    print(
        f"residency: zero-slack degenerates to plain streaming: "
        f"{zero_slack_ok}; slack steady-state traffic collapse >= 2x: "
        f"{residency_ok}; streamed+cached <= budget: {cached_budget_ok}; "
        f"decode steady-state fetches (slack -> 0, no cache -> "
        f"{n_groups}/step): {decode_residency_ok}"
    )
    print(
        f"experts (top-2-of-{ecfg.n_experts}): routed decode "
        f"{routed} B vs all-expert {a_row['expert_decode_bytes']} B = "
        f"{expert_ratio:.2f}x reduction (gate >= 2x): {expert_traffic_ok}; "
        f"tokens bitwise every kind x distance: {expert_bitwise_ok}; "
        f"1 req/fetched expert group: {expert_requests_ok}"
    )
    print(
        f"sanitizer (REPRO_SANITIZE=1): median step "
        f"{san_step_s * 1e3:.1f} ms vs plain {plain_step_s * 1e3:.1f} ms = "
        f"{san_row['overhead_vs_plain']:.3f}x (gate <= 1.05x): "
        f"{sanitize_overhead_ok}"
    )
    return 0 if (
        bitwise_ok and budget_ok and requests_ok and overlap_ok
        and zero_slack_ok and residency_ok and cached_budget_ok
        and decode_residency_ok and expert_traffic_ok
        and expert_bitwise_ok and expert_requests_ok
        and sanitize_overhead_ok
    ) else 1


if __name__ == "__main__":
    raise SystemExit(main())

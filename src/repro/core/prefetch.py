"""Graph streaming engine: pass-by-reference + prefetch inside the XLA program.

The paper's runtime fetches referenced data on demand as the interpreter walks
the kernel, with an optional prefetch ring so transfers overlap compute
(§3.1).  The compiled-XLA analogue: model state lives at a host memory kind;
a ``lax.scan`` over layers carries a ring of ``distance`` chunk buffers in
device memory, and each iteration issues the H2D copy for chunk ``i+distance``
while computing with chunk ``i``.  On TPU the copies lower to async DMA
(copy-start / copy-done) that overlaps the layer's matmuls — exactly the
paper's "data transfer will have completed by the time the code needs it".

``distance=0`` degenerates to the paper's *on-demand* mode: the fetch is in
the critical path of every layer.

Chunk = ``elements_per_fetch`` consecutive layers (the paper's chunked
transfers: "pre-fetching retrieves data in chunks rather than single
individual elements ... significantly fewer requests").
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import memkind as mk
from repro.core.engine import static_auto_distance
from repro.core.refspec import PrefetchSpec

__all__ = [
    "fetch_chunk",
    "eager_transfer",
    "streamed_scan",
    "stream_blocks",
]

Pytree = Any


def _index_chunk(stacked: Pytree, idx: jax.Array) -> Pytree:
    """Slice chunk ``idx`` out of a pytree whose leaves are stacked on axis 0."""
    return jax.tree.map(
        lambda a: lax.dynamic_index_in_dim(a, idx, axis=0, keepdims=False), stacked
    )


def fetch_chunk(
    stacked: Pytree, idx: jax.Array, dev_shardings: Optional[Pytree] = None
) -> Pytree:
    """On-demand fetch of one chunk: host-side slice + explicit H2D copy.

    This is the runtime primitive of paper §4 ("blocking calls, to copy data
    on or off the device").  When the home kind resolves to device (fallback
    backends) the copy is a no-op and only the slice remains.
    """
    chunk = _index_chunk(stacked, idx)
    if dev_shardings is None:
        return chunk
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        chunk,
        dev_shardings,
        is_leaf=lambda x: x is None,
    )


def eager_transfer(stacked: Pytree, dev_shardings: Optional[Pytree] = None) -> Pytree:
    """The paper's *eager* baseline: bulk-copy the entire argument to the fast
    tier before any compute starts."""
    if dev_shardings is None:
        return stacked
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        stacked,
        dev_shardings,
        is_leaf=lambda x: x is None,
    )


def _group(stacked: Pytree, g: int) -> Pytree:
    if g == 1:
        return stacked
    return jax.tree.map(lambda a: a.reshape((a.shape[0] // g, g) + a.shape[1:]), stacked)


def streamed_scan(
    body_fn: Callable[[Pytree, Pytree], tuple[Pytree, Pytree]],
    init_carry: Pytree,
    stacked_params: Pytree,
    *,
    prefetch: PrefetchSpec,
    dev_shardings: Optional[Pytree] = None,
    length: Optional[int] = None,
    unroll: int = 1,
) -> tuple[Pytree, Pytree]:
    """``lax.scan`` over stacked (leading-axis ``L``) parameters with streaming.

    ``body_fn(carry, layer_params) -> (carry, y)`` is applied to each of the
    ``L`` layers in order.  Parameters are fetched chunk-wise
    (``prefetch.elements_per_fetch`` layers per transfer) through a ring of
    ``prefetch.distance`` device-side chunk buffers.  Semantics are identical
    for every (distance, elements_per_fetch) setting — only the transfer
    schedule changes (paper: "the prefetch argument does not impact the
    correctness of the code").

    Returns ``(final_carry, ys)`` with ``ys`` stacked on axis 0, exactly like
    ``lax.scan``.
    """
    leaves = jax.tree.leaves(stacked_params)
    if not leaves:
        raise ValueError("streamed_scan requires at least one parameter leaf")
    L = length if length is not None else leaves[0].shape[0]
    g = prefetch.elements_per_fetch
    if L % g != 0:
        raise ValueError(f"n_layers={L} not divisible by elements_per_fetch={g}")
    n_chunks = L // g
    grouped = _group(stacked_params, g)
    # chunk-level device shardings: same per-layer sharding (group axis unsharded)
    if dev_shardings is not None and g > 1:
        chunk_shardings = dev_shardings  # PartitionSpec leading dims align: chunk adds
        # axis 0; NamedSharding of the per-layer slice is reused — device_put with a
        # rank-mismatched sharding is invalid, so extend specs with a leading None.
        chunk_shardings = jax.tree.map(
            lambda s: None
            if s is None
            else mk.sharding_for(
                s.mesh, jax.sharding.PartitionSpec(None, *s.spec), mk.as_kind(s.memory_kind)
            ),
            dev_shardings,
            is_leaf=lambda x: x is None or isinstance(x, jax.sharding.NamedSharding),
        )
    else:
        chunk_shardings = dev_shardings

    fetch = functools.partial(fetch_chunk, grouped, dev_shardings=chunk_shardings)

    def apply_chunk(carry: Pytree, chunk: Pytree) -> tuple[Pytree, list[Pytree]]:
        ys = []
        if g == 1:
            carry, y = body_fn(carry, chunk)
            return carry, y
        for j in range(g):
            layer = jax.tree.map(lambda a: a[j], chunk)
            carry, y = body_fn(carry, layer)
            ys.append(y)
        y = jax.tree.map(lambda *xs: jnp.stack(xs), *ys) if ys[0] is not None else None
        return carry, y

    # "auto" cannot adapt inside a compiled scan (the ring shape is static);
    # resolve it to a fixed head start once, at trace time
    d = min(prefetch.numeric_distance(static_auto_distance(n_chunks)),
            max(n_chunks - 1, 0))

    if d == 0:
        # --- on-demand: fetch in the critical path of every chunk -----------
        def body(carry, i):
            chunk = fetch(i)
            return apply_chunk(carry, chunk)

        final, ys = lax.scan(body, init_carry, jnp.arange(n_chunks), unroll=unroll)
    else:
        # --- prefetch ring: ring[0] is chunk i; issue fetch of chunk i+d ----
        ring = tuple(fetch(jnp.asarray(j, jnp.int32)) for j in range(d))

        def body(carry_ring, i):
            carry, ring = carry_ring
            nxt = fetch(jnp.minimum(i + d, n_chunks - 1))
            carry, y = apply_chunk(carry, ring[0])
            return (carry, (*ring[1:], nxt)), y

        (final, _), ys = lax.scan(
            body, (init_carry, ring), jnp.arange(n_chunks), unroll=unroll
        )

    if g > 1 and ys is not None:
        ys = jax.tree.map(
            lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), ys
        )
    return final, ys


def stream_blocks(
    fn: Callable[..., Pytree],
    args: Sequence[Pytree],
    *,
    prefetch: PrefetchSpec,
    dev_shardings: Optional[Sequence[Pytree]] = None,
    unroll: int = 1,
) -> Pytree:
    """Generic chunked map over the leading axis of host-resident arrays —
    the paper's Listing-2 pattern (elementwise kernels over data sets larger
    than device memory).

    ``fn(*chunks) -> out_chunk`` is applied to aligned blocks of
    ``prefetch.elements_per_fetch`` rows (vectorized — fn sees the whole
    block); outputs are restacked.  The prefetch ring overlaps the H2D copy
    of block ``i+distance`` with the compute of block ``i``.
    """
    import dataclasses as _dc

    g = prefetch.elements_per_fetch
    n = jax.tree.leaves(args[0])[0].shape[0]
    if n % g != 0:
        raise ValueError(f"leading axis {n} not divisible by elements_per_fetch={g}")
    # block the rows ourselves so fn is applied to whole transfers at once
    stacked = tuple(_group(a, g) for a in args)
    per_block = _dc.replace(prefetch, elements_per_fetch=1)
    if dev_shardings is not None and g > 1:
        shardings = tuple(
            jax.tree.map(
                lambda s: None
                if s is None
                else mk.sharding_for(
                    s.mesh,
                    jax.sharding.PartitionSpec(None, *s.spec),
                    mk.as_kind(s.memory_kind),
                ),
                ds,
                is_leaf=lambda x: x is None or isinstance(x, jax.sharding.NamedSharding),
            )
            for ds in dev_shardings
        )
    elif dev_shardings is not None:
        shardings = tuple(dev_shardings)
    else:
        shardings = None

    def body(_, chunk_args):
        return None, fn(*chunk_args)

    _, out = streamed_scan(
        body,
        None,
        stacked,
        prefetch=per_block,
        dev_shardings=shardings,
        unroll=unroll,
    )
    if g > 1:
        out = jax.tree.map(
            lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), out
        )
    return out

from repro.kernels.rglru_scan.ops import linear_recurrence
from repro.kernels.rglru_scan.ref import linear_recurrence_ref

__all__ = ["linear_recurrence", "linear_recurrence_ref"]

"""Pallas TPU kernels for the compute hot-spots of the offload data path.

Each kernel is a subpackage with:
  ``kernel.py``  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target),
  ``ops.py``     jit'd public wrapper (padding, dtype policy, interpret fallback),
  ``ref.py``     pure-jnp oracle used by the test sweeps.

``streamed_matmul`` is the paper's contribution one level down the hierarchy:
the weight operand stays in HBM (passed **by reference**, ``pl.ANY``) and is
DMA'd tile-wise into a VMEM ring whose depth/lookahead are the paper's
``buffer_size``/``distance`` prefetch knobs.  ``distance=0`` is the paper's
on-demand mode (blocking fetch per tile); ``distance>=1`` overlaps the next
tile's DMA with the current tile's MXU work.

``flash_attention`` (train/prefill) and ``decode_attention`` (one query token
vs an arbitrarily large KV cache, KV streamed block-wise through a VMEM ring)
bound VMEM working sets the same way the paper bounds on-core buffers.

``rglru_scan`` streams the RG-LRU linear recurrence (the hybrid-arch
hot-spot): one HBM pass with a (chunk_t x block_w) VMEM working set,
state carried across time chunks in scratch, vs the associative scan's
O(S log S) materialized intermediates.
"""

"""ISSUE 10 acceptance: traffic front end + crash/recompile bugfix pins.

Covers:
  * the open-loop load generator — seeded determinism, phase/mixture
    shapes, the shared-system-prompt knob,
  * the three ServeSession bugfix pins: oversize submits rejected
    gracefully (no mid-run ValueError), readmit-into-a-full-batch queues
    instead of crashing, and power-of-two prompt buckets bound the prefill
    compile count while staying bitwise-invisible,
  * copy-on-write prefix sharing — lifecycle (refcounts hit zero exactly
    once, disk chunks deleted only at the LAST reference, no stale stream
    keys), bitwise equality vs the unshared baseline for every
    kv kind x page length, transfer savings, and evict/readmit under
    sharing,
  * the SLO scheduler — deterministic virtual-clock reports, goodput
    accounting, and overload shedding.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.kvpager import shared_prefix_keys
from repro.launch import serve as sv
from repro.launch.mesh import make_local_mesh
from repro.serve import SLO, LoadGenConfig, OfferedRequest, Phase, SLOScheduler, generate


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("smollm-360m")


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()


# ---------------------------------------------------------------------------
# load generator
# ---------------------------------------------------------------------------


def _trace_cfg(**kw):
    base = dict(
        seed=3,
        phases=(Phase(2.0, 3.0), Phase(0.5, 12.0), Phase(2.0, 3.0)),
        prompt_lens=(8, 16, 24),
        prompt_mix=(0.4, 0.4, 0.2),
        gen_lens=(2, 4),
        gen_mix=(0.5, 0.5),
        vocab_size=64,
    )
    base.update(kw)
    return LoadGenConfig(**base)


def test_loadgen_is_seed_deterministic():
    a, b = generate(_trace_cfg()), generate(_trace_cfg())
    assert len(a) == len(b) > 0
    for x, y in zip(a, b):
        assert x.arrival_s == y.arrival_s
        assert x.gen == y.gen and x.shared == y.shared
        assert np.array_equal(x.prompt, y.prompt)
    c = generate(_trace_cfg(seed=4))
    assert [o.arrival_s for o in a] != [o.arrival_s for o in c]


def test_loadgen_respects_phases_and_mixtures():
    trace = generate(_trace_cfg())
    arrivals = [o.arrival_s for o in trace]
    assert arrivals == sorted(arrivals)
    assert max(arrivals) < 4.5  # sum of phase durations
    # the burst phase (8x the steady rate, long enough to dominate Poisson
    # noise) is denser than the steady phase
    long = generate(_trace_cfg(phases=(Phase(6.0, 2.0), Phase(6.0, 16.0))))
    steady = sum(1 for o in long if o.arrival_s < 6.0)
    burst = sum(1 for o in long if o.arrival_s >= 6.0)
    assert burst > 2 * steady
    assert {len(o.prompt) for o in trace} <= {8, 16, 24}
    assert {o.gen for o in trace} <= {2, 4}


def test_loadgen_shared_prefix():
    trace = generate(_trace_cfg(shared_prefix_len=8, shared_frac=0.5))
    shared = [o for o in trace if o.shared]
    private = [o for o in trace if not o.shared]
    assert shared and private  # frac=0.5 over a dense trace hits both
    head = shared[0].prompt[:8]
    for o in shared:
        assert np.array_equal(o.prompt[: min(8, len(o.prompt))],
                              head[: min(8, len(o.prompt))])
    # with sharing disabled nothing is flagged
    assert not any(o.shared for o in generate(_trace_cfg()))


def test_loadgen_validation():
    with pytest.raises(ValueError, match="duration_s"):
        Phase(0.0, 1.0)
    with pytest.raises(ValueError, match="rate_rps"):
        Phase(1.0, -1.0)
    with pytest.raises(ValueError, match="align"):
        _trace_cfg(prompt_mix=(1.0,))
    with pytest.raises(ValueError, match="shared_frac"):
        _trace_cfg(shared_frac=1.5)


# ---------------------------------------------------------------------------
# bugfix pins: oversize submit, readmit-into-full-batch, prefill buckets
# ---------------------------------------------------------------------------


def test_oversize_submit_rejected_gracefully(cfg, mesh):
    """An oversized request must not raise mid-run: submit returns None,
    the ``rejected`` counter ticks, and the session keeps serving."""
    with sv.ServeSession(
        cfg, mesh, slots=1, max_len=16, kv_kind="pinned_host", page_len=4,
        seed=0,
    ) as s:
        ok = s.submit(np.arange(1, 9, dtype=np.int32), 4)
        assert ok is not None
        bad = s.submit(np.arange(1, 14, dtype=np.int32), 8)  # 13 + 8 > 16
        assert bad is None
        assert s.rejected == 1
        out = s.run()
        assert ok in out and len(out[ok]) == 4  # survivor fully served


def test_readmit_into_full_batch_queues_not_crashes(cfg, mesh):
    """Readmitting while every slot is occupied must queue the request
    (ahead of new submissions) instead of raising, and the interrupted
    request must still finish bitwise-identical to an uninterrupted run."""
    prompt = np.arange(1, 14, dtype=np.int32)
    other = np.arange(2, 11, dtype=np.int32)

    def run(interrupt):
        with sv.ServeSession(
            cfg, mesh, slots=1, max_len=32, kv_kind="pinned_host",
            page_len=4, hot_pages=1, seed=5,
        ) as s:
            rid = s.submit(prompt, 10)
            s.admit_pending()
            for _ in range(3):
                s.step()
            if interrupt:
                s.evict(rid)
                late = s.submit(other, 3)
                s.admit_pending()  # the single slot is now occupied
                assert s.active == {late: 0}
                assert s.readmit(rid) is False  # queued, not crashed
                assert s.readmit(rid) is False  # idempotent while queued
            while s.pending_work():
                s.step()
            assert len(s.requests[rid].emitted) == 10  # resumed and finished
            return np.asarray(s.requests[rid].emitted, np.int32)

    assert np.array_equal(run(True), run(False))


def test_prefill_compiles_bounded_by_buckets(cfg, mesh):
    """Mixed prompt lengths must not compile one prefill per length: the
    power-of-two buckets bound the variant count, and the padded prefill's
    first token matches an exact-width prefill bitwise."""
    lengths = [9, 11, 13, 14, 17, 21, 26, 30]  # 8 lengths -> 2 buckets
    with sv.ServeSession(
        cfg, mesh, slots=2, max_len=48, kv_kind="pinned_host", page_len=4,
        seed=2,
    ) as s:
        rids = {
            n: s.submit(np.arange(1, n + 1, dtype=np.int32), 2)
            for n in lengths
        }
        out = s.run()
        assert s.prefill_compiles() == 2  # {16, 32}, not 8
        # pad-invisibility: recompute each first token at the EXACT width
        for n, rid in rids.items():
            prompt = np.arange(1, n + 1, dtype=np.int32)
            logits, _ = s._prefill(
                s.params,
                sv._prompt_batch(cfg, prompt[None, :]),
                jnp.asarray(n - 1, jnp.int32),
            )
            exact = np.asarray(s._argmax(logits))[0]
            assert out[rid][0] == exact, n


# ---------------------------------------------------------------------------
# copy-on-write prefix sharing
# ---------------------------------------------------------------------------


def test_shared_prefix_keys_are_content_addressed():
    a = np.arange(1, 17, dtype=np.int32)
    b = np.concatenate([a[:8], np.arange(90, 98, dtype=np.int32)])
    ka, kb = shared_prefix_keys(a, 4), shared_prefix_keys(b, 4)
    assert len(ka) == len(kb) == 4
    assert ka[:2] == kb[:2]      # identical 8-token prefix -> same keys
    assert ka[2:] != kb[2:]      # divergent tail -> different keys
    assert shared_prefix_keys(a, 4, shared_len=8) == ka[:2]
    # a page key depends on EVERYTHING before it (KV is causal), not just
    # the page's own tokens
    c = np.concatenate([np.arange(50, 54, dtype=np.int32), a[4:8]])
    assert shared_prefix_keys(c, 4)[1] != ka[1]


@pytest.mark.parametrize("kv_kind", ["pinned_host", "disk_host"])
@pytest.mark.parametrize("page_len", [4, 8])
def test_prefix_sharing_bitwise_equals_unshared(cfg, mesh, kv_kind, page_len):
    """Sharing must be bitwise-invisible: same tokens as the unshared run,
    strictly fewer unique cold fetches."""
    kw = dict(
        batch=3, prompt_len=24, gen=6, kv_kind=kv_kind,
        kv_page_len=page_len, hot_pages=1, seed=9, shared_prefix_len=16,
        warmup=False,
    )
    on = sv.serve(cfg, mesh, **kw, prefix_sharing=True)
    off = sv.serve(cfg, mesh, **kw, prefix_sharing=False)
    assert np.array_equal(on["generated"], off["generated"])
    assert on["stats"].shared_hits > 0
    assert off["stats"].shared_hits == 0
    assert on["stats"].unique_group_fetches < off["stats"].unique_group_fetches
    if kv_kind == "disk_host":
        assert on["stats"].disk_requests < off["stats"].disk_requests


def test_prefix_sharing_lifecycle_refcounts_and_chunk_deletion(cfg, mesh):
    """Shared chunks live exactly as long as their last reference: the
    registry refcounts down once per retiring sharer, disk chunks survive
    while ANY sharer is active, and everything (registry, stream keys,
    chunks) is gone after the last retire."""
    shared_len, page_len = 16, 4
    head = np.arange(1, shared_len + 1, dtype=np.int32)
    shared_keys = set(shared_prefix_keys(head, page_len))
    prompts = {
        i: np.concatenate([head, np.arange(40 + 10 * i, 44 + 10 * i,
                                           dtype=np.int32)])
        for i in range(3)
    }
    gens = {0: 2, 1: 5, 2: 9}  # staggered: sharers retire one at a time

    with sv.ServeSession(
        cfg, mesh, slots=3, max_len=32, kv_kind="disk_host",
        page_len=page_len, hot_pages=1, seed=1,
    ) as s:
        deleted = []
        real_delete = s._store.delete
        s._store.delete = lambda key: (deleted.append(key),
                                       real_delete(key))[1]
        rids = {i: s.submit(prompts[i], gens[i]) for i in prompts}
        s.admit_pending()
        # content addressing covers EVERY full page behind the write head:
        # the 4 common head pages alias (one entry, 3 refs each) while each
        # private 4-token tail page gets its own single-ref entry
        per_req = {i: shared_prefix_keys(prompts[i], page_len)
                   for i in prompts}
        assert all(k[: len(shared_keys)] == per_req[0][: len(shared_keys)]
                   for k in per_req.values())
        assert s.pager.shared_pages() == len(
            {k for keys in per_req.values() for k in keys}
        )
        refs_total = sum(len(k) for k in per_req.values())
        assert s.pager.shared_refs() == refs_total
        retired_at = {}
        while s.pending_work():
            s.step()
            for i, rid in rids.items():
                if rid not in s.pager.tables and i not in retired_at:
                    retired_at[i] = s.pager.shared_refs()
                    if len(retired_at) < 3:
                        # sharers still active: every shared chunk that
                        # was spilled must still be readable
                        assert not (set(deleted) & shared_keys)
        # refs dropped once per retiring sharer — never double-decremented
        assert retired_at[0] == refs_total - len(per_req[0])
        assert retired_at[1] == len(per_req[2])
        assert retired_at[2] == 0
        assert s.pager.shared_pages() == 0
        # deleted at the LAST reference, exactly once per chunk
        spilled_shared = [k for k in deleted if k in shared_keys]
        assert spilled_shared  # the workload did spill shared pages
        assert len(spilled_shared) == len(set(spilled_shared))
        assert not any(k in s._store for k in shared_keys)
        # no stale stream keys for anyone
        assert not s.pager.stream._owner and not s.pager.stream._staged


@pytest.mark.parametrize("kv_kind", ["pinned_host", "disk_host"])
def test_evict_readmit_with_prefix_sharing_bitwise(cfg, mesh, kv_kind):
    """Evicting one sharer while its siblings keep decoding against the
    aliased pages must resume bitwise — and never lose the shared chunks."""
    head = np.arange(1, 13, dtype=np.int32)
    prompts = [np.concatenate([head, np.arange(t, t + 4, dtype=np.int32)])
               for t in (40, 60)]

    def run(interrupt):
        with sv.ServeSession(
            cfg, mesh, slots=2, max_len=32, kv_kind=kv_kind, page_len=4,
            hot_pages=1, seed=5,
        ) as s:
            rid = s.submit(prompts[0], 10)
            s.submit(prompts[1], 12)
            s.admit_pending()
            assert s.pager.shared_refs() > 0  # prefix actually aliased
            for _ in range(3):
                s.step()
            if interrupt:
                s.evict(rid)
                s.step()
                s.readmit(rid)
            while s.pending_work():
                s.step()
            return np.asarray(s.requests[rid].emitted, np.int32)

    assert np.array_equal(run(True), run(False))


# ---------------------------------------------------------------------------
# SLO scheduler
# ---------------------------------------------------------------------------


def _session(cfg, mesh, **kw):
    base = dict(slots=2, max_len=32, kv_kind="pinned_host", page_len=4,
                hot_pages=1, seed=0)
    base.update(kw)
    return sv.ServeSession(cfg, mesh, **base)


def _small_trace(**kw):
    base = dict(
        seed=5,
        phases=(Phase(1.0, 4.0), Phase(0.25, 16.0)),
        prompt_lens=(8, 12, 20),
        prompt_mix=(0.5, 0.3, 0.2),
        gen_lens=(2, 4),
        gen_mix=(0.5, 0.5),
        shared_prefix_len=8,
        shared_frac=0.5,
        vocab_size=64,
    )
    base.update(kw)
    return LoadGenConfig(**base)


def test_scheduler_report_is_deterministic(cfg, mesh):
    """Virtual clock + seeded trace: two fresh runs yield the same report,
    byte for byte (what makes the bench gates meaningful)."""
    def once():
        with _session(cfg, mesh) as s:
            return SLOScheduler(
                s, generate(_small_trace()), slo=SLO(0.2, 0.05),
                max_queue=8, virtual_step_s=0.01,
            ).run()

    def scrub(rep):
        # wall-clock transfer waits are the ONE real-time residue; every
        # scheduled/counted quantity must reproduce exactly
        rep = dict(rep)
        rep["per_tier"] = {
            tier: {k: v for k, v in d.items() if k != "wait_s"}
            for tier, d in rep["per_tier"].items()
        }
        return rep

    r1, r2 = once(), once()
    assert scrub(r1) == scrub(r2)
    assert r1["offered"] > 0
    assert r1["completed"] == r1["submitted"]  # small trace fully drains
    assert r1["emitted_tokens"] > 0
    assert set(r1["ttft_s"]) == {"p50", "p90", "p99"}
    assert r1["slo"] == dataclasses.asdict(SLO(0.2, 0.05))


def test_scheduler_goodput_counts_only_slo_attaining(cfg, mesh):
    """Goodput under an impossible SLO is zero even though throughput is
    not — the metric's whole point."""
    with _session(cfg, mesh) as s:
        strict = SLOScheduler(
            s, generate(_small_trace()), slo=SLO(ttft_s=0.0, tpot_s=0.0),
            virtual_step_s=0.01,
        ).run()
    assert strict["completed"] > 0 and strict["emitted_tokens"] > 0
    assert strict["slo_attainment"] == 0.0
    assert strict["goodput_rps"] == 0.0
    assert strict["goodput_tokens_per_s"] == 0.0

    with _session(cfg, mesh) as s:
        loose = SLOScheduler(
            s, generate(_small_trace()), slo=SLO(ttft_s=1e9, tpot_s=1e9),
            virtual_step_s=0.01,
        ).run()
    assert loose["slo_attainment"] == 1.0
    assert loose["goodput_rps"] > 0.0


def test_scheduler_sheds_overload_and_counts_oversize(cfg, mesh):
    """A bound-1 admission queue under a burst sheds arrivals instead of
    growing a backlog, and oversized offers are counted as rejected_oversize
    while the run still completes."""
    trace = generate(_small_trace(phases=(Phase(0.2, 60.0),)))
    big = OfferedRequest(
        arrival_s=0.0,  # first in line: reaches submit() before the burst
        prompt=np.arange(1, 40, dtype=np.int32),  # 39 + 4 > max_len 32
        gen=4,
        shared=False,
    )
    with _session(cfg, mesh) as s:
        rep = SLOScheduler(
            s, list(trace) + [big], slo=SLO(0.5, 0.1),
            max_queue=1, virtual_step_s=0.01,
        ).run()
    assert rep["rejected_overload"] > 0
    assert rep["rejected_oversize"] == 1
    assert rep["completed"] == rep["submitted"]  # everyone admitted finishes
    assert rep["offered"] == len(trace) + 1

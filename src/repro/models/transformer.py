"""Model assembly: decoder-only LM over every assigned architecture family.

Pre-norm residual blocks; uniform-block archs are scanned over stacked
``(L, ...)`` parameter leaves (small HLO + the hook the streaming prefetch
engine attaches to); heterogeneous archs (hybrid / ssm) are **period-scanned**
(each in-pattern position stacked over the repeating periods, scanned as a
group, remainder layers unrolled — see ModelConfig.period_scan) or fully
unrolled when the pattern doesn't repeat.

Modes:
  ``forward_train``  — full-sequence; returns logits (+ MoE aux loss);
                       ``lm_loss`` adds the seq-chunked CE.
  ``prefill``        — full-sequence + populated KV caches.
  ``decode_step``    — one token, O(1)/O(window)/O(cache) per arch family.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, frontends, layers, moe, rglru, rope, xlstm
from repro.models.layers import Params

IGNORE_INDEX = -100


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key: jax.Array, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    if kind == "attn":
        p: Params = {
            "ln1": layers.init_norm(ks[0], cfg.d_model, cfg.norm_type),
            "attn": attention.init_attention(ks[1], cfg),
            "ln2": layers.init_norm(ks[2], cfg.d_model, cfg.norm_type),
        }
        if cfg.n_experts:
            p["moe"] = moe.init_moe(ks[3], cfg)
        elif cfg.d_ff:
            p["mlp"] = layers.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_type)
        return p
    if kind == "rec":
        return {
            "ln1": layers.init_norm(ks[0], cfg.d_model, cfg.norm_type),
            "rec": rglru.init_rglru_block(ks[1], cfg),
            "ln2": layers.init_norm(ks[2], cfg.d_model, cfg.norm_type),
            "mlp": layers.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_type),
        }
    if kind == "mlstm":
        return {
            "ln1": layers.init_norm(ks[0], cfg.d_model, cfg.norm_type),
            "mlstm": xlstm.init_mlstm_block(ks[1], cfg),
        }
    if kind == "slstm":
        return {
            "ln1": layers.init_norm(ks[0], cfg.d_model, cfg.norm_type),
            "slstm": xlstm.init_slstm_block(ks[1], cfg),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def init_model(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kl, kh, kv = jax.random.split(key, 4)
    params: Params = {}
    if cfg.n_codebooks:
        params["embed"] = frontends.init_audio_embed(ke, cfg)
    else:
        params["embed"] = layers.init_embed(ke, cfg.vocab_size, cfg.d_model)
        if not cfg.tie_embeddings:
            params["head"] = layers.init_head(kh, cfg.d_model, cfg.vocab_size)
    if cfg.vision_embed:
        params["vision"] = frontends.init_vision_merger(kv, cfg)
    params["ln_f"] = layers.init_norm(kh, cfg.d_model, cfg.norm_type)

    lkeys = jax.random.split(kl, cfg.n_layers)
    if cfg.uniform_blocks and cfg.use_scan:
        blocks = [_init_block(lkeys[i], cfg, "attn") for i in range(cfg.n_layers)]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    elif cfg.period_scan:
        # heterogeneous but periodic: stack each in-period position over the
        # full periods (scan axis), keep the remainder layers unrolled
        p = cfg.scan_period
        n_full = cfg.n_layers // p
        periods = {}
        for k in range(p):
            pos = [
                _init_block(lkeys[j * p + k], cfg, cfg.block_kind(k))
                for j in range(n_full)
            ]
            periods[f"pos_{k}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *pos)
        blocks: Params = {"periods": periods}
        for k in range(cfg.n_layers % p):
            i = n_full * p + k
            blocks[f"tail_{k}"] = _init_block(lkeys[i], cfg, cfg.block_kind(i))
        params["blocks"] = blocks
    else:
        params["blocks"] = {
            f"layer_{i:03d}": _init_block(lkeys[i], cfg, cfg.block_kind(i))
            for i in range(cfg.n_layers)
        }
    return params


def init_model_shell(key: jax.Array, cfg: ModelConfig) -> Params:
    """The non-``blocks`` leaves of :func:`init_model` (embed / vision /
    head / ln_f), bitwise-identical (same key folding), without touching
    any layer.  One piece of the weight-streamed group-wise init: huge
    models must initialize one transfer group at a time, never whole."""
    ke, kl, kh, kv = jax.random.split(key, 4)
    params: Params = {}
    if cfg.n_codebooks:
        params["embed"] = frontends.init_audio_embed(ke, cfg)
    else:
        params["embed"] = layers.init_embed(ke, cfg.vocab_size, cfg.d_model)
        if not cfg.tie_embeddings:
            params["head"] = layers.init_head(kh, cfg.d_model, cfg.vocab_size)
    if cfg.vision_embed:
        params["vision"] = frontends.init_vision_merger(kv, cfg)
    params["ln_f"] = layers.init_norm(kh, cfg.d_model, cfg.norm_type)
    return params


def init_model_slice(key: jax.Array, cfg: ModelConfig, lo: int, hi: int) -> Params:
    """The ``blocks`` slice ``[lo:hi)`` of :func:`init_model`,
    bitwise-identical (each layer drawn from the same per-layer key),
    materializing only those layers.  Uniform scanned stacks return the
    stacked slice; unrolled layouts (and the period layout's unrolled
    tail) return the named-block dict slice."""
    _, kl, _, _ = jax.random.split(key, 4)
    lkeys = jax.random.split(kl, cfg.n_layers)
    if cfg.uniform_blocks and cfg.use_scan:
        blocks = [_init_block(lkeys[i], cfg, "attn") for i in range(lo, hi)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    if cfg.period_scan:
        tail0 = (cfg.n_layers // cfg.scan_period) * cfg.scan_period
        if lo < tail0:
            raise ValueError(
                "period-scanned ranges init via init_model_period_slice"
            )
        return {
            f"tail_{i - tail0}": _init_block(lkeys[i], cfg, cfg.block_kind(i))
            for i in range(lo, hi)
        }
    return {
        f"layer_{i:03d}": _init_block(lkeys[i], cfg, cfg.block_kind(i))
        for i in range(lo, hi)
    }


def init_model_period_slice(
    key: jax.Array, cfg: ModelConfig, ulo: int, uhi: int
) -> Params:
    """The period-unit slice ``[ulo:uhi)`` of a period-scanned model's
    ``params["blocks"]["periods"]``, bitwise-identical to
    :func:`init_model`'s stacking (same per-layer keys), materializing only
    those periods — the period layout's analogue of
    :func:`init_model_slice`."""
    if not cfg.period_scan:
        raise ValueError("init_model_period_slice requires a period-scanned arch")
    _, kl, _, _ = jax.random.split(key, 4)
    lkeys = jax.random.split(kl, cfg.n_layers)
    p = cfg.scan_period
    periods: Params = {}
    for k in range(p):
        pos = [
            _init_block(lkeys[j * p + k], cfg, cfg.block_kind(k))
            for j in range(ulo, uhi)
        ]
        periods[f"pos_{k}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *pos)
    return periods


def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, cl: int, dtype) -> Params:
    if kind == "attn":
        w = cfg.window if cfg.family == "hybrid" else cl
        return attention.init_cache(cfg, batch, min(w or cl, cl) or cl, dtype)
    if kind == "rec":
        return rglru.init_rglru_state(cfg, batch)
    if kind == "mlstm":
        return xlstm.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xlstm.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def _stack_tree(n: int, tree: Params) -> Params:
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), tree
    )


def init_caches(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Params:
    """Decode-state pytree for a context of ``seq_len`` tokens."""
    cl = cfg.cache_len(seq_len)
    if cfg.uniform_blocks and cfg.use_scan:
        return _stack_tree(cfg.n_layers, attention.init_cache(cfg, batch, cl, dtype))
    if cfg.period_scan:
        p = cfg.scan_period
        n_full = cfg.n_layers // p
        caches: Params = {
            "periods": {
                f"pos_{k}": _stack_tree(
                    n_full, _init_layer_cache(cfg, cfg.block_kind(k), batch, cl, dtype)
                )
                for k in range(p)
            }
        }
        for k in range(cfg.n_layers % p):
            i = n_full * p + k
            caches[f"tail_{k}"] = _init_layer_cache(cfg, cfg.block_kind(i), batch, cl, dtype)
        return caches
    return {
        f"layer_{i:03d}": _init_layer_cache(cfg, cfg.block_kind(i), batch, cl, dtype)
        for i in range(cfg.n_layers)
    }


# ---------------------------------------------------------------------------
# block application (one layer)
# ---------------------------------------------------------------------------

def _block_train(cfg: ModelConfig, p: Params, x: jax.Array, angles, mesh=None, sharder=None):
    kind = "attn"  # uniform path; heterogenous archs dispatch explicitly below
    if sharder is not None:
        p = sharder.block(p)  # explicit per-layer FSDP all-gather (ZeRO-3)
    h = layers.norm_apply(p["ln1"], x, cfg.norm_type)
    h = attention.attention_train(cfg, p["attn"], h, angles)
    x = x + h
    h = layers.norm_apply(p["ln2"], x, cfg.norm_type)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        if cfg.moe_impl == "sorted_ep" and mesh is not None:
            h, aux = moe.moe_sorted_ep(cfg, p["moe"], h, mesh)
        else:
            h, aux = moe.moe_dispatch(cfg, p["moe"], h)
    elif "mlp" in p:
        h = layers.mlp_apply(p["mlp"], h, cfg.mlp_type)
    else:
        h = jnp.zeros_like(h)
    x = x + h
    if sharder is not None:
        x = sharder.acts(x)
    return x, aux


def _hetero_block_train(cfg: ModelConfig, kind: str, p: Params, x, angles, state=None):
    """Returns (x, new_state, moe_aux)."""
    h = layers.norm_apply(p["ln1"], x, cfg.norm_type)
    new_state = None
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        if state is not None:
            h, new_state = attention.attention_prefill(cfg, p["attn"], h, angles, state)
        else:
            h = attention.attention_train(cfg, p["attn"], h, angles)
    elif kind == "rec":
        h, new_state = rglru.rglru_block_train(cfg, p["rec"], h, state)
    elif kind == "mlstm":
        h, new_state = xlstm.mlstm_block_train(cfg, p["mlstm"], h, state)
    elif kind == "slstm":
        h, new_state = xlstm.slstm_block_train(cfg, p["slstm"], h, state)
    x = x + h
    if "moe" in p:
        h = layers.norm_apply(p["ln2"], x, cfg.norm_type)
        h, aux = moe.moe_dispatch(cfg, p["moe"], h)
        x = x + h
    elif "mlp" in p:
        h = layers.norm_apply(p["ln2"], x, cfg.norm_type)
        x = x + layers.mlp_apply(p["mlp"], h, cfg.mlp_type)
    return x, new_state, aux


def _block_decode(cfg: ModelConfig, kind: str, p: Params, x, angles, cache, pos):
    h = layers.norm_apply(p["ln1"], x, cfg.norm_type)
    if kind == "attn":
        h, new_cache = attention.attention_decode(cfg, p["attn"], h, angles, cache, pos)
    elif kind == "rec":
        h, new_cache = rglru.rglru_block_step(cfg, p["rec"], h, cache)
    elif kind == "mlstm":
        h, new_cache = xlstm.mlstm_block_step(cfg, p["mlstm"], h, cache)
    elif kind == "slstm":
        h, new_cache = xlstm.slstm_block_step(cfg, p["slstm"], h, cache)
    else:
        raise ValueError(kind)
    x = x + h
    if kind in ("attn", "rec") and ("mlp" in p or "moe" in p):
        h = layers.norm_apply(p["ln2"], x, cfg.norm_type)
        if "moe" in p:
            # router-first dense top-k (no capacity buffer): the same math
            # the route-aware streamed decode runs on its fetched subset
            h = moe.moe_decode(cfg, p["moe"], h)
        else:
            h = layers.mlp_apply(p["mlp"], h, cfg.mlp_type)
        x = x + h
    return x, new_cache


# ---------------------------------------------------------------------------
# embedding / positions / head
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params: Params, batch: dict, pos=None) -> jax.Array:
    dt = cfg.compute_dtype
    if cfg.n_codebooks:
        x = frontends.audio_embed_apply(params["embed"], batch["codes"], dt)
    else:
        x = layers.embed_apply(params["embed"], batch["tokens"], dt)
    if cfg.vision_embed and "vision_embeds" in batch:
        vis = frontends.vision_merge_apply(
            params["vision"], batch["vision_embeds"].astype(dt)
        )
        x = jnp.concatenate([vis, x], axis=1)  # vision prefix + text
    if cfg.pos_type == "sinusoidal":
        s = x.shape[1]
        # decode passes the absolute position of its single token (a scalar,
        # or a (B,) per-slot vector on the serving path); train/prefill
        # start at 0
        if pos is None:
            positions = jnp.arange(s)
        elif jnp.ndim(pos) == 1:
            positions = (
                jnp.asarray(pos, jnp.int32)[:, None] + jnp.arange(s)[None, :] - (s - 1)
            )
        else:
            positions = jnp.asarray(pos, jnp.int32)[None] + jnp.arange(s) - (s - 1)
        emb = rope.sinusoidal_embedding(positions, cfg.d_model)
        if emb.ndim == 2:
            emb = emb[None]
        x = x + emb.astype(dt)
    if getattr(cfg, "scale_embeddings", False):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    return x


def _angles(cfg: ModelConfig, batch: dict, seq_len: int, pos=None):
    """RoPE angles for the whole sequence (train/prefill) or one step."""
    if cfg.pos_type == "rope":
        if pos is not None and jnp.ndim(pos) == 1:
            positions = jnp.asarray(pos)[:, None]  # (B,1) per-slot positions
        elif pos is not None:
            positions = jnp.asarray(pos)[None, None]  # (1,1)
        else:
            positions = jnp.arange(seq_len)[None]
        return rope.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    if cfg.pos_type == "mrope":
        p3d = batch["positions_3d"]
        return rope.mrope_angles(p3d, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    return None


def _head(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    x = layers.norm_apply(params["ln_f"], x, cfg.norm_type)
    if cfg.n_codebooks:
        logits = frontends.audio_heads_apply(params["embed"], x)
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(x.dtype))
    else:
        logits = layers.head_apply(params["head"], x)
    cap = getattr(cfg, "logit_softcap", 0.0)
    if cap:
        logits = cap * jnp.tanh(logits / cap)
    return logits


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full"


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def forward_train(
    cfg: ModelConfig, params: Params, batch: dict, mesh=None, sharder=None
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits, moe_aux_loss)."""
    x, aux = forward_hidden(cfg, params, batch, mesh, sharder)
    return _head(cfg, params, x), aux


def forward_hidden(
    cfg: ModelConfig, params: Params, batch: dict, mesh=None, sharder=None
) -> tuple[jax.Array, jax.Array]:
    """Trunk only: pre-head hidden states (B, S, D) + moe aux loss."""
    x = _embed(cfg, params, batch)
    if sharder is not None:
        x = sharder.acts(x)
    angles = _angles(cfg, batch, x.shape[1])

    if cfg.uniform_blocks and cfg.use_scan:
        def body(carry, p):
            x, aux = carry
            x, a = _block_train(cfg, p, x, angles, mesh, sharder)
            return (x, aux + a), None

        wrapped = _remat(cfg, body)
        (x, aux), _ = jax.lax.scan(wrapped, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    elif cfg.period_scan:
        aux = jnp.zeros((), jnp.float32)
        period = cfg.scan_period

        def period_body(x, pos_params):
            for k in range(period):
                pk = pos_params[f"pos_{k}"]
                if sharder is not None:
                    pk = sharder.block(pk, ("periods", f"pos_{k}"))
                fn = _remat(cfg, functools.partial(_hetero_block_train, cfg, cfg.block_kind(k)))
                x, _, _ = fn(pk, x, angles)
                if sharder is not None:
                    x = sharder.acts(x)
            return x, None

        x, _ = jax.lax.scan(period_body, x, params["blocks"]["periods"])
        for k in range(cfg.n_layers % period):
            i = (cfg.n_layers // period) * period + k
            name = f"tail_{k}"
            p = params["blocks"][name]
            if sharder is not None:
                p = sharder.block(p, (name,))
            fn = _remat(cfg, functools.partial(_hetero_block_train, cfg, cfg.block_kind(i)))
            x, _, _ = fn(p, x, angles)
            if sharder is not None:
                x = sharder.acts(x)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            kind = cfg.block_kind(i)
            name = f"layer_{i:03d}"
            p = params["blocks"][name]
            if sharder is not None:
                p = sharder.block(p, (name,))
            fn = _remat(cfg, functools.partial(_hetero_block_train, cfg, kind))
            x, _, a = fn(p, x, angles)
            aux = aux + a
            if sharder is not None:
                x = sharder.acts(x)
    return x, aux


def prefill(
    cfg: ModelConfig, params: Params, batch: dict, caches: Params, mesh=None, sharder=None,
    last_pos=None,
) -> tuple[jax.Array, Params]:
    """Full-sequence forward that also fills decode state.  Returns
    (last-position logits, caches).

    ``last_pos`` (traced int32 scalar): position whose logits to return —
    the last *real* prompt token when the prompt is right-padded into a
    length bucket (the serve path's bounded-compile prefill).  ``None``
    keeps the static last position (exact-length prompts)."""
    x = _embed(cfg, params, batch)
    if sharder is not None:
        x = sharder.acts(x)
    angles = _angles(cfg, batch, x.shape[1])

    if cfg.uniform_blocks and cfg.use_scan:
        def body(x, pc):
            p, cache = pc
            if sharder is not None:
                p = sharder.block(p)
            h = layers.norm_apply(p["ln1"], x, cfg.norm_type)
            h, new_cache = attention.attention_prefill(cfg, p["attn"], h, angles, cache)
            x = x + h
            h = layers.norm_apply(p["ln2"], x, cfg.norm_type)
            if "moe" in p:
                h, _ = moe.moe_dispatch(cfg, p["moe"], h)
            elif "mlp" in p:
                h = layers.mlp_apply(p["mlp"], h, cfg.mlp_type)
            else:
                h = jnp.zeros_like(h)
            x = x + h
            if sharder is not None:
                x = sharder.acts(x)
            return x, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    elif cfg.period_scan:
        period = cfg.scan_period

        def period_body(x, args):
            pos_params, pos_caches = args
            new_pos = {}
            for k in range(period):
                pk = pos_params[f"pos_{k}"]
                if sharder is not None:
                    pk = sharder.block(pk, ("periods", f"pos_{k}"))
                x, st, _ = _hetero_block_train(
                    cfg, cfg.block_kind(k), pk, x, angles, pos_caches[f"pos_{k}"]
                )
                if sharder is not None:
                    x = sharder.acts(x)
                new_pos[f"pos_{k}"] = st
            return x, new_pos

        x, new_periods = jax.lax.scan(
            period_body, x, (params["blocks"]["periods"], caches["periods"])
        )
        new_caches = {"periods": new_periods}
        for k in range(cfg.n_layers % period):
            i = (cfg.n_layers // period) * period + k
            name = f"tail_{k}"
            p = params["blocks"][name]
            if sharder is not None:
                p = sharder.block(p, (name,))
            x, st, _ = _hetero_block_train(cfg, cfg.block_kind(i), p, x, angles, caches[name])
            if sharder is not None:
                x = sharder.acts(x)
            new_caches[name] = st
    else:
        new_caches = {}
        for i in range(cfg.n_layers):
            kind = cfg.block_kind(i)
            name = f"layer_{i:03d}"
            p = params["blocks"][name]
            if sharder is not None:
                p = sharder.block(p, (name,))
            x, st, _ = _hetero_block_train(cfg, kind, p, x, angles, caches[name])
            if sharder is not None:
                x = sharder.acts(x)
            new_caches[name] = st
    if last_pos is None:
        xl = x[:, -1:]
    else:
        xl = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
    logits = _head(cfg, params, xl)
    return logits, new_caches


def decode_step(
    cfg: ModelConfig, params: Params, batch: dict, caches: Params, pos: jax.Array, sharder=None
) -> tuple[jax.Array, Params]:
    """One decode step.  batch carries the new token(s); ``pos`` is the
    absolute position being written (scalar int32, or a (B,) vector of
    per-slot positions — the serving path's continuous batching).  Returns
    (logits, caches)."""
    x = _embed(cfg, params, batch, pos=pos)
    angles = _angles(cfg, batch, 1, pos=pos)
    if cfg.pos_type == "mrope":
        angles = _angles(cfg, batch, 1)  # positions_3d provided per-step

    if cfg.uniform_blocks and cfg.use_scan and cfg.decode_cache_in_carry:
        # §Perf variant: stacked caches ride in the carry and are updated
        # in place per layer — XLA aliases the (donated) cache buffer through
        # the loop instead of keeping xs + ys + update copies alive.
        def body(carry, p):
            x, caches_c, i = carry
            layer_cache = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
                caches_c,
            )
            if sharder is not None:
                p = sharder.block(p)
            x, nc = _block_decode(cfg, "attn", p, x, angles, layer_cache, pos)
            if sharder is not None:
                x = sharder.acts(x)
            caches_c = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), i, 0
                ),
                caches_c,
                nc,
            )
            return (x, caches_c, i + 1), None

        (x, new_caches, _), _ = jax.lax.scan(
            body, (x, caches, jnp.zeros((), jnp.int32)), params["blocks"]
        )
    elif cfg.uniform_blocks and cfg.use_scan:
        def body(x, pc):
            p, cache = pc
            if sharder is not None:
                p = sharder.block(p)
            x, nc = _block_decode(cfg, "attn", p, x, angles, cache, pos)
            if sharder is not None:
                x = sharder.acts(x)
            return x, nc

        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    elif cfg.period_scan:
        period = cfg.scan_period

        def period_body(x, args):
            pos_params, pos_caches = args
            new_pos = {}
            for k in range(period):
                pk = pos_params[f"pos_{k}"]
                if sharder is not None:
                    pk = sharder.block(pk, ("periods", f"pos_{k}"))
                x, st = _block_decode(
                    cfg, cfg.block_kind(k), pk, x, angles, pos_caches[f"pos_{k}"], pos
                )
                if sharder is not None:
                    x = sharder.acts(x)
                new_pos[f"pos_{k}"] = st
            return x, new_pos

        x, new_periods = jax.lax.scan(
            period_body, x, (params["blocks"]["periods"], caches["periods"])
        )
        new_caches = {"periods": new_periods}
        for k in range(cfg.n_layers % period):
            i = (cfg.n_layers // period) * period + k
            name = f"tail_{k}"
            p = params["blocks"][name]
            if sharder is not None:
                p = sharder.block(p, (name,))
            x, st = _block_decode(cfg, cfg.block_kind(i), p, x, angles, caches[name], pos)
            if sharder is not None:
                x = sharder.acts(x)
            new_caches[name] = st
    else:
        new_caches = {}
        for i in range(cfg.n_layers):
            kind = cfg.block_kind(i)
            name = f"layer_{i:03d}"
            p = params["blocks"][name]
            if sharder is not None:
                p = sharder.block(p, (name,))
            x, st = _block_decode(cfg, kind, p, x, angles, caches[name], pos)
            if sharder is not None:
                x = sharder.acts(x)
            new_caches[name] = st
    return _head(cfg, params, x), new_caches


# ---------------------------------------------------------------------------
# layer-group stages (streamed parameters — see repro.core.weightstream)
#
# The monolithic forward/prefill/decode above consume the whole param tree;
# these stages consume ONE transfer group at a time so host/disk-homed
# weights can arrive by reference mid-stack: the embed group starts the
# residual stream, each stacked layer-group slice continues it with the
# exact scan body the monolithic path uses, and the head group finishes it.
# Chaining the stages is value-identical to the single scan (same per-layer
# ops in the same order), and identical *programs* across memory kinds is
# what makes streamed == device-resident bitwise.
# ---------------------------------------------------------------------------


def embed_stage(cfg: ModelConfig, group: Params, batch: dict, pos=None, sharder=None):
    """Embed-group forward: ``group`` holds the plan's embed leaves
    (``{"embed": ..., "vision"?: ...}``).  Returns the first hidden states;
    RoPE angles are derived separately (:func:`stage_angles`) because the
    vision prefix changes the sequence length the angles must cover."""
    x = _embed(cfg, group, batch, pos=pos)
    if sharder is not None:
        x = sharder.acts(x)
    return x


def stage_angles(cfg: ModelConfig, batch: dict, seq_len: int, pos=None):
    """RoPE/mRoPE angles for the staged passes (``None`` for pos types the
    blocks do not consume)."""
    if cfg.pos_type == "mrope" and pos is not None:
        return _angles(cfg, batch, 1)
    return _angles(cfg, batch, seq_len, pos=pos)


def block_group_train(
    cfg: ModelConfig, blocks_slice: Params, x, aux, angles, mesh=None, sharder=None
):
    """Forward over one stacked layer-group slice ``(Lg, ...)`` — the same
    (remat'd) scan body as :func:`forward_hidden`, entered mid-stack.
    ``aux`` is the running MoE aux-loss carry.  Returns ``(x, aux)``."""

    def body(carry, p):
        x, a = carry
        x, da = _block_train(cfg, p, x, angles, mesh, sharder)
        return (x, a + da), None

    wrapped = _remat(cfg, body)
    (x, aux), _ = jax.lax.scan(wrapped, (x, aux), blocks_slice)
    return x, aux


def block_group_prefill(
    cfg: ModelConfig, blocks_slice: Params, cache_slice: Params, x, angles, sharder=None
):
    """Prefill over one layer-group slice: fills the group's stacked cache
    slice.  Returns ``(x, new_cache_slice)``."""

    def body(x, pc):
        p, cache = pc
        if sharder is not None:
            p = sharder.block(p)
        h = layers.norm_apply(p["ln1"], x, cfg.norm_type)
        h, new_cache = attention.attention_prefill(cfg, p["attn"], h, angles, cache)
        x = x + h
        h = layers.norm_apply(p["ln2"], x, cfg.norm_type)
        if "moe" in p:
            h, _ = moe.moe_dispatch(cfg, p["moe"], h)
        elif "mlp" in p:
            h = layers.mlp_apply(p["mlp"], h, cfg.mlp_type)
        else:
            h = jnp.zeros_like(h)
        x = x + h
        if sharder is not None:
            x = sharder.acts(x)
        return x, new_cache

    return jax.lax.scan(body, x, (blocks_slice, cache_slice))


def block_group_decode(
    cfg: ModelConfig, blocks_slice: Params, cache_slice: Params, x, angles, pos, sharder=None
):
    """One decode step over one layer-group slice.  Returns
    ``(x, new_cache_slice)`` — the same per-layer body as
    :func:`decode_step`'s uniform branch."""

    def body(x, pc):
        p, cache = pc
        if sharder is not None:
            p = sharder.block(p)
        x, nc = _block_decode(cfg, "attn", p, x, angles, cache, pos)
        if sharder is not None:
            x = sharder.acts(x)
        return x, nc

    return jax.lax.scan(body, x, (blocks_slice, cache_slice))


def hetero_group_train(
    cfg: ModelConfig, kinds, group: Params, x, aux, angles, sharder=None
):
    """Forward over one named-block group (unrolled layout / period-scan
    tails): ``kinds`` is the ``(name, block_kind)`` sequence in layer
    order; ``group`` maps each name to its block params.  The exact
    unrolled body of :func:`forward_hidden`, entered mid-stack.  Returns
    ``(x, aux)``."""
    for name, kind in kinds:
        p = group[name]
        if sharder is not None:
            p = sharder.block(p, (name,))
        fn = _remat(cfg, functools.partial(_hetero_block_train, cfg, kind))
        x, _, a = fn(p, x, angles)
        aux = aux + a
        if sharder is not None:
            x = sharder.acts(x)
    return x, aux


def period_group_train(
    cfg: ModelConfig, periods_slice: Params, x, aux, angles, sharder=None
):
    """Forward over a slice of stacked period-units — the same period scan
    body as :func:`forward_hidden`, entered mid-stack (hetero blocks carry
    no MoE, so ``aux`` rides through unchanged, matching the monolithic
    path's discard).  Returns ``(x, aux)``."""
    period = cfg.scan_period

    def period_body(x, pos_params):
        for k in range(period):
            pk = pos_params[f"pos_{k}"]
            if sharder is not None:
                pk = sharder.block(pk, ("periods", f"pos_{k}"))
            fn = _remat(
                cfg, functools.partial(_hetero_block_train, cfg, cfg.block_kind(k))
            )
            x, _, _ = fn(pk, x, angles)
            if sharder is not None:
                x = sharder.acts(x)
        return x, None

    x, _ = jax.lax.scan(period_body, x, periods_slice)
    return x, aux


def block_decode_pre_moe(
    cfg: ModelConfig, blocks_slice: Params, cache_slice: Params, x, angles, pos,
    sharder=None,
):
    """First half of ONE MoE layer's decode step, stopping right before the
    routed FFN: attention + residual + pre-MoE norm + router.  ``blocks_slice``
    is the layer's stacked non-expert group (leading axis 1: norms,
    attention, ``moe.router`` — no expert tensors).  Returns
    ``(x_attn, h2, top_w, top_i, new_cache_slice)``: the caller fetches the
    routed experts' groups and finishes with :func:`repro.models.moe.decode_apply`
    (``x = x_attn + y``)."""
    p = jax.tree.map(lambda a: a[0], blocks_slice)
    cache = jax.tree.map(lambda a: a[0], cache_slice)
    if sharder is not None:
        p = sharder.block(p)
    h = layers.norm_apply(p["ln1"], x, cfg.norm_type)
    h, new_cache = attention.attention_decode(cfg, p["attn"], h, angles, cache, pos)
    x = x + h
    h2 = layers.norm_apply(p["ln2"], x, cfg.norm_type)
    top_w, top_i = moe.decode_route(cfg, p["moe"], h2)
    new_cache = jax.tree.map(lambda a: a[None], new_cache)
    return x, h2, top_w, top_i, new_cache


def head_stage_logits(cfg: ModelConfig, group: Params, x) -> jax.Array:
    """Head-group logits from trunk hidden states.  ``group`` holds
    ``ln_f`` + the head weights (tied/codebook archs: the embed table —
    the plan's head *fetch* group carries it)."""
    return _head(cfg, group, x)


def head_stage_loss(
    cfg: ModelConfig, group: Params, x, aux, batch: dict
) -> tuple[jax.Array, dict]:
    """Head-group loss from precomputed trunk hidden states: the same
    (optionally seq-chunked) CE as :func:`lm_loss`, with the accumulated
    MoE ``aux`` carried in from the layer-group stages."""
    targets = batch["targets"]
    if cfg.vision_embed and "vision_embeds" in batch:
        s_img = batch["vision_embeds"].shape[1]
        pad = jnp.full(targets.shape[:1] + (s_img,), IGNORE_INDEX, targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)

    s = targets.shape[-1]
    c = cfg.loss_chunk
    if not c or s <= c or s % c != 0:
        logits = _head(cfg, group, x)
        ce, n = cross_entropy(logits, targets)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux, "n_tokens": n}

    nb = s // c
    xs = jnp.moveaxis(x.reshape(x.shape[0], nb, c, x.shape[-1]), 1, 0)
    ts = jnp.moveaxis(targets.reshape(*targets.shape[:-1], nb, c), -2, 0)

    @jax.checkpoint
    def chunk(xc, tc):
        logits = _head(cfg, group, xc)
        return cross_entropy_sum(logits, tc)

    def body(carry, args):
        tot, n = carry
        nll, nv = chunk(*args)
        return (tot + nll, n + nv), None

    (tot, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xs, ts)
    )
    n = jnp.maximum(n, 1)
    ce = tot / n
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "n_tokens": n}


def init_cache_group(
    cfg: ModelConfig, n_layers: int, batch: int, seq_len: int, dtype=jnp.bfloat16
) -> Params:
    """Stacked decode-cache slice for ``n_layers`` uniform attention layers
    (the per-group analogue of :func:`init_caches`)."""
    cl = cfg.cache_len(seq_len)
    return _stack_tree(n_layers, attention.init_cache(cfg, batch, cl, dtype))


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, targets: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mean CE over non-ignored targets.  Returns (loss, n_valid).

    Sharding-friendly: the gold-logit gather is an iota-compare masked
    reduction (not ``take_along_axis``), so a vocab-sharded logits tensor
    stays sharded — GSPMD reduces partials instead of all-gathering the
    full (B, S, V) tensor (measured: 67 GiB/dev -> in-budget on olmo-1b
    train_4k; see EXPERIMENTS.md §Dry-run).
    """
    lf = logits.astype(jnp.float32)
    valid = targets != IGNORE_INDEX
    tgt = jnp.where(valid, targets, 0)
    lse = jax.nn.logsumexp(lf, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(iota == tgt[..., None], lf, 0.0), axis=-1)
    nll = (lse - gold) * valid
    n = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / n, n


def cross_entropy_sum(logits: jax.Array, targets: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sum-form CE (for chunked accumulation)."""
    lf = logits.astype(jnp.float32)
    valid = targets != IGNORE_INDEX
    tgt = jnp.where(valid, targets, 0)
    lse = jax.nn.logsumexp(lf, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(iota == tgt[..., None], lf, 0.0), axis=-1)
    return jnp.sum((lse - gold) * valid), jnp.sum(valid)


def lm_loss(
    cfg: ModelConfig, params: Params, batch: dict, mesh=None, sharder=None
) -> tuple[jax.Array, dict]:
    targets = batch["targets"]
    if cfg.vision_embed and "vision_embeds" in batch:
        # vision prefix carries no LM targets
        s_img = batch["vision_embeds"].shape[1]
        pad = jnp.full(targets.shape[:1] + (s_img,), IGNORE_INDEX, targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)

    s = targets.shape[-1]
    c = cfg.loss_chunk
    if not c or s <= c or s % c != 0:
        logits, aux = forward_train(cfg, params, batch, mesh, sharder)
        ce, n = cross_entropy(logits, targets)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux, "n_tokens": n}

    # seq-chunked loss: the (B, S, V) logits tensor is never materialized —
    # each chunk's logits are (re)computed inside a remat'd scan body
    # (measured: minitron-4b train_4k 20.7 -> in-budget; V=256000 logits are
    # the dominant temp for big-vocab archs).
    x, aux = forward_hidden(cfg, params, batch, mesh, sharder)
    nb = s // c
    xs = jnp.moveaxis(x.reshape(x.shape[0], nb, c, x.shape[-1]), 1, 0)
    ts = jnp.moveaxis(
        targets.reshape(*targets.shape[:-1], nb, c), -2, 0
    )  # (nb, ..., c)

    @jax.checkpoint
    def chunk(xc, tc):
        logits = _head(cfg, params, xc)
        return cross_entropy_sum(logits, tc)

    def body(carry, args):
        tot, n = carry
        nll, nv = chunk(*args)
        return (tot + nll, n + nv), None

    (tot, n), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xs, ts))
    n = jnp.maximum(n, 1)
    ce = tot / n
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "n_tokens": n}

"""Deterministic synthetic LM batches: ``step index -> batch``, stateless.

Statelessness is a fault-tolerance property: after restart-from-checkpoint
at step S, batch S+1 is bit-identical to the batch the failed run would have
produced, so loss curves are reproducible across failures (tested).

The generator is a structured-random LM task (Zipf-ish marginals + a
copy/induction pattern) so small models show a real, monotonically
decreasing loss in the examples rather than memorizing uniform noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import IGNORE_INDEX


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    induction_period: int = 16  # tokens repeat with this period (learnable)


def synthetic_batch(cfg: ModelConfig, sc: SyntheticConfig, step: int) -> dict:
    """Batch for ``step`` (pure function of (seed, step))."""
    key = jax.random.fold_in(jax.random.PRNGKey(sc.seed), step)
    k1, k2 = jax.random.split(key)
    b, s, v = sc.global_batch, sc.seq_len, sc.vocab_size
    base = jax.random.randint(k1, (b, sc.induction_period), 1, v)
    reps = (s + 2 * sc.induction_period - 1) // sc.induction_period
    seq = jnp.tile(base, (1, reps))[:, : s + 1]
    noise = jax.random.bernoulli(k2, 0.1, seq.shape)
    seq = jnp.where(noise, jax.random.randint(k2, seq.shape, 1, v), seq)
    tokens, targets = seq[:, :-1], seq[:, 1:]

    if cfg.n_codebooks:
        nq = cfg.n_codebooks
        return {
            "codes": jnp.broadcast_to(tokens[:, None] % cfg.vocab_size, (b, nq, s)),
            "targets": jnp.broadcast_to(targets[:, None] % cfg.vocab_size, (b, nq, s)),
        }
    batch = {"tokens": tokens, "targets": targets}
    if cfg.vision_embed:
        s_img = max(s // 8, 1)
        kv = jax.random.fold_in(key, 7)
        batch["vision_embeds"] = (
            jax.random.normal(kv, (b, s_img, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.compute_dtype)
        pad = jnp.full((b, s_img), IGNORE_INDEX, targets.dtype)
        # vision prefix: model input is [vision, tokens]; loss ignores prefix
    if cfg.pos_type == "mrope":
        s_img = batch["vision_embeds"].shape[1] if cfg.vision_embed else 0
        pos = jnp.arange(s + s_img)[None].astype(jnp.int32)
        batch["positions_3d"] = jnp.broadcast_to(pos[:, None], (b, 3, s + s_img))
    return batch

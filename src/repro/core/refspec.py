"""Offload argument annotations: pass-by-reference + prefetch specs (paper §3.1).

The paper's kernel annotation is::

    @offload(prefetch={a: {buffer_size:10, elements_per_prefetch:2,
                           distance:10, access:'ro'}})
    def mykernel(a, b): ...

``PrefetchSpec`` carries exactly those five fields; ``OffloadRef`` binds a
kernel argument to a memory kind + optional prefetch spec.  These are pure
declarations — ``repro.core.offload`` and the two streaming engines interpret
them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from jax.sharding import PartitionSpec

from repro.core import memkind as mk

__all__ = ["Access", "PrefetchSpec", "OffloadRef", "AUTO"]

#: sentinel for runtime-tuned prefetch distance (engine.AdaptiveDistance)
AUTO = "auto"


class Access:
    READ_ONLY = "ro"
    READ_WRITE = "rw"


@dataclasses.dataclass(frozen=True)
class PrefetchSpec:
    """Paper §3.1: ``prefetch={variable, buffer_size, elements_per_prefetch,
    distance, access_modifier}``.

    Units here are *chunks* of the streamed leading axis (layers for weight
    streaming, blocks/rows for data streaming):

    buffer_size
        number of chunks resident device-side at once (ring depth).
    elements_per_fetch
        chunks moved per transfer — paper: "retrieves multiple pieces of data
        on each access [so] the overall number of data accesses is
        significantly lower".
    distance
        how many chunks ahead transfers are issued.  ``0`` degenerates to the
        paper's *on-demand* mode (synchronous fetch at use time).
        ``"auto"`` defers the choice to the runtime: the host-stream engine
        adapts the window from observed stalls
        (:class:`repro.core.engine.AdaptiveDistance`); the compiled graph
        engine resolves it to a static head start at trace time.
    access
        ``'ro'`` — no write-back; ``'rw'`` — written chunks are copied back to
        the home memory kind (atomically per chunk, in order per device).
    """

    buffer_size: int = 2
    elements_per_fetch: int = 1
    distance: Union[int, str] = 1
    access: str = Access.READ_ONLY

    def __post_init__(self) -> None:
        if self.buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        if self.elements_per_fetch < 1:
            raise ValueError("elements_per_fetch must be >= 1")
        if isinstance(self.distance, str):
            if self.distance != AUTO:
                raise ValueError(f"distance must be an int >= 0 or 'auto', got {self.distance!r}")
        elif self.distance < 0:
            raise ValueError("distance must be >= 0")
        if self.access not in (Access.READ_ONLY, Access.READ_WRITE):
            raise ValueError(f"access must be 'ro' or 'rw', got {self.access!r}")
        if not self.is_auto and self.distance >= self.buffer_size + self.elements_per_fetch:
            raise ValueError(
                "distance must be < buffer_size + elements_per_fetch "
                f"(got distance={self.distance}, buffer_size={self.buffer_size})"
            )

    @property
    def is_auto(self) -> bool:
        return self.distance == AUTO

    @property
    def on_demand(self) -> bool:
        return self.distance == 0

    def numeric_distance(self, default: int = 1) -> int:
        """The static distance, with ``"auto"`` resolved to ``default``."""
        return default if self.is_auto else int(self.distance)


ON_DEMAND = PrefetchSpec(buffer_size=1, elements_per_fetch=1, distance=0)


@dataclasses.dataclass(frozen=True)
class OffloadRef:
    """Binds one kernel argument to a hierarchy level.

    The argument is passed to the device *by reference*: the kernel sees the
    data, but physically only chunk-sized pieces ever occupy device memory
    when ``kind`` is a host kind and ``prefetch`` streaming is active.
    """

    kind: mk.MemKind = mk.DEVICE
    spec: PartitionSpec = PartitionSpec()
    prefetch: Optional[PrefetchSpec] = None
    #: leading axis that streaming chunks (None = bulk transfer, paper "eager")
    stream_axis: Optional[int] = 0

    def __post_init__(self) -> None:
        if self.prefetch is not None and self.kind.jax_kind == "device":
            raise ValueError(
                "prefetch streaming only applies to host-resident arguments; "
                "device-kind arguments are already at the fast tier"
            )

    @property
    def streamed(self) -> bool:
        return self.prefetch is not None and self.stream_axis is not None

"""Mixture-of-Experts: top-k routing with two execution strategies.

``dispatch``  — GShard/Switch-style grouped one-hot dispatch einsum.  Simple,
               GSPMD-shards cleanly (experts over the model axis, groups over
               data), but pays a dispatch-einsum FLOP overhead proportional to
               the group size (measured by the MODEL_FLOPS/HLO_FLOPS ratio in
               the roofline table — this is the paper-analogue "eager" shape
               of the computation).
``sorted_ep`` — shard_map expert parallelism: tokens replicated over the
               model axis, each model-rank scatters only the (token, k) pairs
               routed to its local experts into a capacity buffer, runs its
               experts, and the partial outputs are psum'd.  Removes the
               dispatch einsum; the optimized path for §Perf.

Both drop tokens beyond ``capacity_factor`` (standard TPU MoE), produce
identical routing decisions, and emit the standard auxiliary losses
(load-balance + router z-loss).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params


def init_moe(key: jax.Array, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p: Params = {
        "router": layers.fan_in_init(ks[0], (d, e), d),
        "wi": layers.fan_in_init(ks[1], (e, d, f), d),
        "wo": layers.fan_in_init(ks[2], (e, f, d), f),
    }
    if cfg.mlp_type in layers.GATED:
        p["wg"] = layers.fan_in_init(ks[3], (e, d, f), d)
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(cfg.moe_top_k * tokens_per_group * cfg.capacity_factor / cfg.n_experts)
    return max(c, 1)


def _router(cfg: ModelConfig, p: Params, x: jax.Array):
    """Common routing: returns (top_w, top_i, probs, aux_losses).

    x: (..., D).  Routing in f32 for numerical stability.
    """
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.moe_top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # load-balance loss (Switch eq. 4): E * sum_e fraction_e * prob_e
    e = cfg.n_experts
    frac = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=tuple(range(top_i.ndim - 1)))
    pmean = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = e * jnp.sum(frac * pmean)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    losses = cfg.router_aux_coef * aux + cfg.router_z_coef * z
    return top_w, top_i, probs, losses


def _expert_ffn(cfg: ModelConfig, p: Params, x: jax.Array, eqn_in: str, eqn_out: str) -> jax.Array:
    """Per-expert FFN on a buffer with a leading expert axis."""
    h = jnp.einsum(eqn_in, x, p["wi"].astype(x.dtype))
    h = layers._act(h, cfg.mlp_type)
    if cfg.mlp_type in layers.GATED:
        h = h * jnp.einsum(eqn_in, x, p["wg"].astype(x.dtype))
    return jnp.einsum(eqn_out, h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# router-first decode (route-aware expert streaming)
# ---------------------------------------------------------------------------

def decode_route(cfg: ModelConfig, p: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Routing only: (top_w, top_i) for the decode token(s).  Needs just
    ``p["router"]`` — the weight-stream decode path runs this *before* any
    expert weights are on device, so the engine can fetch only the routed
    experts' groups (aux losses are decode-irrelevant and dropped)."""
    top_w, top_i, _, _ = _router(cfg, {"router": p["router"]}, x)
    return top_w, top_i


def decode_apply(
    cfg: ModelConfig, stack: Params, top_w: jax.Array, top_i: jax.Array, x: jax.Array
) -> jax.Array:
    """Dense per-token expert FFN from precomputed routing.  ``stack``
    holds expert-stacked leaves ``{wi: (E', D, F), wo: (E', F, D), wg?}``
    where ``E'`` may be the full expert count or a fetched subset —
    ``top_i`` indexes ``stack``'s leading axis.  Gather-then-cast keeps the
    gathered rows bitwise-identical whether they come from the full stack
    or a routed subset, which is what makes route-aware streaming
    bitwise-equal to all-expert decode."""
    wi = jnp.take(stack["wi"], top_i, axis=0).astype(x.dtype)  # (..., K, D, F)
    h = jnp.einsum("...d,...kdf->...kf", x, wi)
    h = layers._act(h, cfg.mlp_type)
    if "wg" in stack:
        wg = jnp.take(stack["wg"], top_i, axis=0).astype(x.dtype)
        h = h * jnp.einsum("...d,...kdf->...kf", x, wg)
    wo = jnp.take(stack["wo"], top_i, axis=0).astype(x.dtype)  # (..., K, F, D)
    y = jnp.einsum("...kf,...kfd->...kd", h, wo)
    return jnp.sum(y * top_w.astype(x.dtype)[..., None], axis=-2)


def moe_decode(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """One decode step's MoE FFN: router-first routing + dense top-k gather
    (no capacity buffer, no token drops — every routed pair computes).
    The monolithic decode path and the streamed route-aware path both run
    this math, so splitting experts into their own fetch groups never
    changes what is computed."""
    top_w, top_i = decode_route(cfg, p, x)
    stack = {n: p[n] for n in ("wi", "wo", "wg") if n in p}
    return decode_apply(cfg, stack, top_w, top_i, x)


# ---------------------------------------------------------------------------
# strategy 1: GShard dispatch einsum (baseline)
# ---------------------------------------------------------------------------

def moe_dispatch(cfg: ModelConfig, p: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    Tokens are grouped as (batch row x ``moe_group_size`` contiguous seq
    chunk); chunks are processed by a ``lax.scan`` over the *sequence* axis
    (unsharded), so peak dispatch memory is one chunk's ``(B_local, gs, E, C)``
    combine tensor regardless of sequence length, while the batch dim stays
    sharded over data.  Capacity positions are assigned in GShard order
    (flattened (token, choice) cumsum per expert) and written with a scatter
    instead of materializing the (gs*K, E, C) one-hot product.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    gs = min(cfg.moe_group_size, s)
    assert s % gs == 0, f"seq {s} % group {gs} != 0"
    n_chunks = s // gs
    c = _capacity(cfg, gs)

    xc = x.reshape(b, n_chunks, gs, d)
    top_w, top_i, _, aux = _router(cfg, p, xc)  # (B, n_chunks, gs, K)

    tok_of = jnp.repeat(jnp.arange(gs), k)  # (gs*K,)

    def one_group(xg, tw, ti):
        """xg: (gs, D); tw/ti: (gs, K) -> (gs, D)."""
        ti_flat = ti.reshape(gs * k)
        e_oh = jax.nn.one_hot(ti_flat, e, dtype=jnp.float32)  # (gs*K, E)
        pos = jnp.sum((jnp.cumsum(e_oh, axis=0) - 1.0) * e_oh, axis=-1)  # (gs*K,)
        within = pos < c
        w = tw.reshape(gs * k) * within
        combine = (
            jnp.zeros((gs, e, c), jnp.float32)
            .at[tok_of, ti_flat, jnp.minimum(pos.astype(jnp.int32), c - 1)]
            .add(w)
        )
        dispatch = (combine > 0.0).astype(xg.dtype)
        ex_in = jnp.einsum("sec,sd->ecd", dispatch, xg)
        ex_out = _expert_ffn(cfg, p, ex_in, "ecd,edf->ecf", "ecf,efd->ecd")
        return jnp.einsum("sec,ecd->sd", combine.astype(xg.dtype), ex_out)

    @jax.checkpoint
    def chunk_apply(xg, tw, ti):
        return jax.vmap(one_group)(xg, tw, ti)

    def chunk_body(_, args):
        # remat the chunk: the inner scan's backward otherwise saves every
        # chunk's (B, gs, E, C) dispatch tensors (measured 158 GiB/dev on
        # qwen3-moe train_4k) — recomputing them bounds live memory to one
        # chunk.
        xg, tw, ti = args  # (B, gs, D), (B, gs, K), (B, gs, K)
        y = chunk_apply(xg, tw, ti)
        return None, y

    if n_chunks == 1:
        _, y = chunk_body(None, (xc[:, 0], top_w[:, 0], top_i[:, 0]))
        y = y[:, None]
    else:
        _, y = jax.lax.scan(
            chunk_body,
            None,
            (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(top_w, 1, 0), jnp.moveaxis(top_i, 1, 0)),
        )
        y = jnp.moveaxis(y, 0, 1)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# strategy 2: sorted capacity-scatter expert parallelism (optimized)
# ---------------------------------------------------------------------------

def _moe_sorted_local(cfg: ModelConfig, p_local: Params, x: jax.Array,
                      e_local: int, e_offset: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Token-sorted capacity scatter over the ``e_local`` experts owned by
    this shard.  x: (T, D) — this rank's *replicated* view of the tokens;
    returns this rank's partial output (psum'd by the caller)."""
    t, d = x.shape
    k = cfg.moe_top_k
    top_w, top_i, _, aux = _router(cfg, {"router": p_local["router"], }, x)

    flat_i = top_i.reshape(-1)  # (T*K,) global expert ids
    flat_w = top_w.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(t), k)

    local = (flat_i >= e_offset) & (flat_i < e_offset + e_local)
    lexp = jnp.where(local, flat_i - e_offset, e_local)  # e_local = overflow bin

    # rank of each (token, choice) within its local expert, in index order
    order = jnp.argsort(lexp, stable=True)
    sorted_e = lexp[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e_local + 1))
    rank_sorted = jnp.arange(t * k) - seg_start[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    cap = _capacity(cfg, t)
    keep = local & (rank < cap)
    slot = jnp.where(keep, lexp * cap + rank, e_local * cap)  # overflow slot

    # scatter tokens into the capacity buffer (+1 overflow row, dropped)
    buf = jnp.zeros((e_local * cap + 1, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], x[tok_of], 0).astype(x.dtype))
    ex_in = buf[: e_local * cap].reshape(e_local, cap, d)

    ex_out = _expert_ffn(cfg, p_local, ex_in, "ecd,edf->ecf", "ecf,efd->ecd")

    # gather back + weighted combine
    flat_out = ex_out.reshape(e_local * cap, d)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((1, d), x.dtype)], axis=0)
    per_choice = flat_out[slot] * (flat_w * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[tok_of].add(per_choice)
    return y, aux


def moe_sorted_ep(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    mesh: jax.sharding.Mesh,
    data_axes: tuple[str, ...] = ("data",),
    model_axis: str = "model",
) -> tuple[jax.Array, jax.Array]:
    """shard_map expert parallelism.  Tokens are sharded over ``data_axes``
    and replicated over ``model_axis``; experts are partitioned over
    ``model_axis``; partial outputs are psum'd over ``model_axis``."""
    b, s, d = x.shape
    e = cfg.n_experts
    m = mesh.shape[model_axis]
    assert e % m == 0, f"experts {e} must divide model axis {m} for sorted_ep"
    e_local = e // m

    gated = "wg" in p

    def body(xb, router, wi, wo, *rest):
        midx = jax.lax.axis_index(model_axis)
        p_local = {"router": router, "wi": wi, "wo": wo}
        if gated:
            p_local["wg"] = rest[0]
        t = xb.shape[0] * xb.shape[1]
        y, aux = _moe_sorted_local(cfg, p_local, xb.reshape(t, d), e_local, midx * e_local)
        y = jax.lax.psum(y, model_axis)
        aux = jax.lax.pmean(aux, (*data_axes, model_axis))
        return y.reshape(xb.shape), aux

    specs_in = [
        P(data_axes, None, None),  # x: tokens over data, replicated over model
        P(None, None),  # router replicated
        P(model_axis, None, None),  # wi: experts over model
        P(model_axis, None, None),  # wo
    ]
    args = [x, p["router"], p["wi"], p["wo"]]
    if gated:
        specs_in.append(P(model_axis, None, None))
        args.append(p["wg"])
    out_specs = (P(data_axes, None, None), P())
    fn = jax.shard_map(
        body, mesh=mesh, in_specs=tuple(specs_in), out_specs=out_specs, check_vma=False
    )
    y, aux = fn(*args)
    return y, aux

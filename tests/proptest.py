"""Property-test shim: hypothesis when installed, deterministic grid otherwise.

``hypothesis`` is an optional ``test`` extra (see pyproject.toml); the tier-1
suite must collect and pass without it.  When it is missing, ``given`` runs
the property over a small deterministic grid (strategy boundary values plus
midpoints) instead of randomized examples — weaker search, same invariants,
zero extra dependencies.

Usage in test modules::

    from proptest import given, settings, strategies as hst
"""
from __future__ import annotations

import itertools

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A fixed sample set standing in for a hypothesis strategy."""

        def __init__(self, samples):
            self.samples = list(samples)

    class strategies:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            picks = {min_value, min_value + 1, 0, 1, mid, max_value - 1, max_value}
            return _Strategy(
                sorted(v for v in picks if min_value <= v <= max_value)
            )

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    def settings(**_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # NOTE: deliberately not functools.wraps — pytest must see a
            # zero-argument function, not the strategy parameters (it would
            # otherwise look for fixtures named after them).
            def run():
                if arg_strategies:
                    for combo in itertools.product(
                        *(s.samples for s in arg_strategies)
                    ):
                        fn(*combo)
                else:
                    names = list(kw_strategies)
                    for combo in itertools.product(
                        *(kw_strategies[n].samples for n in names)
                    ):
                        fn(**dict(zip(names, combo)))

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run

        return deco

"""Tolerance layer for JAX API differences across the versions this repo
meets in the wild (container CPU builds vs current TPU releases).

Centralises every version-sensitive call site so the rest of the codebase
uses one spelling:

* ``make_mesh`` — ``axis_types=(AxisType.Auto, ...)`` exists only on newer
  JAX; older builds take no ``axis_types`` argument (and have no explicit
  auto/manual axis distinction, which is the same default).
* ``tpu_compiler_params`` — ``pltpu.CompilerParams`` was renamed from
  ``pltpu.TPUCompilerParams``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

__all__ = ["make_mesh", "tpu_compiler_params"]


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence] = None,
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with ``AxisType.Auto`` axes when supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes,
                axis_names,
                devices=devices,
                axis_types=(axis_type.Auto,) * len(axis_names),
            )
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def tpu_compiler_params(**kwargs):
    """Construct Pallas-TPU compiler params under either class name."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)

"""Gradient compression for the cross-pod (DCN) all-reduce.

At 2+ pods the gradient all-reduce crosses data-center network, which is
~10-25x slower than ICI — compressing that traffic is a standard
distributed-optimization trick.  Two codecs:

  * ``bf16``  — cast f32 grads to bf16 for the reduce (2x), no state.
  * ``int8``  — per-leaf max-abs scaling to int8 (4x) with **error
    feedback**: the quantization residual is carried and added to the next
    step's gradient, which keeps SGD/Adam convergence (Karimireddy et al.).

Codecs are value-level (jit-compatible); the explicit cross-pod psum wiring
lives in the shard_map training variant.  Property tests check
``decode(encode(g)) + error == g`` exactly for the tracked residual.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


def compress_bf16(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def init_error_state(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_int8(
    grads: Pytree, error: Optional[Pytree] = None
) -> tuple[Pytree, Pytree, Pytree]:
    """Returns (int8 payload, scales, new error state)."""

    def leaf(g, e):
        gf = g.astype(jnp.float32) + (e if e is not None else 0.0)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        err = gf - q.astype(jnp.float32) * scale
        return q, scale, err

    if error is None:
        error = jax.tree.map(lambda _: None, grads, is_leaf=lambda x: x is None)
        flat_e = [None] * len(jax.tree.leaves(grads))
    else:
        flat_e = jax.tree.leaves(error)
    flat_g, treedef = jax.tree.flatten(grads)
    qs, scales, errs = zip(*(leaf(g, e) for g, e in zip(flat_g, flat_e)))
    return (
        treedef.unflatten(list(qs)),
        treedef.unflatten(list(scales)),
        treedef.unflatten(list(errs)),
    )


def decompress_int8(payload: Pytree, scales: Pytree) -> Pytree:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, payload, scales
    )


def pod_allreduce_int8(grads: Pytree, axis: str, error: Pytree) -> tuple[Pytree, Pytree]:
    """int8-compressed psum over ``axis`` (use under shard_map).

    Each pod contributes int8; the sum happens in int32 (no overflow for
    <= 2^23 pods) and is rescaled by the max scale (conservative)."""
    q, scales, err = compress_int8(grads, error)
    summed = jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.int32), axis), q
    )
    n = jax.lax.psum(1, axis)
    max_scale = jax.tree.map(lambda s: jax.lax.pmax(s, axis), scales)
    out = jax.tree.map(
        lambda si, s: si.astype(jnp.float32) * s / n, summed, max_scale
    )
    return out, err

"""Sharding-rule tests: divisibility safety, plan modes, small-mesh
integration (2/4 CPU devices via a subprocess would be needed for >1 device;
here we verify rule outputs + a 1-device end-to-end jit)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import jaxcompat
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import transformer
from repro.parallel import sharding as sh
from repro.train import steps as st


class _FakeMesh:
    """Shape-only mesh stand-in for rule tests (no devices needed)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def _plan(mode="train", multi_pod=False):
    shape = {"pod": 2, "data": 16, "model": 16} if multi_pod else {"data": 16, "model": 16}
    return sh.ShardingPlan(mesh=_FakeMesh(shape), mode=mode,
                           pod_axis="pod" if multi_pod else None)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisibility(arch):
    """Every sharded dim must be divisible by its mesh axes product."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: transformer.init_model(jax.random.PRNGKey(0), cfg))
    plan = _plan()
    specs = sh.param_specs(plan, params)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            n = int(np.prod([plan.mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % n == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["qwen2-vl-72b", "qwen3-moe-235b-a22b", "internlm2-20b"])
def test_param_bytes_fit_hbm_train(arch):
    """FSDP plan: params+optimizer state per device must be << 16 GiB."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: st.init_train_state(jax.random.PRNGKey(0), cfg))
    plan = _plan()
    p_specs = sh.param_specs(plan, params[0])
    total = 0
    flat_p = jax.tree.leaves(params[0])
    flat_s = jax.tree.leaves(p_specs, is_leaf=lambda s: isinstance(s, P))
    for leaf, spec in zip(flat_p, flat_s):
        elems = int(np.prod(leaf.shape)) if leaf.shape else 1
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            elems //= int(np.prod([plan.mesh.shape[a] for a in axes]))
        total += elems * leaf.dtype.itemsize
    # bf16 params sharded; x7 for f32 master+m+v = optimizer state
    assert total * 7 < 14 * 2 ** 30, f"{arch}: {total*7/2**30:.1f} GiB state"


def test_serve_plan_no_fsdp_on_dense():
    cfg = get_config("internlm2-20b")
    params = jax.eval_shape(lambda: transformer.init_model(jax.random.PRNGKey(0), cfg))
    specs_t = sh.param_specs(_plan("train"), params)
    specs_s = sh.param_specs(_plan("serve"), params)
    wq_t = specs_t["blocks"]["attn"]["wq"]
    wq_s = specs_s["blocks"]["attn"]["wq"]
    assert "data" in jax.tree.leaves(tuple(a for a in wq_t if a))  # fsdp in train
    assert all(a != "data" for a in jax.tree.leaves(tuple(a for a in wq_s if a)))


def test_serve_plan_moe_expert_fsdp():
    """qwen3 expert weights exceed HBM under pure TP: serve keeps data-axis
    sharding on MoE leaves only."""
    cfg = get_config("qwen3-moe-235b-a22b")
    params = jax.eval_shape(lambda: transformer.init_model(jax.random.PRNGKey(0), cfg))
    specs = sh.param_specs(_plan("serve"), params)
    wi = specs["blocks"]["moe"]["wi"]
    assert "model" in [a for a in wi if isinstance(a, str)]
    assert "data" in [a for a in wi if isinstance(a, str)]


def test_cache_specs_decode_seq_sharding():
    cfg = get_config("internlm2-20b")  # kv=8 does not divide model=16
    caches = jax.eval_shape(lambda: transformer.init_caches(cfg, 128, 1024))
    specs = sh.cache_specs_tree(_plan("serve"), caches, 128)
    k_spec = specs["k"]
    # stacked (L, B, T, K, H): batch->data, seq->model
    assert k_spec[1] == "data" and k_spec[2] == "model", k_spec


def test_cache_specs_kv_head_sharding_when_divisible():
    cfg = get_config("olmo-1b")  # kv=16 divides model=16
    caches = jax.eval_shape(lambda: transformer.init_caches(cfg, 128, 1024))
    specs = sh.cache_specs_tree(_plan("serve"), caches, 128)
    assert specs["k"][3] == "model", specs["k"]


def test_batch_specs_nondivisible_replicates():
    plan = _plan()
    batch = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}
    specs = sh.batch_specs(plan, batch, 1)  # long_500k: batch 1
    assert specs["tokens"] == P(None, None)


def test_multi_pod_batch_axes():
    plan = _plan(multi_pod=True)
    assert plan.batch_axes == ("pod", "data")
    batch = {"tokens": jax.ShapeDtypeStruct((512, 16), jnp.int32)}
    specs = sh.batch_specs(plan, batch, 512)
    assert specs["tokens"][0] == ("pod", "data")


def test_sharder_end_to_end_single_device():
    """Sharder-constrained train step runs on 1 CPU device (constraints are
    no-ops on a trivial mesh but the code path is exercised)."""
    cfg = get_smoke_config("olmo-1b")
    mesh = jaxcompat.make_mesh((1, 1), ("data", "model"))
    plan = sh.make_plan(mesh, "train")
    params, opt = st.init_train_state(jax.random.PRNGKey(0), cfg)
    sharder = sh.make_sharder(plan, params, 2, seq_len=16, seq_shard=True)
    from repro.optim.adamw import AdamWConfig

    step = st.make_train_step(cfg, AdamWConfig(), mesh, sharder)
    batch = {
        "tokens": jnp.ones((2, 16), jnp.int32),
        "targets": jnp.ones((2, 16), jnp.int32),
    }
    with mesh:
        p2, o2, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))

"""Weight-residency group cache tests (ISSUE 7 tentpole).

Pins the residency contract at unit and integration level:
  * ResidencyCache policy: LRU eviction order, pin protection, oversize
    refusal (cache unchanged), refresh-in-place, zero-capacity inertness,
    clear-on-failure semantics,
  * cached streamed train bitwise-equal to the UNCACHED streamed run (and
    hence to the device run) for every kind x distance 0/1/auto,
  * zero-slack budgets degenerate to the plain streaming schedule (every
    consumed group is a unique fetch — the pre-cache traffic, exactly),
  * writeback invalidation: after the group-wise optimizer update the
    cached device copies equal the re-homed bytes (no stale weights),
  * serve steady state: with cache slack a session stops re-fetching the
    model every decode step; the budget validation rejects hot window +
    cache over budget with an actionable message,
  * tied-head embed dedupe: a resident embed group lends its table leaf
    to the head fetch instead of re-reading it over the link.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.refspec import PrefetchSpec
from repro.core.residency import ResidencyCache
from repro.core.weightstream import WeightStreamPlan
from repro.data.synthetic import SyntheticConfig, synthetic_batch
from repro.optim.adamw import AdamWConfig
from repro.train import steps as st


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_smoke_config("smollm-360m"), n_layers=4)


@pytest.fixture(scope="module")
def plan(cfg):
    return WeightStreamPlan(cfg, st.abstract_params(cfg), layers_per_group=2)


@pytest.fixture(scope="module")
def opt_cfg():
    return AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=32)


def _batch(cfg, step=0):
    return synthetic_batch(cfg, SyntheticConfig(cfg.vocab_size, 16, 2, seed=0), step)


def _t(n):
    """An n-byte uint8 tree."""
    return {"w": np.zeros(n, np.uint8)}


# ---------------------------------------------------------------------------
# cache policy units
# ---------------------------------------------------------------------------


def test_lru_evicts_least_recently_used_first():
    c = ResidencyCache(30)
    assert c.put("a", _t(10)) and c.put("b", _t(10)) and c.put("c", _t(10))
    c.lookup("a")  # a is now MRU; b is LRU
    assert c.put("d", _t(10))
    assert "b" not in c and set(c.keys()) == {"a", "c", "d"}
    assert c.evictions == 1
    assert c.resident_bytes == 30


def test_pinned_entries_survive_eviction_pressure():
    c = ResidencyCache(30)
    c.put("a", _t(10), pinned=True)
    c.put("b", _t(10))
    c.put("c", _t(10))
    assert c.put("d", _t(10))  # must evict b (LRU unpinned), never a
    assert "a" in c and "b" not in c


def test_oversize_put_refused_cache_unchanged():
    c = ResidencyCache(25)
    c.put("a", _t(10), pinned=True)
    c.put("b", _t(10))
    before = (set(c.keys()), c.resident_bytes)
    # 20 bytes cannot fit: only b (10) is evictable above the pin
    assert not c.put("big", _t(20))
    assert (set(c.keys()), c.resident_bytes) == before
    assert c.refusals == 1


def test_refresh_replaces_stale_value_and_keeps_pin():
    c = ResidencyCache(None)
    old = _t(8)
    new = {"w": np.ones(8, np.uint8)}
    c.put("a", old, pinned=True)
    assert c.refresh("a", new)
    got = c.lookup("a")
    np.testing.assert_array_equal(got["w"], new["w"])
    assert c.invalidations == 1
    # the pin survived the in-place refresh
    c.put("b", _t(4))
    assert c._entries["a"].pinned


def test_zero_capacity_cache_is_inert():
    c = ResidencyCache(0)
    assert not c.put("a", _t(1))
    assert c.lookup("a") is None
    assert len(c) == 0 and c.resident_bytes == 0
    assert c.hits == 0 and c.misses == 1


def test_clear_drops_everything_including_pins():
    c = ResidencyCache(None)
    c.put("a", _t(4), pinned=True)
    c.put("b", _t(4))
    c.clear()
    assert len(c) == 0 and c.resident_bytes == 0
    assert c.lookup("a") is None and c.lookup("b") is None


def test_unbounded_capacity_never_evicts():
    c = ResidencyCache(None)
    for i in range(64):
        assert c.put(f"k{i}", _t(1000))
    assert c.evictions == 0 and c.resident_bytes == 64_000
    assert c.peak_resident_bytes == 64_000


# ---------------------------------------------------------------------------
# cached vs uncached streamed train: bitwise across kind x distance
# ---------------------------------------------------------------------------


def _train(cfg, opt_cfg, plan, kind, residency, n=2, distance="auto", store=None):
    step = st.make_weight_streamed_train_step(
        cfg, opt_cfg, plan=plan, param_kind=kind, spill_store=store,
        prefetch=PrefetchSpec(buffer_size=plan.n_groups + 2, distance=distance),
        residency=residency,
    )
    state = st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    if kind == "disk_host":
        state = st.spill_weight_streamed_state(plan, state, store)
    losses = []
    try:
        for k in range(n):
            state, m = step(state, _batch(cfg, k))
            losses.append(float(m["loss"]))
    finally:
        stats = step.param_stats
        cache = step.residency
        step.close()
    return losses, state, stats, cache


def _assert_same_params(a_state, b_state):
    for key in a_state["params"]["groups"]:
        for a, b in zip(
            jax.tree.leaves(a_state["params"]["groups"][key]),
            jax.tree.leaves(b_state["params"]["groups"][key]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("distance", [0, 1, "auto"])
@pytest.mark.parametrize("kind", ["pinned_host", "disk_host"])
def test_cached_train_bitwise_equals_uncached(cfg, opt_cfg, plan, kind, distance):
    """The cache must change traffic, never values: a run with an unbounded
    cache is bitwise-identical to the same run with a disabled cache."""
    import tempfile

    from repro.core.spillstore import SpillStore

    def run(cap):
        if kind == "disk_host":
            with tempfile.TemporaryDirectory() as d:
                store = SpillStore(d, ephemeral=True)
                out = _train(
                    cfg, opt_cfg, plan, kind, ResidencyCache(cap),
                    distance=distance, store=store,
                )
                # drain disk leaves to numpy before the store closes
                state = out[1]
                state["params"]["groups"] = {
                    k: jax.tree.map(np.array, v)
                    for k, v in state["params"]["groups"].items()
                }
                store.close()
                return out
        return _train(
            cfg, opt_cfg, plan, kind, ResidencyCache(cap), distance=distance
        )

    u_losses, u_state, u_stats, _ = run(0)  # disabled cache = PR 5 schedule
    c_losses, c_state, c_stats, _ = run(None)  # unbounded cache
    assert c_losses == u_losses
    _assert_same_params(c_state, u_state)
    # the uncached run fetched every consumed group; the cached one did not
    assert u_stats.unique_group_fetches == u_stats.n_groups
    assert u_stats.cache_hits == 0
    assert c_stats.unique_group_fetches < c_stats.n_groups
    assert c_stats.cache_hits > 0


def test_zero_slack_budget_degenerates_to_plain_streaming(cfg, opt_cfg):
    """budget == the window peak -> residency_capacity_bytes() == 0 -> the
    default cache is inert and the schedule (and its traffic) is exactly
    the pre-cache one, still bitwise-correct."""
    abs_p = st.abstract_params(cfg)
    probe = WeightStreamPlan(cfg, abs_p, layers_per_group=2)
    tight = WeightStreamPlan(
        cfg, abs_p, layers_per_group=2,
        device_budget_mb=probe.peak_device_bytes(1) / 1e6,
    )
    assert tight.residency_capacity_bytes() == 0
    losses, state, stats, cache = _train(
        cfg, opt_cfg, tight, "pinned_host", None, distance=1
    )
    assert cache is not None and cache.capacity_bytes == 0
    assert stats.cache_hits == 0
    assert stats.unique_group_fetches == stats.n_groups > 0
    # and the degenerate run still trains identically to an uncached run
    slack = WeightStreamPlan(cfg, abs_p, layers_per_group=2)
    ref_losses, ref_state, _, _ = _train(
        cfg, opt_cfg, slack, "pinned_host", ResidencyCache(0), distance=1
    )
    assert losses == ref_losses
    _assert_same_params(state, ref_state)


def test_cached_groups_fresh_after_optimizer_update(cfg, opt_cfg, plan):
    """Writeback invalidation: after a step, every cached group equals its
    re-homed (post-update) bytes — training from the cache next step uses
    the NEW weights (the regression this PR's invalidation prevents)."""
    step = st.make_weight_streamed_train_step(
        cfg, opt_cfg, plan=plan, param_kind="pinned_host",
        prefetch=PrefetchSpec(buffer_size=plan.n_groups + 2, distance="auto"),
    )
    state = st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    try:
        state, _ = step(state, _batch(cfg, 0))
        cache = step.residency
        assert cache is not None and len(cache) > 0
        for key in cache.keys():
            cached = cache.peek(key)
            home = state["params"]["groups"][key]
            for a, b in zip(jax.tree.leaves(cached), jax.tree.leaves(home)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # nothing is pinned between steps (pins cover one turnaround only)
        assert cache.pinned_bytes == 0
        # and a second step from the (fresh) cache stays bitwise-correct
        state, m1 = step(state, _batch(cfg, 1))
    finally:
        step.close()
    ref_losses, ref_state, _, _ = _train(
        cfg, opt_cfg, plan, "pinned_host", ResidencyCache(0)
    )
    assert float(m1["loss"]) == ref_losses[1]
    _assert_same_params(state, ref_state)


def test_failed_step_clears_cache(cfg, opt_cfg, plan):
    """A step that dies mid-stream may leave refreshed-but-uncommitted
    groups — the cache must come back empty, not half-updated."""
    step = st.make_weight_streamed_train_step(
        cfg, opt_cfg, plan=plan, param_kind="pinned_host",
        prefetch=PrefetchSpec(buffer_size=plan.n_groups + 2, distance="auto"),
    )
    state = st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    try:
        state, _ = step(state, _batch(cfg, 0))
        assert len(step.residency) > 0
        bad = {"tokens": np.zeros((2, 16), np.int32), "boom": object()}
        with pytest.raises(Exception):
            step(state, bad)
        assert len(step.residency) == 0  # poisoned cache dropped outright
        # the next good step repopulates and still runs
        state, m = step(state, _batch(cfg, 1))
        assert np.isfinite(float(m["loss"]))
    finally:
        step.close()


def test_driver_restart_clears_stale_cache(cfg, opt_cfg, tmp_path):
    """A failure OUTSIDE the step (checkpoint commit, watchdog, injected
    pre-step fault) restores older state without tripping the step's own
    failure clear — the driver's restart hook must drop the cache or the
    replay streams post-failure device copies against pre-failure homes."""
    from repro.launch.train import build_trainer
    from repro.runtime.driver import DriverConfig
    from repro.runtime.elastic import elastic_local_mesh

    def losses(root, fail_at):
        d = build_trainer(
            cfg,
            elastic_local_mesh(model=1),
            global_batch=2,
            seq_len=16,
            opt_cfg=opt_cfg,
            driver_cfg=DriverConfig(
                total_steps=4, checkpoint_every=4,
                checkpoint_dir=str(root), log_every=0, max_restarts=1,
            ),
            fail_at=fail_at,
            param_kind="pinned_host",
            param_layers_per_group=2,
        )
        d.run()
        out = {}
        for h in d.history:  # later entries overwrite replayed steps
            out[h["step"]] = h["loss"]
        return out, d.restarts

    ref, _ = losses(tmp_path / "ref", None)
    # no checkpoint exists yet at step 2, so the restart re-inits from
    # scratch and replays 0..3 — stale cached groups would poison step 0
    got, restarts = losses(tmp_path / "chaos", {2})
    assert restarts == 1
    assert ref == got


# ---------------------------------------------------------------------------
# tied-head embed-table dedupe
# ---------------------------------------------------------------------------


def test_head_borrows_resident_embed_table(cfg, plan):
    params, _ = st.init_train_state(jax.random.PRNGKey(0), cfg)
    home = plan.init_home(params)
    assert plan.head_reads_embed
    cache = ResidencyCache(None)
    head = plan.groups[-1]

    # embed not resident: the head fetch reads the host table leaf
    fetch = plan.fetch_group(home, head, cache)
    assert isinstance(fetch["embed"]["tok"], np.ndarray)

    # embed resident: the head fetch borrows the DEVICE table (zero link
    # bytes for the table even though the head itself is a miss)
    embed_dev = jax.device_put(home["groups"][plan.groups[0].key])
    cache.put(plan.groups[0].key, embed_dev)
    fetch = plan.fetch_group(home, head, cache)
    assert fetch["embed"]["tok"] is embed_dev["embed"]["tok"]
    # the cached head entry never retains the borrowed table
    stored = plan.cache_home_tree(head, fetch)
    assert "embed" not in stored and set(stored) == set(plan.head_home_keys)


# ---------------------------------------------------------------------------
# serve: steady-state residency + budget validation
# ---------------------------------------------------------------------------


def test_serve_steady_state_stops_refetching(cfg):
    from repro.launch import serve as sv
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    ref = sv.serve(
        cfg, mesh, batch=2, prompt_len=12, gen=6, kv_kind="pinned_host",
        kv_page_len=4, seed=3, param_kind="device",
    )
    res = sv.serve(
        cfg, mesh, batch=2, prompt_len=12, gen=6, kv_kind="pinned_host",
        kv_page_len=4, seed=3, param_kind="pinned_host",
    )
    assert np.array_equal(res["generated"], ref["generated"])
    rc = res["param_residency"]
    assert rc is not None and rc["hits"] > 0
    # no budget -> unbounded cache -> after the first pass the model is
    # resident and decode steps issue ZERO weight fetches
    fetches = res["param_step_fetches"]
    assert fetches and all(f == 0 for f in fetches)
    # disabling the cache restores the per-step full re-fetch (the bug)
    res0 = sv.serve(
        cfg, mesh, batch=2, prompt_len=12, gen=6, kv_kind="pinned_host",
        kv_page_len=4, seed=3, param_kind="pinned_host", param_cache_mb=0.0,
    )
    assert np.array_equal(res0["generated"], ref["generated"])
    n_groups = res0["param_plan"].n_groups
    assert all(f == n_groups for f in res0["param_step_fetches"])


def test_serve_validates_hot_window_plus_cache_budget(cfg):
    from repro.launch import serve as sv
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    with pytest.raises(ValueError, match="param_cache_mb"):
        sv.ServeSession(
            cfg, mesh, slots=2, max_len=32, kv_kind="pinned_host",
            page_len=4, param_kind="pinned_host", device_budget_mb=0.3,
            param_cache_mb=100.0,
        )
    # a cache that fits is accepted and capped at the requested bytes
    with sv.ServeSession(
        cfg, mesh, slots=2, max_len=32, kv_kind="pinned_host",
        page_len=4, param_kind="pinned_host", device_budget_mb=5.0,
        param_cache_mb=0.5,
    ) as s:
        assert s.param_residency.capacity_bytes == int(0.5e6)

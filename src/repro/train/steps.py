"""Step functions: train / prefill / decode, built per (config, optimizer).

These are the functions the launcher jits with the sharding plan's
in/out-shardings and that the dry-run lowers for every (arch x shape x mesh)
cell.  All of them are pure: ``(state..., batch) -> (state..., outputs)``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.optim.adamw import AdamWConfig, adamw_update

Pytree = Any


def make_train_step(
    cfg: ModelConfig, opt_cfg: AdamWConfig, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree, dict]]:
    """``(params, opt_state, batch) -> (params, opt_state, metrics)``."""

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(transformer.lm_loss, argnums=1, has_aux=True)(
            cfg, params, batch, mesh, sharder
        )
        if sharder is not None:
            grads = sharder.grads(grads)  # ZeRO grad layout (see Sharder.grads)
        new_params, new_opt, om = adamw_update(
            opt_cfg, grads, opt_state, compute_dtype=cfg.compute_dtype
        )
        metrics = {"loss": loss, **aux, **om}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(
    cfg: ModelConfig, batch_size: int, seq_len: int, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree], tuple[jax.Array, Pytree]]:
    """``(params, batch) -> (last-token logits, caches)``.

    Caches are created inside the step (zeros) so the step's out-shardings
    place them; context length is the shape's ``seq_len``.
    """

    def prefill_step(params, batch):
        caches = transformer.init_caches(cfg, batch_size, seq_len, cfg.compute_dtype)
        return transformer.prefill(cfg, params, batch, caches, mesh, sharder)

    return prefill_step


def make_decode_step(
    cfg: ModelConfig, mesh=None, sharder=None
) -> Callable[[Pytree, Pytree, Pytree, jax.Array], tuple[jax.Array, Pytree]]:
    """``(params, caches, batch, pos) -> (logits, caches)`` — one new token
    against a populated decode state (KV cache / recurrent state)."""

    def decode_step(params, caches, batch, pos):
        return transformer.decode_step(cfg, params, batch, caches, pos, sharder)

    return decode_step


def init_train_state(
    key: jax.Array, cfg: ModelConfig
) -> tuple[Pytree, Pytree]:
    """(bf16 params, AdamW state with f32 master) for a fresh run."""
    from repro.optim.adamw import adamw_init

    params_f32 = transformer.init_model(key, cfg)
    opt_state = adamw_init(params_f32)
    params = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), params_f32)
    return params, opt_state


def abstract_train_state(cfg: ModelConfig) -> tuple[Pytree, Pytree]:
    """ShapeDtypeStruct pytrees of (params, opt_state) — no allocation."""
    def build():
        return init_train_state(jax.random.PRNGKey(0), cfg)

    return jax.eval_shape(build)


def abstract_params(cfg: ModelConfig) -> Pytree:
    def build():
        p = transformer.init_model(jax.random.PRNGKey(0), cfg)
        return jax.tree.map(lambda x: x.astype(cfg.compute_dtype), p)

    return jax.eval_shape(build)


def abstract_caches(cfg: ModelConfig, batch: int, seq_len: int) -> Pytree:
    return jax.eval_shape(
        lambda: transformer.init_caches(cfg, batch, seq_len, cfg.compute_dtype)
    )

"""Pure-jnp oracle for the first-order linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_recurrence_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t  over axis 1, h_0 = 0.

    a, b: (B, S, W) f32.  Returns h: (B, S, W) f32.  This is the RG-LRU
    training recurrence with the gates folded into (a, b) (see
    repro.models.rglru._gates).
    """

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(
        step,
        jnp.zeros((a.shape[0], a.shape[2]), a.dtype),
        (a.swapaxes(0, 1), b.swapaxes(0, 1)),
    )
    return hs.swapaxes(0, 1)

"""Weight-residency group cache: bytes already on the fast tier never
cross the link again.

The paper's pass-by-reference model says the host service moves *only the
data the computation needs*; the streamed-weights runtime violated that by
re-fetching groups it had just held — the backward pass re-fetched every
group the forward had landed moments earlier, and a serving session
re-fetched the whole model every decode step even when
``--device-budget-mb`` had slack.  :class:`ResidencyCache` closes that gap:
an LRU/pinned cache of **device-resident fetch groups**, scoped to one
transfer engine's consumers and sized to the budget slack above the
streaming window (see
:meth:`repro.core.weightstream.WeightStreamPlan.residency_capacity_bytes`).

A cached group is a pytree of committed ``jax.Array`` leaves.  Re-submitting
it through :meth:`repro.core.engine.TransferEngine.submit_group` costs ZERO
H2D requests — the engine's layouts pass ``jax.Array`` leaves through by
reference — so a hit is simply "hand the engine the cached tree" and every
downstream consumer (jitted stage programs, stats, shardings) is unchanged.

Three policies keep it correct:

pin / evict
    entries are LRU-ordered; :meth:`put` evicts least-recently-used
    *unpinned* entries until the new entry fits, and refuses (leaving the
    cache unchanged) when it cannot.  :meth:`pin` protects entries across a
    known turnaround — the streamed train step pins the last K layer groups
    between the forward and the reverse-order backward so the backward's
    first K fetches are hits.
budget accounting
    ``capacity_bytes`` is a hard byte ceiling (``None`` = unbounded, the
    no-budget case).  The owner sizes it so streamed window + cached bytes
    can never exceed the device budget; ``peak_resident_bytes`` is the
    observable the benches gate against.
writeback invalidation
    the streamed optimizer updates params group-wise, so any cached copy of
    an updated group is STALE the moment the update lands.  :meth:`refresh`
    replaces the entry in place with the post-update device tree (the same
    values the D2H drain writes to the home) — or, if the entry cannot be
    kept, guarantees it is gone.  A step that fails mid-update calls
    :meth:`clear`: a half-updated cache must never survive into a retry.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Iterable, Optional

import jax

from repro.core.schedcheck import HazardError, sanitize_enabled, tree_fingerprint

__all__ = ["ResidencyCache"]

Pytree = Any


def _tree_nbytes(tree: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


class _Entry:
    __slots__ = ("tree", "nbytes", "pinned")

    def __init__(self, tree: Pytree, nbytes: int, pinned: bool) -> None:
        self.tree = tree
        self.nbytes = nbytes
        self.pinned = pinned


class ResidencyCache:
    """LRU/pinned cache of device-resident weight fetch groups.

    Single-threaded by design: it is only touched from the compute thread
    (the executor's submit/apply path), never from the engine worker.
    """

    def __init__(
        self,
        capacity_bytes: Optional[int] = None,
        *,
        sanitize: Optional[bool] = None,
    ) -> None:
        if capacity_bytes is not None and capacity_bytes < 0:
            raise ValueError(f"capacity_bytes must be >= 0, got {capacity_bytes}")
        self.capacity_bytes = capacity_bytes
        #: hazard-sanitizer mode (``REPRO_SANITIZE=1`` when unset): record a
        #: fingerprint of each key's HOME tree at fetch time and raise
        #: :class:`~repro.core.schedcheck.HazardError` when a later hit
        #: would serve a device copy whose home has been swapped out from
        #: under it (restart / reshard without :meth:`clear`) — the
        #: stale-residency RAW the static analyzer checks per schedule
        self.sanitize = sanitize_enabled() if sanitize is None else bool(sanitize)
        self._home_marks: dict = {}  # key -> tree fingerprint at fetch
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self.resident_bytes = 0
        #: high-water mark of ``resident_bytes`` — the cache's term of the
        #: device-budget gate (streamed peak + this must stay <= budget)
        self.peak_resident_bytes = 0
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self.invalidations = 0
        #: puts refused because the entry could not fit (capacity minus
        #: pinned bytes) — the zero-slack degenerate case counts all here
        self.refusals = 0

    # ------------------------------------------------------------- queries
    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> Iterable[str]:
        return self._entries.keys()

    @property
    def pinned_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values() if e.pinned)

    def lookup(self, key: str) -> Optional[Pytree]:
        """The cached device tree, or None.  Counts a hit/miss and marks
        the entry most-recently-used."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return e.tree

    def peek(self, key: str) -> Optional[Pytree]:
        """Like :meth:`lookup` but without touching LRU order or counters —
        for leaf-level borrowing (the tied head's embed-table dedupe)."""
        e = self._entries.get(key)
        return e.tree if e is not None else None

    def sanitize_home(self, key: str, home_tree: Pytree, *, hit: bool) -> None:
        """Sanitizer check at a fetch decision point: on a miss, remember
        what ``key``'s home tree looks like; on a hit, assert the home is
        still the one the cached device copy was fetched from.  A mismatch
        means the home was rebound or mutated without invalidating the
        cache — the hit would silently serve stale weights."""
        if not self.sanitize:
            return
        mark = tree_fingerprint(home_tree)
        prev = self._home_marks.get(key)
        if hit and prev is not None and prev != mark:
            raise HazardError(
                f"sanitizer: stale residency RAW on group {key!r} — the "
                "host home changed since this device copy was cached "
                "(restart or reshard without ResidencyCache.clear()?); "
                "a cache hit would serve pre-change weights"
            )
        self._home_marks[key] = mark

    # ------------------------------------------------------------ mutation
    def _drop(self, key: str) -> None:
        e = self._entries.pop(key)
        self.resident_bytes -= e.nbytes
        self._home_marks.pop(key, None)

    def put(
        self,
        key: str,
        tree: Pytree,
        nbytes: Optional[int] = None,
        *,
        pinned: bool = False,
    ) -> bool:
        """Insert a landed device group.  Evicts LRU unpinned entries until
        it fits; returns False (cache unchanged) when it cannot.  A key
        already present is only touched (and its pin widened) — replacing
        live values is :meth:`refresh`'s job."""
        e = self._entries.get(key)
        if e is not None:
            self._entries.move_to_end(key)
            e.pinned = e.pinned or pinned
            return True
        if nbytes is None:
            nbytes = _tree_nbytes(tree)
        if self.capacity_bytes is not None:
            evictable = [
                k for k, v in self._entries.items() if not v.pinned
            ]  # LRU-first
            spare = self.capacity_bytes - self.resident_bytes
            i = 0
            while spare < nbytes and i < len(evictable):
                spare += self._entries[evictable[i]].nbytes
                i += 1
            if spare < nbytes:
                self.refusals += 1
                return False
            for k in evictable[:i]:
                self._drop(k)
                self.evictions += 1
        self._entries[key] = _Entry(tree, nbytes, pinned)
        self.resident_bytes += nbytes
        self.peak_resident_bytes = max(self.peak_resident_bytes, self.resident_bytes)
        self.insertions += 1
        return True

    def refresh(self, key: str, tree: Pytree, nbytes: Optional[int] = None) -> bool:
        """Writeback invalidation: the group's params were just updated, so
        a cached copy is stale.  Replace it in place with the post-update
        device tree (bitwise the values the D2H drain re-homes), or insert
        it if it fits; either way the cache never holds a stale ``key`` on
        return."""
        e = self._entries.get(key)
        if e is not None:
            pinned = e.pinned
            self._drop(key)
            self.invalidations += 1
            return self.put(key, tree, nbytes, pinned=pinned)
        return self.put(key, tree, nbytes)

    def invalidate(self, key: str) -> bool:
        e = self._entries.get(key)
        if e is None:
            return False
        self._drop(key)
        self.invalidations += 1
        return True

    def clear(self) -> None:
        """Drop everything (pins included) — a failed streamed step may
        have refreshed some groups but not committed the home update, and a
        half-updated cache must never feed the retried step."""
        n = len(self._entries)
        self._entries.clear()
        self._home_marks.clear()
        self.resident_bytes = 0
        self.invalidations += n

    # ------------------------------------------------------------- pinning
    def pin(self, key: str) -> bool:
        e = self._entries.get(key)
        if e is None:
            return False
        e.pinned = True
        return True

    def unpin_all(self) -> None:
        for e in self._entries.values():
            e.pinned = False

    # -------------------------------------------------------------- stats
    def counters(self) -> dict:
        return {
            "capacity_bytes": self.capacity_bytes,
            "resident_bytes": self.resident_bytes,
            "peak_resident_bytes": self.peak_resident_bytes,
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "insertions": self.insertions,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "refusals": self.refusals,
        }

    def __repr__(self) -> str:  # pragma: no cover - trivial
        cap = (
            "unbounded"
            if self.capacity_bytes is None
            else f"{self.capacity_bytes / 1e6:.1f}MB"
        )
        return (
            f"ResidencyCache({len(self._entries)} groups, "
            f"{self.resident_bytes / 1e6:.1f}MB resident, cap {cap})"
        )

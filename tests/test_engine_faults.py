"""Fault-injection tests for the transfer engine (three-level pipeline).

The paper's host service must stay correct when things go wrong mid-run:
a worker exception on one group must surface on that group's waiter and
leave the engine serviceable; a failed run's writeback tickets must never
drain into the next run; ``close()`` during in-flight prefetch (including
in-flight *disk* fetches) must drain cleanly and allow transparent
restart; and the adaptive-distance controllers must keep their learned
state across runs — including failed ones.

Every test body runs under a watchdog (daemon thread + join timeout), so
a deadlock fails the test instead of hanging the suite.

The whole module runs twice: once plain and once under ``REPRO_SANITIZE=1``
(the runtime hazard sanitizer), proving that fault injection, retries, and
restart recovery raise zero hazard reports — the chaos paths are
happens-before clean, not just bitwise-correct.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig, LinkModel, TransferEngine
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import AUTO, PrefetchSpec
from repro.core.spillstore import SpillStore

TIMEOUT_S = 60.0


@pytest.fixture(autouse=True, params=["plain", "sanitized"])
def sanitize_mode(request, monkeypatch):
    if request.param == "sanitized":
        monkeypatch.setenv("REPRO_SANITIZE", "1")
    else:
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    return request.param


def run_with_timeout(fn, timeout_s: float = TIMEOUT_S):
    """Per-test deadlock watchdog: run ``fn`` on a daemon thread; a hang
    fails the test instead of wedging the whole suite."""
    box: dict = {}

    def target():
        try:
            box["value"] = fn()
        except BaseException as e:  # noqa: BLE001 — re-raised on the caller
            box["error"] = e

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(timeout_s)
    if t.is_alive():
        pytest.fail(f"timed out after {timeout_s}s (possible deadlock)")
    if "error" in box:
        raise box["error"]
    return box.get("value")


def _groups(n=4, shape=(4, 4)):
    rng = np.random.default_rng(0)
    return [rng.standard_normal(shape).astype(np.float32) for _ in range(n)]


def _disk_groups(tmp_path, n=4, shape=(4, 4)):
    store = SpillStore(tmp_path / "spill")
    host = _groups(n, shape)
    out = []
    for i, g in enumerate(host):
        store.put(f"g{i}", {"x": g})
        out.append(store.get(f"g{i}"))
    return host, out


# ---------------------------------------------------------------------------
# worker exception mid-group
# ---------------------------------------------------------------------------


def test_worker_exception_surfaces_on_waiter_and_engine_survives(monkeypatch):
    """An H2D failure on group k raises on *that* future's wait(); other
    groups complete, and the engine keeps serving — with uncorrupted
    staging contents — afterwards."""
    real_put = jax.device_put
    fail_on = {"n": 0}

    def flaky_put(x, *a, **kw):
        fail_on["n"] += 1
        if fail_on["n"] == 2:  # second transfer (group index 1)
            raise RuntimeError("injected H2D fault")
        return real_put(x, *a, **kw)

    groups = [{"x": g} for g in _groups(3)]

    def body():
        with TransferEngine() as eng:
            monkeypatch.setattr(jax, "device_put", flaky_put)
            futs = [eng.submit_group(i, g) for i, g in enumerate(groups)]
            futs[0].wait()
            with pytest.raises(RuntimeError, match="injected H2D fault"):
                futs[1].wait()
            futs[2].wait()
            np.testing.assert_array_equal(
                np.asarray(futs[2].group()["x"]), groups[2]["x"]
            )
            monkeypatch.setattr(jax, "device_put", real_put)
            # same layout after the fault: staging pool must hand back a
            # correctly-packed buffer, not a stale/corrupted one
            fut = eng.submit_group(3, groups[0])
            fut.wait()
            np.testing.assert_array_equal(
                np.asarray(fut.group()["x"]), groups[0]["x"]
            )

    run_with_timeout(body)


def test_disk_stage_exception_surfaces_and_pool_recovers(tmp_path, monkeypatch):
    """A fault while a *disk* group's H2D runs must not deadlock the
    read-ahead window (the buffer is released on the error path) and later
    disk groups must stream correctly."""
    host, disk = _disk_groups(tmp_path, n=4)
    real_put = jax.device_put
    fail_on = {"n": 0}

    def flaky_put(x, *a, **kw):
        fail_on["n"] += 1
        if fail_on["n"] == 1:
            raise RuntimeError("injected disk-group fault")
        return real_put(x, *a, **kw)

    def body():
        # window of 1: a leaked disk buffer would wedge every later fetch
        with TransferEngine(EngineConfig(disk_slots=1, disk_max_slots=1)) as eng:
            monkeypatch.setattr(jax, "device_put", flaky_put)
            futs = [eng.submit_group(i, g) for i, g in enumerate(disk)]
            with pytest.raises(RuntimeError, match="injected disk-group fault"):
                futs[0].wait()
            for i in (1, 2, 3):
                futs[i].wait()
                np.testing.assert_array_equal(
                    np.asarray(futs[i].group()["x"]), host[i]
                )

    run_with_timeout(body)


# ---------------------------------------------------------------------------
# stale writeback tickets after a failed run
# ---------------------------------------------------------------------------


def test_stale_writeback_tickets_discarded_after_failed_run():
    calls = {"n": 0}

    def apply(carry, g):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected apply fault")
        return carry, g * 2.0

    groups = _groups(5)

    def body():
        with HostStreamExecutor(apply, writeback=True) as ex:
            with pytest.raises(RuntimeError, match="injected apply fault"):
                ex.run(jnp.zeros(()), groups, mode="prefetch")
            # the failed run left pending tickets behind; they must be
            # visible to discard and must never drain into the next run
            assert ex.engine.discard_writebacks() >= 0
            _, outs = ex.run(jnp.zeros(()), groups[:2], mode="prefetch")
            assert len(outs) == 2
            for i in range(2):
                np.testing.assert_array_equal(outs[i], groups[i] * 2.0)

    run_with_timeout(body)


# ---------------------------------------------------------------------------
# close() during in-flight prefetch
# ---------------------------------------------------------------------------


def test_close_with_inflight_prefetch_drains_and_restarts():
    """close() while transfers are in flight drains pending work (no
    future left unset), then a later submit transparently restarts the
    workers (the driver's close-at-shutdown / resurrect-if-reused
    contract)."""
    link = LinkModel(request_s=2e-3, bandwidth_Bps=1e9)
    groups = [{"x": g} for g in _groups(6)]

    def body():
        eng = TransferEngine(EngineConfig(link=link))
        futs = [eng.submit_group(i, g) for i, g in enumerate(groups)]
        eng.close()  # in-flight: several transfers still queued
        for i, fut in enumerate(futs):
            fut.wait()  # all futures completed before the worker exited
            np.testing.assert_array_equal(
                np.asarray(fut.group()["x"]), groups[i]["x"]
            )
        assert eng._worker is None
        fut = eng.submit_group(99, groups[0])  # resurrects the worker
        fut.wait()
        np.testing.assert_array_equal(
            np.asarray(fut.group()["x"]), groups[0]["x"]
        )
        eng.close()

    run_with_timeout(body)


def test_close_with_inflight_disk_fetches_drains_cleanly(tmp_path):
    """Same contract one tier down: close() with queued disk fetches must
    complete every stage-1 ticket and stage-2 future, no deadlock."""
    host, disk = _disk_groups(tmp_path, n=6)
    cfg = EngineConfig(
        disk_link=LinkModel(request_s=2e-3, bandwidth_Bps=1e9),
        disk_slots=1, disk_max_slots=2,
    )

    def body():
        eng = TransferEngine(cfg)
        futs = [eng.submit_group(i, g) for i, g in enumerate(disk)]
        eng.close()
        for i, fut in enumerate(futs):
            fut.wait()
            np.testing.assert_array_equal(np.asarray(fut.group()["x"]), host[i])
        assert eng._disk_worker is None

    run_with_timeout(body)


# ---------------------------------------------------------------------------
# adaptive-controller persistence (incl. across a failed run)
# ---------------------------------------------------------------------------


def test_adaptive_controller_survives_failed_run():
    """The executor's learned prefetch window persists across run() calls
    — including a run that raises mid-way.  A fresh controller per run
    would restart every training step at the minimum distance."""
    link = LinkModel(request_s=1e-4, bandwidth_Bps=1e9, latency_s=2e-3)
    groups = _groups(6, shape=(16, 16))
    state = {"fail": False}

    def apply(carry, g):
        if state["fail"]:
            state["fail"] = False
            raise RuntimeError("injected")
        return carry + jnp.sum(g)

    pf = PrefetchSpec(buffer_size=12, distance=AUTO)

    def body():
        with HostStreamExecutor(apply, engine_config=EngineConfig(link=link)) as ex:
            st = StreamStats()
            for _ in range(3):  # learn a window > 1 on the slow link
                ex.run(jnp.zeros(()), groups, mode="prefetch", prefetch=pf, stats=st)
            ctrl = ex._controller
            assert ctrl is not None
            learned = ctrl.distance
            assert learned > 1
            state["fail"] = True
            with pytest.raises(RuntimeError, match="injected"):
                ex.run(jnp.zeros(()), groups, mode="prefetch", prefetch=pf, stats=st)
            # same controller object, learned state intact (within one
            # observe step of where the failed run left it)
            assert ex._controller is ctrl
            assert ctrl.distance >= learned - 1
            st2 = StreamStats()
            ex.run(jnp.zeros(()), groups, mode="prefetch", prefetch=pf, stats=st2)
            assert st2.distance_trace[0] == ctrl.distance or st2.distance_trace[0] > 1

    run_with_timeout(body)


def test_same_signature_groups_with_different_disk_positions(tmp_path):
    """Regression: group_signature cannot tell a memmap from a same-shaped
    ndarray, so the disk-stage layout must key on *which* leaves are
    disk-resident — mixed groups with swapped positions must not share a
    fetch plan."""
    store = SpillStore(tmp_path / "s")
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4, 4)).astype(np.float32)
    y = rng.standard_normal((4, 4)).astype(np.float32)
    store.put("x", x)
    store.put("y", y)
    ga = {"p": store.get("x"), "q": y}  # disk at position 0
    gb = {"p": x, "q": store.get("y")}  # disk at position 1, same signature

    def body():
        with TransferEngine() as eng:
            fa = eng.submit_group(0, ga)
            fb = eng.submit_group(1, gb)
            fa.wait()
            fb.wait()
            np.testing.assert_array_equal(np.asarray(fa.group()["p"]), x)
            np.testing.assert_array_equal(np.asarray(fa.group()["q"]), y)
            np.testing.assert_array_equal(np.asarray(fb.group()["p"]), x)
            np.testing.assert_array_equal(np.asarray(fb.group()["q"]), y)

    run_with_timeout(body)


def test_disk_controller_persists_across_runs(tmp_path):
    """The engine-level disk read-ahead controller is engine state, not
    run state: a slow disk link grows the window and it stays grown for
    the next run on the same engine."""
    host, disk = _disk_groups(tmp_path, n=8, shape=(32, 32))
    cfg = EngineConfig(
        disk_link=LinkModel(request_s=1e-4, bandwidth_Bps=5e7, latency_s=1e-3),
        disk_slots=1,
    )

    @jax.jit
    def apply(carry, g):
        return carry + jnp.sum(g["x"])

    def body():
        eng = TransferEngine(cfg)
        with HostStreamExecutor(apply, engine=eng) as ex:
            ex.run(jnp.zeros(()), disk, mode="prefetch",
                   prefetch=PrefetchSpec(buffer_size=12, distance=AUTO))
            assert eng._disk_controller is not None
            grown = eng._disk_window
            assert grown > 1  # slow disk forced the window open
            ex.run(jnp.zeros(()), disk, mode="prefetch",
                   prefetch=PrefetchSpec(buffer_size=12, distance=AUTO))
            assert eng._disk_window >= 1 and eng._disk_controller is not None
        eng.close()

    run_with_timeout(body)


# ---------------------------------------------------------------------------
# serve path: disk-stage fault mid-decode
# ---------------------------------------------------------------------------


def test_disk_stage_fault_mid_decode_recovers(tmp_path, monkeypatch):
    """A disk-stage failure while a page fetch is in flight surfaces on the
    decode step, releases its read-ahead window slot (no wedged pipeline),
    and the session finishes with exactly the tokens of an un-faulted run —
    the cold home copy is intact, so the page is simply re-fetched."""
    from repro.configs import get_smoke_config
    from repro.launch import serve as sv
    from repro.launch.mesh import make_local_mesh

    cfg = get_smoke_config("smollm-360m")
    mesh = make_local_mesh()
    prompt = np.arange(1, 14, dtype=np.int32)

    def run(fault: bool):
        from repro.core.engine import TransferEngine as TE

        real_acquire = TE._acquire_disk_staging
        armed = {"on": False, "fired": 0}

        def flaky_acquire(self, dsig, layout):
            if armed["on"]:
                armed["on"] = False
                armed["fired"] += 1
                raise RuntimeError("injected disk-stage fault")
            return real_acquire(self, dsig, layout)

        monkeypatch.setattr(TE, "_acquire_disk_staging", flaky_acquire)
        with sv.ServeSession(
            cfg, mesh, slots=1, max_len=24, kv_kind="disk_host",
            page_len=4, hot_pages=0, seed=5,
            spill_dir=str(tmp_path / ("faulted" if fault else "clean")),
        ) as s:
            rid = s.submit(prompt, 8)
            s.admit_pending()
            for _ in range(2):
                s.step()
            if fault:
                armed["on"] = True
                with pytest.raises(RuntimeError, match="injected disk-stage"):
                    while s.pending_work():
                        s.step()
                assert armed["fired"] == 1
                # the failed fetch must have released its window slot
                assert s._engine._disk_in_use == 0
            while s.pending_work():
                s.step()
            toks = np.asarray(s.requests[rid].emitted, np.int32)
            # retire deleted the request's spill chunks — nothing leaked
            assert not [k for k in s._store.keys() if k.startswith("kv/")]
            assert s._engine._disk_in_use == 0
        monkeypatch.setattr(TE, "_acquire_disk_staging", real_acquire)
        return toks

    clean = run_with_timeout(lambda: run(False))
    faulted = run_with_timeout(lambda: run(True))
    np.testing.assert_array_equal(faulted, clean)

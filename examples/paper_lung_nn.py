"""The paper's §5 experiment, end to end: the lung-scan NN benchmark under
eager / on-demand / prefetch offload, small AND full-size images.

This is the faithful-reproduction driver behind EXPERIMENTS.md §Bench —
it trains the 1-hidden-layer (100 neuron) network of [30]/§5 on image-like
data held at the Host memory kind, with the input pixels distributed across
the accelerator, and reports the paper's three phases per offload mode.

Run:  PYTHONPATH=src:. python examples/paper_lung_nn.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common as C
from benchmarks.offload_modes import run as run_modes


def train_accuracy_check() -> None:
    """The network actually learns its task (sanity for the benchmark)."""
    cfg = C.LungNNConfig(n_pixels=512, n_hidden=100, batch_images=64)
    params = C.init_lung_nn(cfg)
    xs, ys = C.make_images(cfg, 64)
    update = jax.jit(lambda p, x, y: C.model_update(p, C.combine_gradients(p, x, y), lr=2.0))
    loss0 = float(C.loss_fn(params, xs, ys))
    for _ in range(300):
        params = update(params, xs, ys)
    loss1 = float(C.loss_fn(params, xs, ys))
    pred = np.asarray(C.feed_forward(params, xs)) > 0.5
    acc = float(np.mean(pred == np.asarray(ys, bool)))
    print(f"lung-NN training: loss {loss0:.4f} -> {loss1:.4f}, train acc {acc:.2f}")
    assert loss1 < loss0


def main() -> int:
    train_accuracy_check()
    print("\n--- small (interpolated) images, paper Fig 3 ---")
    small = run_modes(3600, groups=16, tag="example_fig3")
    print("\n--- full-size images, paper Fig 4 ---")
    full = run_modes(720_000, groups=60, batch_images=2, tag="example_fig4")
    for rows, tag in ((small, "small"), (full, "full")):
        by = {r["mode"]: r for r in rows}
        print(
            f"{tag}: prefetch/on-demand feed-forward ratio = "
            f"{by['on_demand']['feed_forward_s']/by['prefetch']['feed_forward_s']:.2f}x; "
            f"model-update spread across modes = "
            f"{max(r['model_update_s'] for r in rows)/max(min(r['model_update_s'] for r in rows),1e-9):.2f}x"
        )
    print("paper benchmark reproduction: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

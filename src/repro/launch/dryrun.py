import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — JAX locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Per cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. resolves the sharding plan (train or serve mode per shape kind),
  3. jits the step function with explicit in/out shardings and
     ``.lower().compile()``s it against ShapeDtypeStruct inputs,
  4. records ``memory_analysis()`` (residency proof) + ``cost_analysis()``,
  5. (single-pod) compiles two *unrolled layer probes* to derive
     scan-corrected roofline terms (see repro.roofline.analysis),
  6. appends the cell result to a JSON results file.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun.json]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, cell_is_runnable, get_config, input_specs
from repro.configs.base import SHAPES, ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding as sh
from repro.roofline import analysis as ra
from repro.train import steps as st

DEFAULT_OUT = "results/dryrun.json"


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

def _shardings(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )


def _dryrun_cfg(cfg: ModelConfig) -> ModelConfig:
    """Production overrides for at-scale lowering: chunked attention keeps
    the score working set bounded (the Pallas flash kernel is the TPU path;
    it cannot lower on this CPU container — see DESIGN.md)."""
    return dataclasses.replace(cfg, attn_impl="chunked")


def _probe_cfg(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    """Unrolled probe: no layer scan, inner scans disabled where cheap.

    The mLSTM chunk scan is left in place: its projections (the dominant
    matmuls) run outside the scan and are counted exactly; the intra-chunk
    cell (<5% of block FLOPs) is undercounted by the while-counted-once rule
    and added back analytically (``residual_inner_scan_flops``).  Forcing
    chunk=seq instead creates (B, H, S, S)-shaped HLO that stalls the CPU
    compiler for tens of minutes.
    """
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        use_scan=False,
        attn_impl="xla",
        moe_group_size=1 << 30,
    )


def _period(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return len(cfg.block_pattern)
    if cfg.family == "ssm" and cfg.slstm_every:
        return cfg.slstm_every
    return 1


def lower_cell(
    cfg: ModelConfig,
    shape: str,
    mesh,
    *,
    probe_layers: Optional[int] = None,
    donate: bool = True,
    cfg_overrides: Optional[dict] = None,
    plan_overrides: Optional[dict] = None,
):
    """Lower+compile one cell.  Returns (compiled, step_kind, n_tokens)."""
    seq, batch, step_kind = SHAPES[shape]
    mode = "train" if step_kind == "train" else "serve"
    overrides = dict(plan_overrides or {})
    if mode == "serve" and "serve_expert_fsdp" not in overrides:
        # expert FSDP only when the experts cannot fit pure TP (§Perf B1):
        # mixtral fits (5.9 GiB) -> off; qwen3 (29 GiB) -> on
        model_size = mesh.shape["model"]
        overrides["serve_expert_fsdp"] = (
            cfg.param_count()[0] * 2 / model_size > 10 * 2**30
        )
    plan = sh.make_plan(mesh, mode=mode, **overrides)
    if probe_layers is not None:
        cfg = _probe_cfg(cfg, probe_layers)
    else:
        cfg = _dryrun_cfg(cfg)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)

    batch_tree = input_specs(cfg, shape)
    b_shardings = _shardings(mesh, sh.batch_specs(plan, batch_tree, batch))

    with mesh:
        if step_kind == "train":
            params, opt_state = st.abstract_train_state(cfg)
            p_specs = sh.param_specs(plan, params)
            o_specs = sh.opt_state_specs(plan, p_specs, params)
            p_sh = _shardings(mesh, p_specs)
            o_sh = _shardings(mesh, o_specs)
            sharder = (
                sh.make_sharder(plan, params, batch, seq_len=seq,
                                seq_shard=not plan.pure_dp)
                if plan.use_sharder else None
            )
            fn = st.make_train_step(cfg, AdamWConfig(), mesh, sharder)
            jitted = jax.jit(
                fn,
                in_shardings=(p_sh, o_sh, b_shardings),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params, opt_state, batch_tree)
        elif step_kind == "prefill":
            params = st.abstract_params(cfg)
            caches = st.abstract_caches(cfg, batch, seq)
            p_specs = sh.param_specs(plan, params)
            c_specs = sh.cache_specs_tree(plan, caches, batch)
            p_sh = _shardings(mesh, p_specs)
            c_sh = _shardings(mesh, c_specs)
            sharder = sh.make_sharder(plan, params, batch) if plan.use_sharder else None
            fn = st.make_prefill_step(cfg, batch, seq, mesh, sharder)
            jitted = jax.jit(
                fn, in_shardings=(p_sh, b_shardings), out_shardings=(None, c_sh)
            )
            lowered = jitted.lower(params, batch_tree)
        else:  # decode
            params = st.abstract_params(cfg)
            caches = st.abstract_caches(cfg, batch, seq)
            p_specs = sh.param_specs(plan, params)
            c_specs = sh.cache_specs_tree(plan, caches, batch)
            p_sh = _shardings(mesh, p_specs)
            c_sh = _shardings(mesh, c_specs)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            sharder = sh.make_sharder(plan, params, batch) if plan.use_sharder else None
            fn = st.make_decode_step(cfg, mesh, sharder)
            jitted = jax.jit(
                fn,
                in_shardings=(p_sh, c_sh, b_shardings, NamedSharding(mesh, P())),
                out_shardings=(None, c_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(params, caches, batch_tree, pos)
        compiled = lowered.compile()
    return compiled, step_kind, seq * batch


def _mem_fields(compiled) -> dict[str, float]:
    m = compiled.memory_analysis()
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {k: float(getattr(m, k, 0) or 0) for k in keys}
    out["per_device_total_gib"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out["alias_size_in_bytes"]
    ) / 2**30
    return out


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    probes: bool = True,
    verbose: bool = True,
    cfg_overrides: Optional[dict] = None,
    plan_overrides: Optional[dict] = None,
) -> dict[str, Any]:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    ok, why = cell_is_runnable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict[str, Any] = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "runnable": ok,
    }
    if not ok:
        rec["skip_reason"] = why
        if verbose:
            print(f"[skip] {arch} x {shape}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    seq, batch, step_kind = SHAPES[shape]
    t0 = time.time()
    try:
        compiled, step_kind, _ = lower_cell(
            cfg, shape, mesh,
            cfg_overrides=cfg_overrides, plan_overrides=plan_overrides,
        )
    except Exception as e:  # noqa: BLE001 — recorded, the harness reports it
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} x {shape} [{mesh_name}]: {rec['error'][:200]}")
        return rec

    rec["ok"] = True
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["step_kind"] = step_kind
    rec["memory"] = _mem_fields(compiled)
    rec["cost_raw"] = ra.cost_terms(compiled)
    hlo = compiled.as_text()
    rec["coll_raw"] = ra.collective_bytes_from_hlo(hlo)
    del compiled, hlo

    if probes and not multi_pod:
        p = _period(cfg)
        L = cfg.n_layers
        try:
            c1, _, _ = lower_cell(cfg, shape, mesh, probe_layers=p, donate=False,
                                  cfg_overrides=cfg_overrides, plan_overrides=plan_overrides)
            t1 = ra.cost_terms(c1)
            x1 = ra.collective_bytes_from_hlo(c1.as_text())
            del c1
            c2, _, _ = lower_cell(cfg, shape, mesh, probe_layers=2 * p, donate=False,
                                  cfg_overrides=cfg_overrides, plan_overrides=plan_overrides)
            t2 = ra.cost_terms(c2)
            x2 = ra.collective_bytes_from_hlo(c2.as_text())
            del c2
            periods = L // p
            scale = lambda a, b: a + (periods - 1) * (b - a)
            flops = scale(t1["flops"], t2["flops"])
            bytes_ = scale(t1["bytes"], t2["bytes"])
            coll = {k: int(scale(x1[k], x2[k])) for k in x1}
            res = ra.RooflineResult(
                arch=arch,
                shape=shape,
                mesh=mesh_name,
                step_kind=step_kind,
                n_devices=n_dev,
                hlo_flops=flops,
                hlo_bytes=bytes_,
                coll_bytes_by_class=coll,
                coll_bytes_weighted=ra.weighted_collective_bytes(coll),
                residual_flops=ra.residual_inner_scan_flops(
                    cfg, step_kind, seq, batch, n_dev
                ),
                model_flops_global=ra.model_flops(cfg, step_kind, seq, batch),
                analytic_bytes=ra.analytic_hbm_bytes(
                    cfg,
                    step_kind,
                    seq,
                    batch,
                    n_devices=n_dev,
                    tp_degree=1 if (plan_overrides or {}).get("pure_dp") else None,
                ),
            )
            rec["probe"] = {
                "flops": flops,
                "bytes": bytes_,
                "coll": coll,
                "coll_weighted": res.coll_bytes_weighted,
                "residual_flops": res.residual_flops,
                "model_flops_global": res.model_flops_global,
                "analytic_bytes": res.analytic_bytes,
            }
            rec["roofline"] = res.terms()
            if verbose:
                print(ra.roofline_report(res))
        except Exception as e:  # noqa: BLE001
            rec["probe_error"] = f"{type(e).__name__}: {e}"
            if verbose:
                print(f"[probe-fail] {arch} x {shape}: {rec['probe_error'][:200]}")

    if verbose:
        mem = rec["memory"]
        print(
            f"[ok] {arch} x {shape} [{mesh_name}] compile={rec['compile_s']}s "
            f"mem/dev={mem['per_device_total_gib']:.2f} GiB"
        )
    return rec


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _load(path: Path) -> list[dict]:
    if path.exists():
        return json.loads(path.read_text())
    return []


def _save(path: Path, rows: list[dict]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(rows, indent=1))
    tmp.rename(path)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true", help="re-run completed cells")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="ModelConfig override (hillclimb A/B), e.g. decode_cache_in_carry=true")
    ap.add_argument("--plan-set", action="append", default=[], metavar="K=V",
                    help="ShardingPlan override, e.g. attn_indivisible=replicate")
    args = ap.parse_args()

    def parse_kv(items):
        out = {}
        for it in items:
            k, v = it.split("=", 1)
            if v.lower() in ("true", "false"):
                out[k] = v.lower() == "true"
            else:
                try:
                    out[k] = int(v)
                except ValueError:
                    out[k] = v
        return out

    cfg_overrides = parse_kv(args.set)
    plan_overrides = parse_kv(getattr(args, "plan_set"))

    out = Path(args.out)
    rows = _load(out)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in rows if r.get("ok") or not r.get("runnable", True)}
    mesh_name = "2x16x16" if args.multi_pod else "16x16"

    cells = (
        [(a, s) for a in ARCHS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    n_fail = 0
    for arch, shape in cells:
        if not arch or not shape:
            ap.error("--arch/--shape required unless --all")
        if not args.force and (arch, shape, mesh_name) in done:
            print(f"[cached] {arch} x {shape} [{mesh_name}]")
            continue
        rec = run_cell(
            arch, shape, multi_pod=args.multi_pod, probes=not args.no_probes,
            cfg_overrides=cfg_overrides or None, plan_overrides=plan_overrides or None,
        )
        rows = [
            r for r in rows
            if not (r["arch"] == arch and r["shape"] == shape and r["mesh"] == mesh_name)
        ] + [rec]
        _save(out, rows)
        if rec.get("runnable") and not rec.get("ok"):
            n_fail += 1
        jax.clear_caches()
    print(f"done: {len(cells)} cells, {n_fail} failures -> {out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

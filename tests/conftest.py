"""Test configuration.  NOTE: no XLA_FLAGS device-count override here —
smoke tests must see the real (1-device) backend; only the dry-run uses
512 placeholder devices (in its own process).
"""
import os

# keep CPU tests deterministic and fast
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

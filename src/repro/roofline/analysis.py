"""Roofline terms from compiled artifacts.

Sources (all per-device — SPMD-compiled modules carry shard shapes):
  * ``compiled.cost_analysis()`` — HLO FLOPs and bytes accessed,
  * HLO text parse — collective bytes by op class (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute),
  * ``compiled.memory_analysis()`` — residency proof for §Dry-run.

Scan caveat (measured in this container, see DESIGN.md §4): XLA's
``cost_analysis`` counts a ``while`` body **once**.  The dry-run therefore
derives FLOPs/bytes/collectives from *unrolled layer probes* (period and
2x period layers, inner scans disabled) and scales:

    total = probe(p) + (L/p - 1) * (probe(2p) - probe(p))

which is exact for layer-homogeneous costs (embed/head/optimizer overhead
cancels in the delta).  Residual inner-scan costs that cannot be unrolled
(sLSTM's time recurrence) are added analytically and reported separately.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

from repro.roofline.hw import V5E, HwSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
# bytes moved per device relative to the (sharded) output tensor size
_CLASS_WEIGHT = {
    "all-gather": 1.0,       # receives ~full output
    "all-reduce": 2.0,       # ring: reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Per-device collective bytes by op class (output-tensor sizes).

    ``-start`` ops are skipped (their ``-done`` twin carries the output);
    shapes in an SPMD module are shard shapes, so sums are per-device.
    """
    out: dict[str, int] = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        for op in _COLL_OPS:
            token = f" {op}("
            done = f" {op}-done("
            start = f" {op}-start("
            if start in line:
                break  # counted at -done
            seg = None
            if done in line:
                seg = line.split(done)[0]
            elif token in line:
                seg = line.split(token)[0]
            if seg is not None:
                lhs = seg.split("=", 1)[1] if "=" in seg else seg
                out[op] += _shape_bytes(lhs)
                break
    return out


def weighted_collective_bytes(by_class: dict[str, int]) -> float:
    return sum(_CLASS_WEIGHT[k] * v for k, v in by_class.items())


def cost_terms(compiled) -> dict[str, float]:
    """flops / bytes-accessed per device from XLA cost analysis."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    step_kind: str
    n_devices: int
    # per-device quantities
    hlo_flops: float
    hlo_bytes: float
    coll_bytes_by_class: dict[str, int]
    coll_bytes_weighted: float
    residual_flops: float = 0.0  # analytic inner-scan add-on
    model_flops_global: float = 0.0
    analytic_bytes: float = 0.0  # first-order HBM model (see analytic_hbm_bytes)

    def terms(self, hw: HwSpec = V5E) -> dict[str, float]:
        t_c = (self.hlo_flops + self.residual_flops) / hw.peak_flops_bf16
        t_m_hlo = self.hlo_bytes / hw.hbm_bw
        t_m = (self.analytic_bytes or self.hlo_bytes) / hw.hbm_bw
        t_x = self.coll_bytes_weighted / hw.ici_bw
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
        mf_dev = self.model_flops_global / max(self.n_devices, 1)
        return {
            "t_compute_s": t_c,
            "t_memory_hlo_s": t_m_hlo,
            "t_memory_s": t_m,
            "t_collective_s": t_x,
            "dominant": dom[0],
            "t_dominant_s": dom[1],
            "t_ideal_s": max(mf_dev / hw.peak_flops_bf16, 1e-30),
            "model_flops_ratio": mf_dev / max(self.hlo_flops + self.residual_flops, 1.0),
            "roofline_fraction": (mf_dev / hw.peak_flops_bf16) / max(dom[1], 1e-30),
        }


def analytic_hbm_bytes(
    cfg,
    step_kind: str,
    seq: int,
    batch: int,
    *,
    data: int = 16,
    model: int = 16,
    n_devices: int = 256,
    tp_degree: int | None = None,
    act_passes: float | None = None,
) -> float:
    """First-order per-device HBM traffic model (documented in EXPERIMENTS.md).

    Why this exists: XLA's ``bytes accessed`` on the *CPU* pipeline counts
    every elementwise op at full tensor width (the CPU compiler barely
    fuses — measured 146 GB/layer of bare ``convert`` ops on olmo-1b), so it
    overstates TPU HBM traffic by an order of magnitude.  The HLO number is
    still reported (per the assignment); this analytic term is reported
    alongside and used to sanity-check the dominant-bottleneck call.

    Terms (bf16 weights/activations, f32 optimizer/scores):
      weights     fwd(+remat+bwd for train) reads of the TP-local shard
      optimizer   master/m/v read+write + f32 grads (fully sharded)
      activations ~12 block tensors per pass, 1 pass fwd / 3 passes train
      scores      materialized (B,S,S) f32+bf16 per local head (XLA path)
      kv/state    decode-cache read + write
    """
    p_total, p_active = cfg.param_count()
    tp = tp_degree if tp_degree is not None else model
    dp = n_devices // max(tp, 1)
    b_loc = max(batch // dp, 1)
    d = cfg.d_model
    L = cfg.n_layers
    n_attn = sum(1 for i in range(L) if cfg.block_kind(i) == "attn")
    h_loc = max(cfg.n_heads // max(tp, 1), 1)
    tp_shards = max(tp, 1)
    if cfg.n_experts and step_kind != "train":
        tp_shards = min(n_devices, cfg.n_experts * max(tp, 1))  # serve expert FSDP

    w_local = 2.0 * p_total / tp_shards  # bf16 TP shard
    toks = b_loc * seq

    if step_kind == "train":
        passes = act_passes if act_passes is not None else (3.0 if cfg.remat == "full" else 2.0)
        weights = passes * w_local  # fwd (+ remat recompute) + bwd
        optim = (24.0 + 8.0) * p_total / n_devices  # f32 m/v/master rw + grads
        acts = L * 12.0 * toks * d * 2.0 * passes
        attn_ctx = min(seq, cfg.window or seq) if cfg.attn_type == "swa" else seq
        scores = n_attn * passes * h_loc * b_loc * seq * attn_ctx * 6.0
        return weights + optim + acts + scores
    if step_kind == "prefill":
        weights = w_local
        acts = L * 12.0 * toks * d * 2.0
        attn_ctx = min(seq, cfg.window or seq) if (cfg.attn_type == "swa" or cfg.family == "hybrid") else seq
        scores = n_attn * h_loc * b_loc * seq * attn_ctx * 6.0
        kv_write = 2.0 * n_attn * b_loc * cfg.cache_len(seq) * cfg.n_kv_heads * cfg.head_dim * 2.0 / max(model // 8, 1)
        return weights + acts + scores + kv_write
    # decode: weights + full cache read dominate
    weights = w_local
    cl = cfg.cache_len(seq)
    kv_heads_loc = max(cfg.n_kv_heads, 1)
    kv = 2.0 * n_attn * b_loc * cl * kv_heads_loc * cfg.head_dim * 2.0 / model
    acts = L * 12.0 * b_loc * d * 2.0
    return weights + kv + acts


def model_flops(cfg, step_kind: str, seq: int, batch: int) -> float:
    """Assignment formula: 6·N·D (train) / 2·N·D (forward-only serve steps);
    N = active params (MoE: routed active + shared), D = tokens."""
    _, n_active = cfg.param_count()
    if step_kind == "train":
        return 6.0 * n_active * batch * seq
    if step_kind == "prefill":
        return 2.0 * n_active * batch * seq
    return 2.0 * n_active * batch  # decode: one token per sequence


def residual_inner_scan_flops(cfg, step_kind: str, seq: int, batch: int, n_devices: int) -> float:
    """Per-device analytic FLOPs for work inside time/chunk scans the probes
    cannot unroll (counted once by cost_analysis):
      * sLSTM recurrent matvecs (the whole time scan),
      * mLSTM intra-chunk cell beyond the first chunk (<5% of block FLOPs;
        projections are outside the scan and counted exactly).
    Everything else is captured by the unrolled probes."""
    if cfg.family != "ssm" or step_kind == "decode":
        return 0.0
    total = 0.0
    n_slstm = sum(1 for i in range(cfg.n_layers) if cfg.block_kind(i) == "slstm")
    n_mlstm = cfg.n_layers - n_slstm
    nh = cfg.n_heads
    if n_slstm:
        dh = cfg.d_model // nh
        per_tok = 4 * nh * dh * dh * 2  # r_z/r_i/r_f/r_o matvecs
        total += n_slstm * batch * seq * per_tok
    if n_mlstm:
        di = int(cfg.proj_factor * cfg.d_model)
        dhin = di // nh
        dqk = dhin // 2
        c = min(cfg.mlstm_chunk, seq)
        n_chunks = max(seq // c, 1)
        per_chunk = nh * (2 * c * c * dqk + 2 * c * c * dhin)  # qk^T + D@v
        total += n_mlstm * batch * (n_chunks - 1) * per_chunk
    if step_kind == "train":
        total *= 3  # fwd + bwd (2x)
    return total / n_devices


def roofline_report(res: RooflineResult, hw: HwSpec = V5E) -> str:
    t = res.terms(hw)
    lines = [
        f"{res.arch} x {res.shape} [{res.mesh}, {res.step_kind}, {res.n_devices} chips]",
        f"  compute    {t['t_compute_s']*1e3:10.3f} ms   ({(res.hlo_flops+res.residual_flops)/1e9:.1f} GFLOP/dev)",
        f"  memory     {t['t_memory_s']*1e3:10.3f} ms   (analytic {res.analytic_bytes/1e9:.2f} GB/dev;"
        f" HLO {res.hlo_bytes/1e9:.2f} GB/dev = {t['t_memory_hlo_s']*1e3:.1f} ms)",
        f"  collective {t['t_collective_s']*1e3:10.3f} ms   ({res.coll_bytes_weighted/1e9:.2f} GB/dev weighted)",
        f"  dominant: {t['dominant']}   MODEL/HLO flops ratio: {t['model_flops_ratio']:.3f}"
        f"   roofline fraction: {t['roofline_fraction']:.3f}",
    ]
    return "\n".join(lines)

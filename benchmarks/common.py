"""Shared benchmark utilities: the paper's ML workload, timing, reporting."""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = Path("results/bench")


def timed(
    fn: Callable[[], Any], *, repeats: int = 3, warmup: int = 1, stats: Any = None
) -> dict:
    """Median wall time of fn() (block_until_ready'd).

    ``stats`` (e.g. a ``StreamStats``) is ``reset()`` after the warmup runs,
    so its counters afterwards cover *exactly* the ``repeats`` timed runs —
    callers divide by ``stats.n_runs`` (== repeats) for per-run numbers
    instead of guessing the repeat structure.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    if stats is not None:
        stats.reset()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return {
        "median_s": float(np.median(ts)),
        "min_s": min(ts),
        "max_s": max(ts),
        "repeats": repeats,
    }


def save_rows(name: str, rows: list[dict]) -> Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"{name}.json"
    out.write_text(json.dumps(rows, indent=1))
    return out


def print_table(title: str, rows: list[dict], cols: list[str]) -> None:
    print(f"\n== {title} ==")
    header = " | ".join(f"{c:>18s}" for c in cols)
    print(header)
    print("-" * len(header))
    for r in rows:
        print(" | ".join(f"{_fmt(r.get(c)):>18s}" for c in cols))


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


# ---------------------------------------------------------------------------
# The paper's ML benchmark (§5): 1-hidden-layer NN over 3D-scan-like images.
# input pixels distributed across cores; phases: feed forward / combine
# gradients / model update.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LungNNConfig:
    n_pixels: int  # 3600 (small) / "full" ~7M in the paper
    n_hidden: int = 100
    batch_images: int = 8
    seed: int = 0

    @property
    def image_bytes(self) -> int:
        return self.n_pixels * 4


def init_lung_nn(cfg: LungNNConfig):
    k1, k2 = jax.random.split(jax.random.PRNGKey(cfg.seed))
    w1 = jax.random.normal(k1, (cfg.n_pixels, cfg.n_hidden), jnp.float32) * 0.01
    w2 = jax.random.normal(k2, (cfg.n_hidden, 1), jnp.float32) * 0.1
    return {"w1": w1, "w2": w2}


def make_images(cfg: LungNNConfig, n: int):
    key = jax.random.PRNGKey(cfg.seed + 1)
    xs = jax.random.normal(key, (n, cfg.n_pixels), jnp.float32)
    ys = (jnp.sum(xs[:, ::97], axis=-1, keepdims=True) > 0).astype(jnp.float32)
    return xs, ys


def feed_forward(params, x):
    h = jax.nn.sigmoid(x @ params["w1"])
    return jax.nn.sigmoid(h @ params["w2"])


def loss_fn(params, x, y):
    p = feed_forward(params, x)
    return jnp.mean((p - y) ** 2)


combine_gradients = jax.grad(loss_fn)


def model_update(params, grads, lr=0.1):
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)

"""Asynchronous coalesced transfer engine — the paper's host service, engineered.

The paper's runtime (§4) is a host process that serves device channel
requests; its performance result (§5.1, Table 2) is that the 21-25x
on-demand penalty comes from *request count*, not per-transfer bandwidth,
and that chunked prefetch with overlap recovers nearly all of it.  The seed
``HostStreamExecutor`` reproduced the *schedule* but not the engineering:
one ``jax.device_put`` per pytree leaf per group, a fresh device allocation
per group, and a blocking ``jax.device_get`` per ``rw`` writeback.  This
module is the engineering:

coalescing
    Each group's host-resident leaves are packed byte-wise into ONE
    contiguous staging buffer, so a group costs one H2D request instead of
    one per leaf (paper: "significantly fewer requests").  A cached jitted
    unpack reconstitutes the leaves on device (bitcast + reshape — bitwise
    exact).  Leaves that are already committed ``jax.Array``s pass through
    untouched (true pass-by-reference: data already at the fast tier is
    never re-sent).

sharding-aware coalescing
    Explicit multi-device layouts (``device_shardings``) compose with
    coalescing instead of disabling it: a :class:`ShardedGroupLayout`
    derives each leaf's per-device shard slices from its sharding's
    ``addressable_devices_indices_map``, packs ONE staging buffer per
    (addressable device, group), issues one ``device_put`` per device per
    group, and assembles the committed leaves with
    ``jax.make_array_from_single_device_arrays`` — bitwise identical to
    eager sharded placement, at ``n_devices`` requests per group instead
    of ``n_leaves x n_shards``.  This mirrors the source paper's (and
    ePython's) host service, which feeds *per-core* channels: the host
    process serves one request per device, never one per object per
    device.

buffer reuse
    Staging buffers are preallocated per group layout and recycled
    round-robin (the transfer worker completes a copy before reusing a
    slot).  Device-side, the flat buffer of group ``i`` is *donated* into
    its unpacked leaves, so the ring of ``distance+1`` in-flight flats is
    recycled by the allocator instead of growing per group.

asynchrony
    Transfers run on a dedicated worker thread (the host service).  The
    compute thread submits a group and receives a :class:`TransferFuture`;
    packing, ``device_put`` and (for ``rw`` groups) ``device_get`` all
    happen off the compute path.  ``rw`` writebacks are drained at the end
    of the run, in group order.

adaptive prefetch distance
    :class:`AdaptiveDistance` watches the per-group transfer wait and
    grows/shrinks the in-flight window at run time; it backs
    ``PrefetchSpec(distance="auto")``.

three-level streaming (the ``DiskHost`` tier)
    Groups whose leaves are memory-mapped spill-store views
    (:func:`repro.core.spillstore.is_disk_leaf`) move through a *two-stage*
    pipeline: a dedicated disk worker copies the mapped bytes into pooled
    host staging buffers (the disk read), then the transfer worker packs
    and issues the H2D exactly as for host groups.  Each stage has its own
    staging pool and its own :class:`AdaptiveDistance` controller: the
    executor's controller sizes the submission window from *compute-thread*
    stalls, while the engine's disk controller sizes the disk read-ahead
    window (number of fetched-but-unconsumed buffers) from *transfer-
    worker* stalls — so disk latency hides behind host->device latency
    exactly as host latency hides behind compute.

An optional :class:`LinkModel` emulates a slow interconnect (per-request
service time + serial bandwidth occupancy + overlappable completion
latency) so the paper's phenomenology — request-count collapse, prefetch
hiding latency — is reproducible deterministically on this container,
whose real host->device "link" is main memory.  ``EngineConfig.disk_link``
models the disk tier's (slower) link the same way.
"""
from __future__ import annotations

import dataclasses
import logging
import queue
import threading
import time
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.schedcheck import HazardError, HazardSanitizer, sanitize_enabled

log = logging.getLogger("repro.engine")

__all__ = [
    "LinkModel",
    "PAPER_EPIPHANY_LINK",
    "EngineConfig",
    "GroupLayout",
    "ShardedGroupLayout",
    "TransferFuture",
    "AdaptiveDistance",
    "TransferEngine",
    "static_auto_distance",
]

Pytree = Any

#: staging offsets are padded to this many bytes so dtype views stay aligned
_ALIGN = 64


def _align(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


def _sleep_precise(duration_s: float) -> None:
    """Sleep with sub-millisecond accuracy without starving other threads.

    ``time.sleep`` with a nonzero duration overshoots by ~1 ms on this
    container — larger than the paper's 0.104 ms per-request cost the link
    model emulates.  The tail is waited in ``sleep(0)`` yields (a plain spin
    would hold the GIL for up to the 5 ms switch interval and serialize the
    engine worker behind the waiter).
    """
    end = time.perf_counter() + duration_s
    while True:
        remaining = end - time.perf_counter()
        if remaining <= 0:
            return
        if remaining > 1.5e-3:
            time.sleep(remaining - 1e-3)
        else:
            time.sleep(0)  # yield the GIL, keep ~10 us accuracy


# ---------------------------------------------------------------------------
# link emulation (paper §5.1 constants)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Emulated interconnect for schedule studies.

    ``request_s``
        serial per-request service time (the paper's host-service
        turnaround: ~0.104 ms/request on Epiphany, Table 2).  This is the
        term the coalescer collapses.
    ``bandwidth_Bps``
        serial occupancy: a transfer holds the link for ``nbytes/bw``.
    ``latency_s``
        completion delay *after* the link is released — overlappable by
        prefetch depth, which is what ``distance`` (and the adaptive
        controller) hides.
    """

    request_s: float = 0.104e-3
    bandwidth_Bps: float = 88e6
    latency_s: float = 0.0

    def occupancy_s(self, n_requests: int, nbytes: int) -> float:
        return n_requests * self.request_s + nbytes / self.bandwidth_Bps

    def transfer_s(self, n_requests: int, nbytes: int) -> float:
        return self.occupancy_s(n_requests, nbytes) + self.latency_s


#: the paper's measured Epiphany link (88 MB/s, 0.104 ms/request)
PAPER_EPIPHANY_LINK = LinkModel()


# ---------------------------------------------------------------------------
# engine configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs of the transfer engine.  The defaults are the fast path; the
    seed executor's behaviour is ``EngineConfig(coalesce=False,
    async_writeback=False)`` (kept for A/B benchmarking)."""

    #: pack each group's host leaves into one staging buffer (1 H2D request)
    coalesce: bool = True
    #: drain ``rw`` writebacks at end of run instead of blocking per group
    async_writeback: bool = True
    #: staging buffers preallocated per group layout
    staging_slots: int = 2
    #: donate the flat device buffer into its unpacked leaves
    donate_flat: bool = True
    #: emulated interconnect (None = the container's real link)
    link: Optional[LinkModel] = None
    # -- adaptive distance (PrefetchSpec(distance="auto")) ------------------
    min_distance: int = 1
    max_distance: int = 8
    #: a per-group wait above this counts as a stall -> grow the window
    wait_eps_s: float = 100e-6
    #: consecutive stall-free groups before the window shrinks
    shrink_after: int = 4
    # -- disk tier (DiskHost groups: two-stage disk->host->device) ----------
    #: emulated disk link (None = the container's real page cache / disk)
    disk_link: Optional[LinkModel] = None
    #: initial disk read-ahead window (fetched-but-unconsumed host buffers);
    #: the disk-stage AdaptiveDistance controller grows/shrinks it at run
    #: time from observed transfer-worker stalls
    disk_slots: int = 2
    disk_max_slots: int = 8
    disk_wait_eps_s: float = 100e-6
    disk_shrink_after: int = 4
    # -- robustness (self-healing streamed runtime) -------------------------
    #: attempts per transfer operation (H2D put, D2H get, disk stage);
    #: 1 = fail fast (the historical behaviour).  Retried operations re-read
    #: their intact cold home (host arrays / mapped chunk bytes), so a
    #: schedule that retried is bitwise-equal to one that did not.
    max_attempts: int = 1
    #: base of the exponential backoff between attempts (attempt ``k``
    #: sleeps ``retry_backoff_s * 2**k``); the clean path never sleeps
    retry_backoff_s: float = 1e-3
    #: per-worker join timeout in ``close()`` before the thread counts as
    #: leaked (surfaced on ``TransferEngine.leaked_threads``)
    close_timeout_s: float = 5.0
    #: CRC-verify spill-store chunk bytes in the disk stage before packing
    verify_spill: bool = True
    # -- hazard sanitizer (static analyzer's runtime counterpart) -----------
    #: record a happens-before edge per ticket and raise
    #: :class:`repro.core.schedcheck.HazardError` on writeback-vs-fetch RAW
    #: hazards and staging-pool lifetime violations.  Defaults from the
    #: ``REPRO_SANITIZE`` environment variable so chaos/fault suites can
    #: run sanitized without threading a flag through every constructor.
    sanitize: bool = dataclasses.field(default_factory=sanitize_enabled)


def static_auto_distance(n_chunks: int, cap: int = 4) -> int:
    """Compile-time resolution of ``distance="auto"`` for the graph engine
    (``prefetch.streamed_scan``), which cannot re-shape its ring at run
    time: a small fixed head start, clamped to the chunk count."""
    return max(1, min(cap, n_chunks - 1))


# ---------------------------------------------------------------------------
# adaptive prefetch distance
# ---------------------------------------------------------------------------


class AdaptiveDistance:
    """Grow-on-stall / shrink-when-idle controller for the in-flight window.

    Observes the compute thread's per-group transfer wait.  A wait above
    ``wait_eps_s`` grows the window by one; ``shrink_after`` consecutive
    clean groups shrink it by one.  A stall immediately after a shrink
    raises a sticky floor so the controller converges to the minimal
    sufficient window instead of oscillating.
    """

    def __init__(
        self,
        *,
        initial: int = 1,
        min_distance: int = 1,
        max_distance: int = 8,
        wait_eps_s: float = 100e-6,
        shrink_after: int = 4,
    ) -> None:
        self.min_distance = max(1, min_distance)
        self.max_distance = max(self.min_distance, max_distance)
        self.wait_eps_s = wait_eps_s
        self.shrink_after = max(1, shrink_after)
        self.distance = min(max(initial, self.min_distance), self.max_distance)
        self._floor = self.min_distance
        self._clean = 0
        self._just_shrank = False

    def observe(self, wait_s: float) -> int:
        """Record one group's transfer wait; returns the updated distance."""
        if wait_s > self.wait_eps_s:
            if self._just_shrank:
                # shrinking caused a stall: the previous window was minimal
                self._floor = min(self.distance + 1, self.max_distance)
            self.distance = min(self.distance + 1, self.max_distance)
            self._clean = 0
            self._just_shrank = False
        else:
            self._clean += 1
            self._just_shrank = False
            if self._clean >= self.shrink_after and self.distance > max(
                self.min_distance, self._floor
            ):
                self.distance -= 1
                self._clean = 0
                self._just_shrank = True
        return self.distance

    def boost(self, n: int = 1) -> int:
        """Externally widen the window (straggler feedback): a flagged slow
        step is treated like an observed stall without waiting for one."""
        self.distance = min(self.distance + max(1, n), self.max_distance)
        self._clean = 0
        self._just_shrank = False
        return self.distance


# ---------------------------------------------------------------------------
# group layout: cached pack/unpack plan
# ---------------------------------------------------------------------------


def group_signature(group: Pytree) -> tuple:
    """Hashable identity of a group's structure: treedef + per-leaf
    (shape, dtype, device-resident?)."""
    leaves, treedef = jax.tree.flatten(group)
    return (
        treedef,
        tuple(
            (np.shape(x), str(np.asarray(x).dtype) if not isinstance(x, jax.Array) else str(x.dtype),
             isinstance(x, jax.Array))
            for x in leaves
        ),
    )


def _aliases_host(flat: jax.Array, staging: np.ndarray) -> bool:
    """True if the device array zero-copied the staging memory (some CPU
    backends do) — in that case the buffer must NOT be recycled while the
    array is alive."""
    try:
        return flat.unsafe_buffer_pointer() == staging.ctypes.data
    except Exception:  # noqa: BLE001 — unknown backend: assume aliasing
        return True


class GroupLayout:
    """Pack/unpack plan for one group structure.

    Host leaves (anything not already a ``jax.Array``) are packed into one
    contiguous byte buffer at 64-byte-aligned offsets; device-resident
    leaves pass through by reference.  ``unpack`` is a jitted
    slice+bitcast+reshape, compiled once per layout and bitwise-exact.
    """

    #: default-placement layouts stage through a single device
    n_devices = 1

    def __init__(self, group: Pytree, *, donate_flat: bool = True) -> None:
        leaves, self.treedef = jax.tree.flatten(group)
        self.n_leaves = len(leaves)
        self.metas: list[tuple[int, int, tuple, np.dtype, int]] = []
        self.passthrough_idx: list[int] = []
        off = 0
        for i, x in enumerate(leaves):
            if isinstance(x, jax.Array):
                self.passthrough_idx.append(i)
                continue
            a = np.asarray(x)
            # pack at JAX's canonical dtype: the per-leaf device_put path
            # canonicalizes float64->float32 etc., and the device-side
            # bitcast target is canonicalized regardless — packing source
            # bytes would unpack into garbage (or a shape error)
            dtype = np.dtype(jax.dtypes.canonicalize_dtype(a.dtype))
            nbytes = a.size * dtype.itemsize
            self.metas.append((i, off, a.shape, dtype, nbytes))
            off = _align(off + nbytes)
        self.staging_bytes = off
        #: actual H2D payload (unpadded), for byte accounting
        self.payload_bytes = sum(m[4] for m in self.metas)
        #: H2D requests this layout costs when coalesced (0 if nothing to move)
        self.n_packed = len(self.metas)

        metas = self.metas

        def _unpack(flat: jax.Array) -> tuple:
            outs = []
            for _, o, shape, dtype, nbytes in metas:
                seg = lax.slice(flat, (o,), (o + nbytes,))
                outs.append(_bitcast(seg, dtype).reshape(shape))
            return tuple(outs)

        donate = (0,) if donate_flat else ()
        self._unpack = jax.jit(_unpack, donate_argnums=donate)

    @property
    def has_payload(self) -> bool:
        return bool(self.metas)

    @property
    def n_requests(self) -> int:
        """H2D requests this layout costs per group when coalesced."""
        return 1 if self.metas else 0

    def new_staging(self) -> np.ndarray:
        return np.empty((self.staging_bytes,), np.uint8)

    def put_staged(self, staging: np.ndarray):
        """Issue the (single) H2D transfer of the packed staging buffer."""
        return jax.device_put(staging)

    def any_alias(self, flat, staging) -> bool:
        return _aliases_host(flat, staging)

    def pack_into(self, leaves: list, staging: np.ndarray) -> np.ndarray:
        for i, off, shape, dtype, nbytes in self.metas:
            dst = staging[off : off + nbytes].view(dtype).reshape(shape)
            # same_kind: permits the canonicalizing f64->f32 / i64->i32 cast
            np.copyto(dst, leaves[i], casting="same_kind")
        return staging

    def unpack(self, flat: jax.Array, src_leaves: list) -> Pytree:
        """Rebuild the group pytree from the flat device buffer, merging
        passed-through device leaves from the original submission."""
        if self.metas:
            with warnings.catch_warnings():
                # donation is best-effort: backends without aliasing support
                # fall back to a copy — correct, and not worth a warning per
                # layout (scoped here instead of a process-global filter)
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable"
                )
                packed = self._unpack(flat)
        else:
            packed = ()
        out: list = [None] * self.n_leaves
        for (i, *_), leaf in zip(self.metas, packed):
            out[i] = leaf
        for i in self.passthrough_idx:
            out[i] = src_leaves[i]
        return jax.tree.unflatten(self.treedef, out)


def _bitcast(seg_u8: jax.Array, dtype: np.dtype) -> jax.Array:
    jdt = jnp.dtype(dtype)
    if jdt == jnp.uint8:
        return seg_u8
    if jdt == jnp.bool_:
        return seg_u8 != 0
    if jdt.itemsize == 1:
        return lax.bitcast_convert_type(seg_u8, jdt)
    return lax.bitcast_convert_type(seg_u8.reshape(-1, jdt.itemsize), jdt)


def _shard_shape(shape: tuple, idx: tuple) -> tuple:
    """Shape of the shard a device holds, from its indices-map entry."""
    if not idx:  # 0-d leaf: every device holds the scalar
        return tuple(shape)
    out = []
    for dim, sl in zip(shape, idx):
        start, stop, step = sl.indices(dim)
        out.append(max(0, -(-(stop - start) // step)))
    return tuple(out)


def flatten_shardings(device_shardings: Any, n_leaves: int) -> list:
    """Flatten a shardings pytree positionally against a group's leaf list.

    ``None`` entries are kept as leaves (they mark default placement for
    that position); a single sharding broadcasts over every leaf.
    """
    flat, _ = jax.tree.flatten(device_shardings, is_leaf=lambda x: x is None)
    if len(flat) == 1 and n_leaves != 1:
        flat = flat * n_leaves
    if len(flat) != n_leaves:
        raise ValueError(
            f"device_shardings has {len(flat)} leaves for a group of "
            f"{n_leaves} leaves"
        )
    return flat


class ShardedGroupLayout:
    """Per-(addressable device, group) pack/unpack plan for explicitly
    sharded groups.

    Each host leaf's per-device shard slices come from its sharding's
    ``addressable_devices_indices_map``; every device gets ONE contiguous
    staging buffer holding its shards at 64-byte-aligned offsets (a
    replicated leaf contributes a full copy per device — exactly the bytes
    eager sharded placement moves).  ``put_staged`` issues one
    ``device_put`` per device per group; ``unpack`` runs a jitted
    slice+bitcast+reshape on each device's flat buffer and assembles the
    committed leaves with ``jax.make_array_from_single_device_arrays`` —
    bitwise identical to ``jax.device_put(leaf, sharding)``.
    """

    def __init__(self, group: Pytree, shardings_flat: list, *, donate_flat: bool = True) -> None:
        leaves, self.treedef = jax.tree.flatten(group)
        self.n_leaves = len(leaves)
        self.passthrough_idx: list[int] = []
        #: per-leaf assembly plan: (leaf idx, global shape, dtype, sharding)
        self.assembly: list[tuple] = []
        entries: dict[Any, list] = {}
        offs: dict[Any, int] = {}
        default_dev = jax.devices()[0]
        for i, (x, s) in enumerate(zip(leaves, shardings_flat)):
            if isinstance(x, jax.Array):
                self.passthrough_idx.append(i)
                continue
            a = np.asarray(x)
            # pack at JAX's canonical dtype, same as GroupLayout (and as
            # jax.device_put would canonicalize)
            dtype = np.dtype(jax.dtypes.canonicalize_dtype(a.dtype))
            if s is None:
                # unplaced leaf riding in a sharded group: default device
                s = jax.sharding.SingleDeviceSharding(default_dev)
            imap = s.addressable_devices_indices_map(a.shape)
            self.assembly.append((i, a.shape, dtype, s))
            for d in sorted(imap, key=lambda d: d.id):
                shard_shape = _shard_shape(a.shape, imap[d])
                nbytes = int(np.prod(shard_shape, dtype=np.int64)) * dtype.itemsize
                off = offs.get(d, 0)
                entries.setdefault(d, []).append(
                    (i, imap[d], off, shard_shape, dtype, nbytes)
                )
                offs[d] = _align(off + nbytes)
        self.devices = sorted(entries, key=lambda d: d.id)
        self.entries = [entries[d] for d in self.devices]
        self.staging_bytes = [offs[d] for d in self.devices]
        #: actual H2D payload (unpadded, summed over devices)
        self.payload_bytes = sum(e[5] for es in self.entries for e in es)
        #: ONE coalesced H2D request per (addressable device, group)
        self.n_requests = len(self.devices)
        self.n_devices = max(1, len(self.devices))
        # one jitted unpack per distinct per-device plan (devices usually
        # share one: identical shard shapes at identical offsets)
        donate = (0,) if donate_flat else ()
        by_plan: dict[tuple, Any] = {}
        self._unpacks = []
        for es in self.entries:
            key = tuple((o, shape, str(dt), nb) for _i, _ix, o, shape, dt, nb in es)
            fn = by_plan.get(key)
            if fn is None:
                metas = [(o, shape, dt, nb) for _i, _ix, o, shape, dt, nb in es]

                def _unpack(flat, _metas=metas):
                    outs = []
                    for o, shape, dt, nb in _metas:
                        seg = lax.slice(flat, (o,), (o + nb,))
                        outs.append(_bitcast(seg, dt).reshape(shape))
                    return tuple(outs)

                fn = by_plan[key] = jax.jit(_unpack, donate_argnums=donate)
            self._unpacks.append(fn)

    @property
    def has_payload(self) -> bool:
        return bool(self.devices)

    def new_staging(self) -> list[np.ndarray]:
        return [np.empty((n,), np.uint8) for n in self.staging_bytes]

    def pack_into(self, leaves: list, stagings: list[np.ndarray]) -> list[np.ndarray]:
        for buf, es in zip(stagings, self.entries):
            for i, idx, off, shape, dtype, nbytes in es:
                dst = buf[off : off + nbytes].view(dtype).reshape(shape)
                np.copyto(dst, np.asarray(leaves[i])[idx], casting="same_kind")
        return stagings

    def put_staged(self, stagings: list[np.ndarray]) -> list:
        """One H2D transfer per device: the request-count collapse under a
        mesh is ``n_devices`` per group, not ``n_leaves x n_shards``."""
        return [jax.device_put(buf, d) for buf, d in zip(stagings, self.devices)]

    def any_alias(self, flats: list, stagings: list) -> bool:
        return any(_aliases_host(f, b) for f, b in zip(flats, stagings))

    def unpack(self, flats: list, src_leaves: list) -> Pytree:
        """Rebuild the group: per-device jitted unpack of each flat buffer,
        then per-leaf assembly onto its sharding (committed multi-device
        arrays, bitwise vs eager sharded placement)."""
        shards: dict[int, list] = {}
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            for flat, es, fn in zip(flats or [], self.entries, self._unpacks):
                for (i, *_), piece in zip(es, fn(flat)):
                    shards.setdefault(i, []).append(piece)
        out: list = [None] * self.n_leaves
        for i, shape, dtype, s in self.assembly:
            out[i] = jax.make_array_from_single_device_arrays(shape, s, shards[i])
        for i in self.passthrough_idx:
            out[i] = src_leaves[i]
        return jax.tree.unflatten(self.treedef, out)


# ---------------------------------------------------------------------------
# futures
# ---------------------------------------------------------------------------


def _retryable(e: BaseException) -> bool:
    """Faults the bounded-retry loops may absorb.  Corruption is excluded:
    its recovery path (re-read, durable-home rewrite) already ran inside
    :func:`repro.core.spillstore.verify_disk_leaf`, and retrying would just
    re-consume the same bad bytes.  :class:`HazardError` is excluded too:
    a retried hazard is a hidden hazard."""
    from repro.core.spillstore import SpillCorruptionError

    return not isinstance(
        e, (KeyboardInterrupt, SystemExit, SpillCorruptionError, HazardError)
    )


class TransferFuture:
    """Handle to one in-flight H2D group transfer."""

    __slots__ = (
        "index",
        "layout",
        "src_leaves",
        "n_requests",
        "nbytes",
        "n_devices",
        "disk_requests",
        "disk_nbytes",
        "disk_wait_s",
        "retries",
        "_event",
        "_flat",
        "_device_tree",
        "_error",
        "ready_at",
        "_group",
    )

    def __init__(self, index: int, layout: Optional[GroupLayout], src_leaves, n_requests: int, nbytes: int):
        self.index = index
        self.layout = layout
        self.src_leaves = src_leaves
        self.n_requests = n_requests
        self.nbytes = nbytes
        #: addressable devices this group stages onto (1 for default placement)
        self.n_devices = 1
        #: disk-tier accounting (zero for pure host/device groups)
        self.disk_requests = 0
        self.disk_nbytes = 0
        #: time the *transfer worker* blocked on the disk stage (stage-2-on-
        #: stage-1 stall; zero when the disk read-ahead window covers it)
        self.disk_wait_s = 0.0
        #: transient faults absorbed while staging this group (both stages)
        self.retries = 0
        self._event = threading.Event()
        self._flat = None
        self._device_tree = None
        self._error: Optional[BaseException] = None
        self.ready_at = 0.0
        self._group = None

    # -- worker side --------------------------------------------------------
    def _complete(self, *, flat=None, device_tree=None, ready_at=0.0, error=None):
        self._flat = flat
        self._device_tree = device_tree
        self.ready_at = ready_at
        self._error = error
        self._event.set()

    # -- compute side -------------------------------------------------------
    @property
    def is_resident(self) -> bool:
        """True iff this submit moved NOTHING across any link: every leaf
        was already a committed ``jax.Array`` (device-kind homes, and
        residency-cache hits) and nothing was disk-staged.  The executors'
        cache-hit/unique-fetch accounting keys off this."""
        return self.n_requests == 0 and self.disk_requests == 0

    def wait(self) -> float:
        """Block until the transfer has landed; returns the time the compute
        thread actually spent blocked (the paper's stall time)."""
        t0 = time.perf_counter()
        self._event.wait()
        if self._error is not None:
            raise self._error
        residual = self.ready_at - time.perf_counter()
        if residual > 0:  # emulated link latency tail
            _sleep_precise(residual)
        return time.perf_counter() - t0

    def group(self) -> Pytree:
        """The staged device-side group (unpacks the flat buffer once)."""
        if self._group is None:
            if self._device_tree is not None:
                self._group = self._device_tree
            else:
                self._group = self.layout.unpack(self._flat, self.src_leaves)
            self._flat = None  # donated/consumed — release our reference
            self.src_leaves = None
        return self._group


class _DiskFetchTicket:
    """Handle to one in-flight disk->host-staging fetch (pipeline stage 1).

    The disk worker copies each memory-mapped leaf into a pooled host
    staging buffer (the copy *is* the disk read) and publishes ndarray
    views; the transfer worker substitutes them for the mapped leaves
    before packing, then releases the buffer back to the pool.
    """

    __slots__ = ("sig", "idx", "n_requests", "nbytes", "retries", "_event",
                 "_error", "views", "buf", "ready_at")

    def __init__(self, sig: tuple, idx: list, n_requests: int, nbytes: int):
        self.sig = sig
        #: positions of the disk leaves in the group's flattened leaf list
        self.idx = idx
        self.n_requests = n_requests
        self.nbytes = nbytes
        #: transient disk-stage faults absorbed for this fetch
        self.retries = 0
        self._event = threading.Event()
        self._error: Optional[BaseException] = None
        self.views: Optional[list] = None
        self.buf: Optional[np.ndarray] = None
        self.ready_at = 0.0


class _WritebackTicket:
    __slots__ = ("index", "n_requests", "nbytes", "retries", "_event", "_host",
                 "_error", "ready_at", "key")

    def __init__(self, index: int, n_requests: int, nbytes: int, key=None):
        #: logical group key (sanitizer happens-before tracking); None when
        #: the submitter has no stable name for the group
        self.key = key
        self.index = index
        self.n_requests = n_requests
        self.nbytes = nbytes
        #: transient D2H faults absorbed for this writeback
        self.retries = 0
        self._event = threading.Event()
        self._host = None
        self._error: Optional[BaseException] = None
        self.ready_at = 0.0

    def result(self) -> Pytree:
        self._event.wait()
        if self._error is not None:
            raise self._error
        residual = self.ready_at - time.perf_counter()
        if residual > 0:
            _sleep_precise(residual)
        return self._host


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class TransferEngine:
    """Background host service moving groups between host and device.

    One worker thread owns all transfer work (pack, ``device_put``,
    ``device_get``, link emulation); the compute thread only submits work
    and waits on futures.  FIFO processing preserves submission order, so
    writebacks drain in group order by construction.
    """

    def __init__(self, config: Optional[EngineConfig] = None) -> None:
        self.config = config or EngineConfig()
        #: runtime hazard sanitizer (``EngineConfig(sanitize=True)`` /
        #: ``REPRO_SANITIZE=1``); None on the un-instrumented fast path
        self.sanitizer: Optional[HazardSanitizer] = (
            HazardSanitizer() if self.config.sanitize else None
        )
        self._tasks: "queue.SimpleQueue" = queue.SimpleQueue()
        self._worker: Optional[threading.Thread] = None
        self._layouts: dict[tuple, GroupLayout] = {}
        #: per-layout free list of reusable staging buffers (worker-owned)
        self._staging_free: dict[tuple, list[np.ndarray]] = {}
        #: total staging buffers ever allocated (reuse-efficiency metric)
        self.staging_allocs: int = 0
        self._pending_wb: list[_WritebackTicket] = []
        #: the emulated link is one serial resource: every transfer's
        #: occupancy — worker H2D/D2H *and* the executor's blocking D2H
        #: (seed schedule) — holds this lock for its duration
        self._link_lock = threading.Lock()
        # -- disk stage (DiskHost groups) -----------------------------------
        self._disk_tasks: "queue.SimpleQueue" = queue.SimpleQueue()
        self._disk_worker: Optional[threading.Thread] = None
        self._disk_layouts: dict[tuple, GroupLayout] = {}
        self._disk_free: dict[tuple, list[np.ndarray]] = {}
        #: fetched-but-unconsumed disk buffers; bounded by the read-ahead
        #: window so the disk stage cannot run unboundedly ahead of H2D
        self._disk_in_use = 0
        self._disk_cond = threading.Condition()
        self._disk_controller: Optional[AdaptiveDistance] = None
        self._disk_window = max(1, self.config.disk_slots)
        #: total disk staging buffers ever allocated (reuse metric)
        self.disk_staging_allocs: int = 0
        #: the (emulated) disk is its own serial resource
        self._disk_link_lock = threading.Lock()
        #: True when the last close() abandoned a live worker thread
        #: (failed join) — tests assert clean shutdown through this
        self.leaked_threads: bool = False
        #: executor AdaptiveDistance controllers fed by this engine, so
        #: external signals (straggler events) can widen every window
        self._controllers: list[AdaptiveDistance] = []

    # -- external window control --------------------------------------------
    def register_controller(self, ctrl: AdaptiveDistance) -> None:
        """Attach an executor's prefetch controller to this engine (the
        executor registers itself); :meth:`widen` then reaches it."""
        if ctrl not in self._controllers:
            self._controllers.append(ctrl)

    def widen(self, n: int = 1) -> list[int]:
        """Widen every registered prefetch window (and the disk read-ahead
        window) by ``n``.  The driver calls this on straggler events: a slow
        step buys more transfer overlap instead of only a log line.  Returns
        the new window sizes (observability)."""
        out = [c.boost(n) for c in self._controllers]
        with self._disk_cond:
            if self._disk_controller is not None:
                self._disk_window = self._disk_controller.boost(n)
            else:
                self._disk_window = min(
                    self._disk_window + max(1, n), self.config.disk_max_slots
                )
            out.append(self._disk_window)
            self._disk_cond.notify_all()
        return out

    # -- lifecycle -----------------------------------------------------------
    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._worker_loop, name="transfer-engine", daemon=True
            )
            self._worker.start()

    def _ensure_disk_worker(self) -> None:
        if self._disk_worker is None or not self._disk_worker.is_alive():
            self._disk_worker = threading.Thread(
                target=self._disk_worker_loop, name="transfer-engine-disk",
                daemon=True,
            )
            self._disk_worker.start()

    def close(self) -> None:
        """Stop the worker threads.  Not final: a later submit restarts the
        workers, so close() is "quiesce", matching the driver's restart loop
        (close at shutdown, resurrect transparently if reused).  Pending
        tasks — including in-flight disk fetches — drain before the workers
        exit, so no future is left unset.

        A worker that fails to join within ``close_timeout_s`` is *leaked*,
        not silently forgotten: it is logged loudly, surfaced on
        ``leaked_threads``, and its reference is kept so a later submit
        cannot start a duplicate consumer on the same queue."""
        timeout = self.config.close_timeout_s
        if self._disk_worker is not None and self._disk_worker.is_alive():
            self._disk_tasks.put(None)
        if self._worker is not None and self._worker.is_alive():
            self._tasks.put(None)
            self._worker.join(timeout=timeout)
        if self._disk_worker is not None and self._disk_worker.is_alive():
            self._disk_worker.join(timeout=timeout)
        leaked = [
            t.name
            for t in (self._worker, self._disk_worker)
            if t is not None and t.is_alive()
        ]
        self.leaked_threads = bool(leaked)
        if leaked:
            log.error(
                "TransferEngine.close(): worker thread(s) %s still alive "
                "after a %.1fs join — leaked (wedged transfer?); keeping "
                "their references so a later submit cannot spawn a "
                "duplicate consumer on the same queue",
                leaked,
                timeout,
            )
        if self._worker is not None and not self._worker.is_alive():
            self._worker = None
        if self._disk_worker is not None and not self._disk_worker.is_alive():
            self._disk_worker = None

    def __enter__(self) -> "TransferEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # pragma: no cover - defensive
        try:
            self.close()
        except Exception:
            pass

    # -- layout / staging ----------------------------------------------------
    def layout_for(self, group: Pytree) -> GroupLayout:
        return self._layout_for_sig(
            group_signature(group),
            lambda: GroupLayout(group, donate_flat=self.config.donate_flat),
        )

    def _layout_for_sig(self, sig: tuple, factory):
        """Layout cache: one layout + staging pool per signature (shared by
        the default-placement and sharded coalescing paths)."""
        lo = self._layouts.get(sig)
        if lo is None:
            lo = factory()
            self._layouts[sig] = lo
            self._staging_free[sig] = []
        return lo

    def _acquire_staging(self, sig: tuple, layout) -> Any:
        """Check a staging buffer (set) out of the layout's pool (worker
        thread) — one ndarray for default-placement layouts, one ndarray
        per addressable device for sharded layouts.

        Pops a recycled buffer when one is free, else allocates: the pool
        self-sizes to the worker's actual concurrency (1 buffer in the
        steady state, since the worker blocks each ``device_put``).
        """
        free = self._staging_free[sig]
        if free:
            staging = free.pop()
            if self.sanitizer is not None:
                self.sanitizer.on_staging_acquire(id(staging), from_pool=True)
            return staging
        self.staging_allocs += 1
        staging = layout.new_staging()
        if self.sanitizer is not None:
            self.sanitizer.on_staging_acquire(id(staging), from_pool=False)
        return staging

    def _release_staging(self, sig: tuple, staging: Any) -> None:
        if self.sanitizer is not None:
            self.sanitizer.on_staging_release(id(staging))
        free = self._staging_free[sig]
        if len(free) < max(1, self.config.staging_slots):
            free.append(staging)

    # -- disk stage pool (read-ahead window) --------------------------------
    def _disk_layout_for(self, dsig: tuple, disk_leaves: list) -> GroupLayout:
        lo = self._disk_layouts.get(dsig)
        if lo is None:
            lo = GroupLayout(tuple(disk_leaves), donate_flat=False)
            self._disk_layouts[dsig] = lo
            self._disk_free[dsig] = []
        return lo

    def _acquire_disk_staging(self, dsig: tuple, layout: GroupLayout) -> np.ndarray:
        """Check a host buffer out of the disk pool (disk worker thread).

        Blocks while ``window`` buffers are already fetched-but-unconsumed —
        this is the disk read-ahead throttle the disk-stage controller
        adjusts.  Progress is guaranteed: the transfer worker consumes
        tickets in submission order and releases each buffer after packing.
        """
        with self._disk_cond:
            while self._disk_in_use >= max(1, self._disk_window):
                self._disk_cond.wait(timeout=0.5)
            self._disk_in_use += 1
        try:
            free = self._disk_free[dsig]
            if free:
                return free.pop()
            self.disk_staging_allocs += 1
            return layout.new_staging()
        except BaseException:
            # allocation failed (e.g. MemoryError in a RAM-constrained run):
            # give the window slot back or the pipeline wedges permanently
            with self._disk_cond:
                self._disk_in_use -= 1
                self._disk_cond.notify_all()
            raise

    def _release_disk_staging(self, dsig: tuple, buf: np.ndarray) -> None:
        free = self._disk_free.get(dsig)
        if free is not None and len(free) < self.config.disk_max_slots:
            free.append(buf)
        with self._disk_cond:
            self._disk_in_use -= 1
            self._disk_cond.notify_all()

    def _observe_disk_wait(self, wait_s: float) -> None:
        """Feed the disk-stage controller one stage-2-on-stage-1 stall
        sample; widens/narrows the read-ahead window (transfer worker)."""
        if self._disk_controller is None:
            cfg = self.config
            self._disk_controller = AdaptiveDistance(
                initial=cfg.disk_slots,
                min_distance=1,
                max_distance=cfg.disk_max_slots,
                wait_eps_s=cfg.disk_wait_eps_s,
                shrink_after=cfg.disk_shrink_after,
            )
        new = self._disk_controller.observe(wait_s)
        if new != self._disk_window:
            with self._disk_cond:
                self._disk_window = new
                self._disk_cond.notify_all()

    # -- submission (compute thread) ----------------------------------------
    def _submit_disk_stage(self, sig: tuple, leaves: list, fut: TransferFuture):
        """Enqueue the stage-1 disk fetch for a group's disk-tier leaves
        (sharded and unsharded groups feed the disk worker identically);
        returns the ticket, or None when nothing is disk-resident."""
        from repro.core.spillstore import is_disk_leaf

        disk_idx = [i for i, x in enumerate(leaves) if is_disk_leaf(x)]
        if not disk_idx:
            return None
        disk_leaves = [leaves[i] for i in disk_idx]
        # one chunk file = one disk request (the store's coalescing)
        n_files = len({getattr(x, "filename", None) or id(x) for x in disk_leaves})
        # group_signature cannot tell a memmap from an ndarray, so the disk
        # layout must additionally key on *which* leaves are disk-resident
        dsig = ("disk", sig, tuple(disk_idx))
        dlayout = self._disk_layout_for(dsig, disk_leaves)
        ticket = _DiskFetchTicket(dsig, disk_idx, n_files, dlayout.payload_bytes)
        fut.disk_requests = n_files
        fut.disk_nbytes = dlayout.payload_bytes
        self._ensure_disk_worker()
        self._disk_tasks.put((ticket, disk_leaves))
        return ticket

    def submit_group(
        self, index: int, group: Pytree, *, device_shardings=None, key=None
    ) -> TransferFuture:
        """Queue the H2D transfer of one group; returns immediately.

        ``key`` is the group's logical name (plan group key, KV page id):
        the hazard sanitizer refuses a fetch whose key has a D2H writeback
        still in flight.  ``key=None`` transfers are unchecked — exactly
        the transfers the static analyzer cannot name either.

        Coalescing composes with explicit ``device_shardings``
        (multi-device layouts): the group stages through one buffer per
        addressable device — ``n_devices`` requests per group — and the
        committed leaves are assembled bitwise-equal to eager sharded
        placement (see :class:`ShardedGroupLayout`).  Only
        ``EngineConfig(coalesce=False)`` takes the per-leaf path, which
        costs one request per (leaf, addressable shard).

        Groups containing disk-tier leaves (spill-store memmaps, see
        :mod:`repro.core.spillstore`) additionally enqueue a stage-1 fetch
        on the disk worker; the H2D stage blocks on it per group, so the
        two stages pipeline across groups.
        """
        from repro.core.spillstore import is_disk_leaf

        if self.sanitizer is not None:
            self.sanitizer.on_fetch(key)
        leaves = jax.tree.leaves(group)
        sh_flat = None
        if device_shardings is not None:
            sh_flat = flatten_shardings(device_shardings, len(leaves))
        if self.config.coalesce:
            if sh_flat is None:
                sig = group_signature(group)
                layout = self._layout_for_sig(
                    sig,
                    lambda: GroupLayout(group, donate_flat=self.config.donate_flat),
                )
            else:
                sig = ("sharded", group_signature(group), tuple(sh_flat))
                layout = self._layout_for_sig(
                    sig,
                    lambda: ShardedGroupLayout(
                        group, sh_flat, donate_flat=self.config.donate_flat
                    ),
                )
            fut = TransferFuture(
                index, layout, leaves, layout.n_requests, layout.payload_bytes
            )
            fut.n_devices = layout.n_devices
            ticket = self._submit_disk_stage(sig, leaves, fut)
            self._ensure_worker()
            self._tasks.put(("h2d", fut, group, None, True, sig, ticket))
            return fut

        # per-leaf fallback (A/B baseline): one request per (host leaf,
        # addressable shard); disk-tier memmaps are read inline by
        # device_put (no stage-1 pipeline) but their traffic is accounted
        n_host = 0
        nbytes = 0
        n_devices = 1
        disk_files: set = set()
        disk_bytes = 0
        for j, x in enumerate(leaves):
            if isinstance(x, jax.Array):
                continue
            a = np.asarray(x)
            s = sh_flat[j] if sh_flat is not None else None
            if s is None:
                n_shards, shard_bytes = 1, a.size * a.dtype.itemsize
            else:
                imap = s.addressable_devices_indices_map(a.shape)
                n_shards = len(imap)
                n_devices = max(n_devices, n_shards)
                shard_bytes = sum(
                    int(np.prod(_shard_shape(a.shape, idx), dtype=np.int64))
                    * a.dtype.itemsize
                    for idx in imap.values()
                )
            n_host += n_shards
            nbytes += shard_bytes
            if is_disk_leaf(x):
                disk_files.add(getattr(x, "filename", None) or id(x))
                disk_bytes += a.size * a.dtype.itemsize
        fut = TransferFuture(index, None, leaves, n_host, nbytes)
        fut.n_devices = n_devices
        fut.disk_requests = len(disk_files)
        fut.disk_nbytes = disk_bytes
        self._ensure_worker()
        self._tasks.put(("h2d", fut, group, sh_flat, False, None, None))
        return fut

    def submit_writeback(
        self, index: int, group_out: Pytree, *, key=None
    ) -> _WritebackTicket:
        """Queue the D2H copy of an ``rw`` group's output; returns
        immediately.  ``key`` names the group for the hazard sanitizer:
        until the ticket drains, a same-key fetch is a RAW hazard."""
        leaves = jax.tree.leaves(group_out)
        nbytes = sum(x.size * x.dtype.itemsize for x in leaves)
        ticket = _WritebackTicket(index, len(leaves), nbytes, key=key)
        self._pending_wb.append(ticket)
        if self.sanitizer is not None:
            self.sanitizer.on_writeback(key)
        self._ensure_worker()
        self._tasks.put(("d2h", ticket, group_out))
        return ticket

    def drain_writebacks(self) -> list:
        """Wait for every pending writeback; returns host groups in group
        order (FIFO worker + ordered tickets ⇒ paper's per-device ordering)."""
        tickets = sorted(self._pending_wb, key=lambda t: t.index)
        self._pending_wb = []
        out = [t.result() for t in tickets]
        if self.sanitizer is not None:
            # only reached when every result landed: a failed drain keeps
            # its keys pending, so a restart must discard before re-fetching
            self.sanitizer.on_drained([t.key for t in tickets])
        return out

    def discard_writebacks(self) -> int:
        """Drop any pending writeback tickets (a failed run may have left
        some behind; the next run must not drain stale groups).  Returns
        the number discarded."""
        n = len(self._pending_wb)
        if self.sanitizer is not None:
            self.sanitizer.on_drained([t.key for t in self._pending_wb])
        self._pending_wb = []
        return n

    # -- worker thread -------------------------------------------------------
    def _retry_loop(self, op, counter, what: str):
        """Run ``op()`` with bounded retry + exponential backoff.

        A transient fault re-runs ``op`` from its intact inputs (host
        arrays, disk staging views), incrementing ``counter.retries``;
        attempt exhaustion (or a non-retryable fault) re-raises so the
        waiter sees the permanent error."""
        attempts = max(1, self.config.max_attempts)
        for attempt in range(attempts):
            try:
                return op()
            except BaseException as e:  # noqa: BLE001 — bounded, re-raised
                if attempt + 1 >= attempts or not _retryable(e):
                    raise
                counter.retries += 1
                log.warning(
                    "transient %s fault (attempt %d/%d), backing off: %s",
                    what, attempt + 1, attempts, e,
                )
                _sleep_precise(self.config.retry_backoff_s * (2.0 ** attempt))
        raise AssertionError("unreachable")  # pragma: no cover

    def _worker_loop(self) -> None:
        link = self.config.link
        while True:
            task = self._tasks.get()
            if task is None:
                return
            kind = task[0]
            try:
                if kind == "h2d":
                    _, fut, group, shardings, coalesce, sig, ticket = task
                    if coalesce:
                        src_leaves = fut.src_leaves
                        disk_buf = None
                        if ticket is not None:
                            # stage-2-on-stage-1 wait: zero once the disk
                            # read-ahead window covers the disk latency
                            t0 = time.perf_counter()
                            ticket._event.wait()
                            if ticket._error is not None:
                                raise ticket._error
                            residual = ticket.ready_at - time.perf_counter()
                            if residual > 0:
                                _sleep_precise(residual)
                            fut.disk_wait_s = time.perf_counter() - t0
                            self._observe_disk_wait(fut.disk_wait_s)
                            fut.retries += ticket.retries
                            src_leaves = list(src_leaves)
                            for i, view in zip(ticket.idx, ticket.views):
                                src_leaves[i] = view
                            disk_buf = ticket.buf
                        try:
                            layout = fut.layout
                            if layout.has_payload:
                                # the disk buffer is held across attempts
                                # (src_leaves view into it), so a retried
                                # pack re-reads intact staged bytes
                                flat = self._retry_loop(
                                    lambda: self._put_coalesced(sig, layout, src_leaves),
                                    fut, f"H2D (group {fut.index})",
                                )
                            else:  # everything already device-resident
                                flat = None
                        finally:
                            if disk_buf is not None:
                                # packed (or failed): the disk buffer's bytes
                                # are no longer needed either way
                                self._release_disk_staging(ticket.sig, disk_buf)
                        ready_at = self._emulate(link, fut.n_requests, fut.nbytes)
                        fut._complete(flat=flat, ready_at=ready_at)
                    else:
                        tree = self._retry_loop(
                            lambda: self._put_per_leaf(group, shardings),
                            fut, f"H2D per-leaf (group {fut.index})",
                        )
                        ready_at = self._emulate(link, fut.n_requests, fut.nbytes)
                        fut._complete(device_tree=tree, ready_at=ready_at)
                elif kind == "d2h":
                    _, ticket, group_out = task
                    host = self._retry_loop(
                        lambda: jax.device_get(group_out),
                        ticket, f"D2H (group {ticket.index})",
                    )
                    ready_at = self._emulate(link, ticket.n_requests, ticket.nbytes)
                    ticket.ready_at = ready_at
                    ticket._host = host
                    ticket._event.set()
            except BaseException as e:  # noqa: BLE001 — surface on the waiter
                obj = task[1]
                obj._error = e
                obj._event.set()

    def _put_coalesced(self, sig: tuple, layout, src_leaves):
        """One attempt of the coalesced H2D: stage, put, block.  On a fault
        the staging buffer is dropped, not recycled — a half-issued put may
        still alias it; the pool reallocates on the next attempt."""
        staging = self._acquire_staging(sig, layout)
        layout.pack_into(src_leaves, staging)
        flat = layout.put_staged(staging)
        jax.block_until_ready(flat)
        if not layout.any_alias(flat, staging):
            # the device holds its own copy: recycle now
            self._release_staging(sig, staging)
        return flat

    def _put_per_leaf(self, group, shardings):
        """One attempt of the per-leaf fallback H2D."""
        if shardings is not None:
            # per-leaf fallback under explicit placements:
            # one device_put per leaf (None -> default)
            leaves, treedef = jax.tree.flatten(group)
            tree = jax.tree.unflatten(treedef, [
                jax.device_put(x, s) if s is not None
                else (x if isinstance(x, jax.Array) else jax.device_put(x))
                for x, s in zip(leaves, shardings)
            ])
        else:
            tree = jax.device_put(group)
        jax.block_until_ready(tree)
        return tree

    # -- disk worker thread (pipeline stage 1) ------------------------------
    def _disk_worker_loop(self) -> None:
        from repro.core.spillstore import verify_disk_leaf

        link = self.config.disk_link
        while True:
            task = self._disk_tasks.get()
            if task is None:
                return
            ticket, disk_leaves = task
            attempts = max(1, self.config.max_attempts)
            for attempt in range(attempts):
                buf = None
                try:
                    layout = self._disk_layouts[ticket.sig]
                    if self.config.verify_spill:
                        # CRC-check the mapped chunk bytes before consuming
                        # them; a mismatch re-fetches from the durable home
                        # or surfaces a rich SpillCorruptionError — corrupt
                        # bytes never reach the optimizer
                        disk_leaves = [verify_disk_leaf(x) for x in disk_leaves]
                    buf = self._acquire_disk_staging(ticket.sig, layout)
                    # the copy out of the memory-mapped view IS the disk read
                    layout.pack_into(disk_leaves, buf)
                    views = [
                        buf[o : o + nb].view(dt).reshape(shape)
                        for _, o, shape, dt, nb in layout.metas
                    ]
                    ticket.ready_at = self._emulate(
                        link, ticket.n_requests, ticket.nbytes,
                        lock=self._disk_link_lock,
                    )
                    ticket.views = views
                    ticket.buf = buf
                    ticket._event.set()
                    break
                except BaseException as e:  # noqa: BLE001 — retry or surface
                    if buf is not None:
                        # give the window slot back between attempts or the
                        # read-ahead throttle counts phantom buffers
                        self._release_disk_staging(ticket.sig, buf)
                    if attempt + 1 >= attempts or not _retryable(e):
                        ticket._error = e
                        ticket._event.set()
                        break
                    ticket.retries += 1
                    log.warning(
                        "transient disk-stage fault (attempt %d/%d), "
                        "backing off: %s",
                        attempt + 1, attempts, e,
                    )
                    _sleep_precise(self.config.retry_backoff_s * (2.0 ** attempt))

    def _emulate(
        self,
        link: Optional[LinkModel],
        n_requests: int,
        nbytes: int,
        *,
        lock: Optional[threading.Lock] = None,
    ) -> float:
        """Hold the emulated link for the transfer's occupancy (sleep under
        the link's serial lock) and return the completion timestamp
        including the overlappable latency tail."""
        if link is None or n_requests == 0:
            return 0.0
        occ = link.occupancy_s(n_requests, nbytes)
        if occ > 0:
            with (lock if lock is not None else self._link_lock):
                _sleep_precise(occ)
        return time.perf_counter() + link.latency_s

    def emulate_blocking_transfer(self, n_requests: int, nbytes: int) -> None:
        """Pay the emulated link for a transfer issued *on the caller's
        thread* (the seed schedule's blocking ``device_get`` write-back).
        No-op without a link model."""
        ready_at = self._emulate(self.config.link, n_requests, nbytes)
        residual = ready_at - time.perf_counter()
        if residual > 0:
            _sleep_precise(residual)

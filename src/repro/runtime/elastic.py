"""Elastic re-meshing: resume a job on a different device count.

Checkpoints store unsharded leaves (see ``repro.checkpoint``), so elasticity
reduces to choosing a new mesh and re-deriving shardings from the same
logical rules.  Policy: keep the model axis (TP degree is an architectural
choice — it must divide heads/ffn), shrink/grow the data axis; drop the pod
axis when only one pod survives.
"""
from __future__ import annotations

from typing import Optional


def elastic_mesh_shape(
    n_devices: int,
    *,
    model: int = 16,
    prefer_pods: bool = True,
    pod_size: Optional[int] = None,
) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest (pod, data, model) shape that fits ``n_devices``.

    >>> elastic_mesh_shape(512, model=16)      # healthy 2-pod job
    ((2, 16, 16), ('pod', 'data', 'model'))
    >>> elastic_mesh_shape(480, model=16)      # lost 2 hosts (8 chips each)
    ((30, 16), ('data', 'model'))
    >>> elastic_mesh_shape(256, model=16)
    ((16, 16), ('data', 'model'))
    """
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if n_devices < model:
        raise ValueError(
            f"{n_devices} devices cannot host a model axis of {model}"
        )
    if n_devices % model != 0:
        raise ValueError(f"{n_devices} devices not divisible by model={model}")
    rest = n_devices // model
    if prefer_pods and pod_size:
        chips_per_pod = pod_size
        # a pod must hold whole model groups, or the (pod, data, model)
        # product silently loses devices (pod_size=24, model=16 used to
        # yield a 32-device mesh for 48 devices)
        if (
            chips_per_pod % model == 0
            and chips_per_pod >= model
            and n_devices % chips_per_pod == 0
            and n_devices // chips_per_pod > 1
        ):
            pods = n_devices // chips_per_pod
            data = chips_per_pod // model
            return (pods, data, model), ("pod", "data", "model")
    if prefer_pods and rest % 16 == 0 and rest // 16 > 1:
        return (rest // 16, 16, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")

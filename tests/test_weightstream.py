"""Streamed-parameters tests (ISSUE 5 tentpole).

Pins the weight-streaming contract at unit and integration level:
  * plan partition invariants (layer coverage, byte model, budget guards),
  * streamed train step bitwise-equal to the device-resident run for
    pinned_host and disk_host homes,
  * streamed prefill/decode bitwise-equal to the monolithic executables,
  * checkpoint round trip of host- AND disk-homed states (memmap leaves
    saved by reference, restore template via eval_shape),
  * exactly one coalesced H2D request per fetched (device, group) and the
    device-budget cap on the engine's prefetch window.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.engine import EngineConfig, TransferEngine
from repro.core.refspec import PrefetchSpec
from repro.core.spillstore import SpillStore, is_disk_leaf
from repro.core.weightstream import WeightStreamPlan, weight_stream_supported
from repro.data.synthetic import SyntheticConfig, synthetic_batch
from repro.optim.adamw import AdamWConfig
from repro.train import steps as st


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_smoke_config("smollm-360m"), n_layers=4)


@pytest.fixture(scope="module")
def plan(cfg):
    return WeightStreamPlan(cfg, st.abstract_params(cfg), layers_per_group=2)


@pytest.fixture(scope="module")
def opt_cfg():
    return AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=32)


def _batch(cfg, step=0):
    return synthetic_batch(cfg, SyntheticConfig(cfg.vocab_size, 16, 2, seed=0), step)


# ---------------------------------------------------------------------------
# plan invariants
# ---------------------------------------------------------------------------


def test_plan_partitions_every_layer_exactly_once(cfg, plan):
    covered = []
    for g in plan.layer_groups:
        covered.extend(range(g.lo, g.hi))
    assert covered == list(range(cfg.n_layers))
    assert plan.groups[0].kind == "embed" and plan.groups[-1].kind == "head"
    # tied embeddings: the head fetch group re-reads the embed table
    assert plan.head_reads_embed
    params, _ = st.init_train_state(jax.random.PRNGKey(0), cfg)
    fetch = plan.fetch_group(plan.init_home(params), plan.groups[-1])
    assert "embed" in fetch and "ln_f" in fetch


def test_plan_byte_model_and_budget_guards(cfg):
    abs_p = st.abstract_params(cfg)
    plan = WeightStreamPlan(cfg, abs_p, layers_per_group=1)
    # peak is monotone in distance and bounded by the full fetch sequence
    peaks = [plan.peak_device_bytes(d) for d in range(0, 6)]
    assert peaks == sorted(peaks)
    assert peaks[-1] <= sum(plan.fetch_sequence_bytes())
    # a budget below the distance-1 peak is rejected outright
    with pytest.raises(ValueError, match="cannot hold"):
        WeightStreamPlan(cfg, abs_p, layers_per_group=1, device_budget_mb=1e-6)
    # the window cap keeps the modeled peak under the budget
    budget_mb = plan.peak_device_bytes(2) / 1e6
    capped = WeightStreamPlan(
        cfg, abs_p, layers_per_group=1, device_budget_mb=budget_mb
    )
    d = capped.max_distance_for_budget()
    assert capped.peak_device_bytes(d) <= capped.device_budget_bytes


def test_plan_rejects_unsupported_arch():
    rg = get_smoke_config("recurrentgemma-2b")
    assert not weight_stream_supported(rg)
    with pytest.raises(ValueError, match="uniform"):
        WeightStreamPlan(rg, st.abstract_params(rg))


def test_home_assemble_roundtrip(cfg, plan):
    params, _ = st.init_train_state(jax.random.PRNGKey(0), cfg)
    home = plan.init_home(params)
    back = plan.assemble(home)
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_flatten_with_path(back)[0],
    ):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# streamed train step: bitwise vs the device-resident run
# ---------------------------------------------------------------------------


def _device_state(plan, state):
    return {
        "params": plan.device_home(state["params"]),
        "opt": {
            "groups": jax.device_put(state["opt"]["groups"]),
            "step": state["opt"]["step"],
        },
    }


def _run_steps(cfg, opt_cfg, plan, kind, n=2, store=None, distance="auto"):
    step = st.make_weight_streamed_train_step(
        cfg, opt_cfg, plan=plan, param_kind=kind, spill_store=store,
        prefetch=PrefetchSpec(buffer_size=plan.n_groups + 2, distance=distance),
    )
    state = st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    if kind == "device":
        state = _device_state(plan, state)
    elif kind == "disk_host":
        state = st.spill_weight_streamed_state(plan, state, store)
    losses = []
    try:
        for k in range(n):
            state, m = step(state, _batch(cfg, k))
            losses.append(float(m["loss"]))
    finally:
        stats = step.param_stats
        step.close()
    return losses, state, stats


def test_streamed_train_bitwise_vs_device(cfg, opt_cfg, plan):
    ref_losses, ref_state, _ = _run_steps(cfg, opt_cfg, plan, "device")
    losses, state, stats = _run_steps(cfg, opt_cfg, plan, "pinned_host")
    assert losses == ref_losses
    for key in ref_state["params"]["groups"]:
        for a, b in zip(
            jax.tree.leaves(state["params"]["groups"][key]),
            jax.tree.leaves(ref_state["params"]["groups"][key]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # exactly one coalesced H2D request per FETCHED (device, group); the
    # residency cache (unbounded here — no budget) makes every non-first
    # visit a resident pass-through, so total link traffic is well below
    # one request per consumed group
    h2d = stats.per_tier()["h2d"]
    assert h2d["requests_per_fetched_device_group"] == 1.0
    assert stats.h2d_requests == stats.unique_group_fetches > 0
    assert stats.cache_hits > 0
    assert stats.h2d_requests < stats.n_groups


def test_streamed_train_disk_home_bitwise_and_writes_back(cfg, opt_cfg, plan):
    ref_losses, _, _ = _run_steps(cfg, opt_cfg, plan, "device")
    with tempfile.TemporaryDirectory() as d:
        store = SpillStore(d, ephemeral=True)
        losses, state, stats = _run_steps(
            cfg, opt_cfg, plan, "disk_host", store=store
        )
        assert losses == ref_losses
        # updated params and moments went back to their disk home
        assert plan.is_spilled(state["params"])
        assert all(
            is_disk_leaf(v)
            for v in jax.tree.leaves(state["opt"]["groups"])
        )
        # one spill chunk per fetch -> one disk request per non-head group,
        # two for the tied head fetch (head home + embed table chunks)
        assert stats.disk_requests > 0
        store.close()


@pytest.mark.parametrize("distance", [0, 1])
def test_streamed_train_static_distances_bitwise(cfg, opt_cfg, plan, distance):
    ref_losses, _, _ = _run_steps(cfg, opt_cfg, plan, "device")
    losses, _, _ = _run_steps(
        cfg, opt_cfg, plan, "pinned_host", distance=distance
    )
    assert losses == ref_losses


# ---------------------------------------------------------------------------
# streamed prefill / decode vs the monolithic executables
# ---------------------------------------------------------------------------


def test_streamed_prefill_decode_match_monolithic(cfg, plan):
    params, _ = st.init_train_state(jax.random.PRNGKey(0), cfg)
    home = plan.init_home(params)
    tokens = jnp.asarray(
        np.pad(np.arange(1, 9, dtype=np.int32)[None, :], ((0, 0), (0, 8)))
    )
    with TransferEngine() as eng:
        prefill = st.make_weight_streamed_prefill_step(
            cfg, plan, 1, 16, engine=eng
        )
        decode = st.make_weight_streamed_decode_step(
            cfg, plan, engine=eng, paged=False
        )
        logits, caches = prefill(home, {"tokens": tokens})
        ref_prefill = jax.jit(st.make_prefill_step(cfg, 1, 16))
        rl, rc = ref_prefill(params, {"tokens": tokens})
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(rl))
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(rc)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        step_tok = {"tokens": jnp.asarray([[5]], jnp.int32)}
        pos = jnp.asarray([8], jnp.int32)
        l1, c1 = decode(home, caches, step_tok, pos)
        ref_decode = jax.jit(st.make_decode_step(cfg))
        l2, c2 = ref_decode(params, rc, step_tok, pos)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# checkpointing host- and disk-homed states
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_host_home(cfg, opt_cfg, plan, tmp_path):
    _, state, _ = _run_steps(cfg, opt_cfg, plan, "pinned_host", n=1)
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(0, state, blocking=True)
    template = jax.eval_shape(
        lambda: st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    )
    step, restored = mgr.restore(template)
    assert step == 0
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip_disk_home_memmap_leaves(cfg, opt_cfg, plan, tmp_path):
    """Disk-homed states checkpoint without materializing the tree: the
    memmap leaves are snapshotted by reference and serialized leaf-wise;
    restore hands back plain host arrays that re-spill bitwise."""
    with tempfile.TemporaryDirectory() as d:
        store = SpillStore(d, ephemeral=True)
        _, state, _ = _run_steps(cfg, opt_cfg, plan, "disk_host", n=1, store=store)
        assert any(is_disk_leaf(x) for x in jax.tree.leaves(state["params"]))
        mgr = CheckpointManager(tmp_path, keep=2)
        mgr.save(3, state, blocking=True)
        template = jax.eval_shape(
            lambda: st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
        )
        step, restored = mgr.restore(template)
        assert step == 3
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the trainer's restore path: re-impose the disk home bitwise
        respilled = st.spill_weight_streamed_state(plan, restored, store)
        assert plan.is_spilled(respilled["params"])
        for a, b in zip(jax.tree.leaves(respilled), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        store.close()


def test_engine_window_capped_by_budget(cfg, opt_cfg):
    """The adaptive controller can never stream past the device budget:
    the engine's max_distance comes from the plan's byte model."""
    abs_p = st.abstract_params(cfg)
    free = WeightStreamPlan(cfg, abs_p, layers_per_group=1)
    budget_mb = free.peak_device_bytes(1) / 1e6
    plan = WeightStreamPlan(
        cfg, abs_p, layers_per_group=1, device_budget_mb=budget_mb
    )
    cap = plan.max_distance_for_budget()
    assert cap < 8  # the budget actually bites
    engine = TransferEngine(EngineConfig(max_distance=cap))
    step = st.make_weight_streamed_train_step(
        cfg, opt_cfg, plan=plan, param_kind="pinned_host", engine=engine,
    )
    state = st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    try:
        for k in range(2):
            state, _ = step(state, _batch(cfg, k))
        assert step.param_stats.peak_inflight_bytes <= plan.device_budget_bytes
        if step.param_stats.distance_trace:
            assert max(step.param_stats.distance_trace) <= cap
    finally:
        step.close()
        engine.close()


# ---------------------------------------------------------------------------
# review-fix pins
# ---------------------------------------------------------------------------


def test_auto_group_sizing_uses_real_window_peak(cfg):
    """Bugfix pin: the auto fit once modeled the peak as big + 2*lpg*layer
    and could pick a layers_per_group whose true distance-1 sliding window
    (3 consecutive layer groups) blew the budget, making the constructor
    raise 'raise the budget' even though a smaller group size fit."""
    big_cfg = dataclasses.replace(cfg, n_layers=12)
    abs_p = st.abstract_params(big_cfg)
    free = WeightStreamPlan(big_cfg, abs_p, layers_per_group=1)
    budget_mb = (
        max(free.embed_bytes, free.head_fetch_bytes) + 8 * free.per_layer_bytes
    ) / 1e6
    plan = WeightStreamPlan(big_cfg, abs_p, device_budget_mb=budget_mb)
    assert plan.peak_device_bytes(1) <= plan.device_budget_bytes


def test_groupwise_init_matches_monolithic_init(cfg, plan):
    """Group-wise init (one transfer group device-resident at a time) must
    be bitwise-identical to homing init_train_state: same per-layer keys,
    same cast — and the AdamW masters keep the full f32 init values."""
    params_f32 = st.transformer.init_model(jax.random.PRNGKey(3), cfg)
    params = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), params_f32)
    ref_home = plan.init_home(params)
    state = st.init_weight_streamed_state(jax.random.PRNGKey(3), cfg, plan)
    for g in plan.groups:
        for a, b in zip(
            jax.tree.leaves(state["params"]["groups"][g.key]),
            jax.tree.leaves(ref_home["groups"][g.key]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # masters are the f32 init values, not a round trip through bf16
        ref_f32 = plan.home_group(params_f32, g)
        flat_ref = jax.tree.leaves(ref_f32)
        flat_opt = jax.tree.flatten(
            state["opt"]["groups"][g.key],
            is_leaf=lambda x: isinstance(x, dict) and "master" in x,
        )[0]
        for r, o in zip(flat_ref, flat_opt):
            np.testing.assert_array_equal(np.asarray(o["master"]), np.asarray(r))
            assert o["master"].dtype == np.float32


def test_loose_external_engine_rejected_under_budget(cfg, opt_cfg):
    abs_p = st.abstract_params(cfg)
    free = WeightStreamPlan(cfg, abs_p, layers_per_group=1)
    budget_mb = free.peak_device_bytes(1) / 1e6
    plan = WeightStreamPlan(
        cfg, abs_p, layers_per_group=1, device_budget_mb=budget_mb
    )
    loose = TransferEngine(EngineConfig(max_distance=8))
    try:
        with pytest.raises(ValueError, match="window cap"):
            st.make_weight_streamed_train_step(
                cfg, opt_cfg, plan=plan, param_kind="pinned_host", engine=loose
            )
    finally:
        loose.close()

"""Runtime-layer tests: checkpointing, fault tolerance, elastic, stragglers,
optimizer, data determinism, gradient compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, strategies as hst

from repro import jaxcompat
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import SyntheticConfig, synthetic_batch
from repro.optim import grad_compress as gc
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.runtime.elastic import elastic_mesh_shape
from repro.runtime.straggler import StragglerMonitor


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32), "c": jnp.ones(())},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(3, t, blocking=True)
    step, restored = mgr.restore(jax.eval_shape(lambda: _tree()))
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip_bf16(tmp_path):
    """Extension dtypes serialize as raw void in npy; restore must re-view
    them through meta.json (bf16 params resume — found by verification)."""
    mgr = CheckpointManager(tmp_path, keep=2)
    t = {"w": jnp.linspace(-2, 2, 16).astype(jnp.bfloat16)}
    mgr.save(1, t, blocking=True)
    _, restored = mgr.restore(jax.eval_shape(lambda: t))
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["w"], np.float32), np.asarray(t["w"], np.float32)
    )
    jnp.asarray(restored["w"])  # must be a valid JAX input


def test_checkpoint_keep_k_prunes(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree(), blocking=True)
    assert not list(tmp_path.glob("*.tmp"))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(7, _tree())
    mgr.wait()
    assert mgr.latest_step() == 7


def test_checkpoint_overwrite_crash_window_keeps_old_copy(tmp_path, monkeypatch):
    """A crash at the commit rename while overwriting a step must not lose
    the previous copy (the seed rmtree'd it *before* the rename)."""
    import pathlib

    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree(0), blocking=True)

    real_rename = pathlib.Path.rename

    def boom(self, target):
        if self.name.endswith(".tmp"):
            raise OSError("injected crash at commit")
        return real_rename(self, target)

    monkeypatch.setattr(pathlib.Path, "rename", boom)
    with pytest.raises(OSError, match="injected"):
        mgr.save(1, _tree(1), blocking=True)
    monkeypatch.undo()

    step, restored = mgr.restore(jax.eval_shape(lambda: _tree()))
    assert step == 1
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(_tree(0))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not list(tmp_path.glob("*.old"))  # rolled back, nothing dangling


def test_checkpoint_init_sweeps_stale_tmp_and_recovers_old(tmp_path):
    """Leftovers of a crashed save: partial .tmp dirs are deleted on init;
    a .old whose commit never landed is restored as the step."""
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, _tree(5), blocking=True)
    (tmp_path / "step_00000007.tmp").mkdir()
    old = tmp_path / "step_00000003.old"
    old.mkdir()
    mgr2 = CheckpointManager(tmp_path, keep=3)
    assert not (tmp_path / "step_00000007.tmp").exists()
    assert (tmp_path / "step_00000003").exists()  # crash-window recovery
    assert mgr2.all_steps() == [3, 5]


def test_checkpoint_keep_zero_keeps_all_negative_rejected(tmp_path):
    with pytest.raises(ValueError):
        CheckpointManager(tmp_path / "bad", keep=-1)
    mgr = CheckpointManager(tmp_path, keep=0)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.all_steps() == [1, 2, 3, 4]  # keep=0: keep all, documented


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore places leaves onto a *different* sharding (elastic resume)."""
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t, blocking=True)
    mesh = jaxcompat.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()), t
    )
    _, restored = mgr.restore(jax.eval_shape(lambda: _tree()), shardings=sh)
    assert all(x.sharding.mesh.shape == {"data": 1} for x in jax.tree.leaves(restored))


# ---------------------------------------------------------------------------
# fault-tolerant driver
# ---------------------------------------------------------------------------

def _driver(tmp_path, fail_at=None, steps=12, every=4):
    cfg = get_smoke_config("smollm-360m")
    from repro.train import steps as st
    from repro.models import transformer

    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=steps)
    step_raw = st.make_train_step(cfg, opt_cfg)
    jitted = jax.jit(step_raw)

    def step_fn(state, batch):
        p, o, m = jitted(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    sc = SyntheticConfig(cfg.vocab_size, 16, 2, seed=1)

    def make_batch(i):
        return synthetic_batch(cfg, sc, i)

    def init_state():
        p, o = st.init_train_state(jax.random.PRNGKey(0), cfg)
        return {"params": p, "opt": o}

    dcfg = DriverConfig(
        total_steps=steps,
        checkpoint_every=every,
        checkpoint_dir=str(tmp_path),
        log_every=0,
        max_restarts=3,
    )
    return TrainDriver(dcfg, step_fn, make_batch, init_state, fail_at=fail_at)


@pytest.mark.slow
def test_driver_trains_and_checkpoints(tmp_path):
    d = _driver(tmp_path / "a")
    state = d.run()
    assert d.ckpt.latest_step() is not None
    losses = [h["loss"] for h in d.history]
    assert all(np.isfinite(losses))


@pytest.mark.slow
def test_driver_restart_replays_identical_losses(tmp_path):
    """Node-failure recovery: inject a failure; the restarted run must
    produce bit-identical loss at each step vs an unfailed run."""
    d_ok = _driver(tmp_path / "ok")
    d_ok.run()
    ok_losses = {h["step"]: h["loss"] for h in d_ok.history}

    d_fail = _driver(tmp_path / "fail", fail_at={6})
    d_fail.run()
    assert d_fail.restarts == 1
    fail_losses = {}
    for h in d_fail.history:  # later entries overwrite replayed steps
        fail_losses[h["step"]] = h["loss"]
    for s in ok_losses:
        assert abs(ok_losses[s] - fail_losses[s]) < 1e-5, (s, ok_losses[s], fail_losses[s])


def test_driver_gives_up_after_max_restarts(tmp_path):
    d = _driver(tmp_path / "x", fail_at={2, 3, 4, 5, 6})
    d.cfg = DriverConfig(
        total_steps=8, checkpoint_every=100, checkpoint_dir=str(tmp_path / "x"),
        log_every=0, max_restarts=2,
    )
    with pytest.raises(RuntimeError):
        d.run()


# ---------------------------------------------------------------------------
# elastic / straggler
# ---------------------------------------------------------------------------

def test_elastic_mesh_shapes():
    assert elastic_mesh_shape(512, model=16) == ((2, 16, 16), ("pod", "data", "model"))
    assert elastic_mesh_shape(256, model=16) == ((16, 16), ("data", "model"))
    shape, axes = elastic_mesh_shape(480, model=16)  # lost 2 hosts
    assert np.prod(shape) == 480 and axes[-1] == "model"
    with pytest.raises(ValueError):
        elastic_mesh_shape(100, model=16)


def test_straggler_monitor_flags_outlier():
    m = StragglerMonitor(window=32, z_threshold=6.0)
    import time as _t

    for i in range(12):
        m.start_step(i)
        m.end_step()
    # fake a straggling step by injecting window values
    m.window.clear()
    m.window.extend([0.010] * 20)
    m.start_step(99)
    _t.sleep(0.08)
    ev = m.end_step()
    assert ev is not None and ev.step == 99


def test_straggler_deadline():
    m = StragglerMonitor(deadline_s=0.01)
    import time as _t

    m.start_step(0)
    _t.sleep(0.02)
    assert m.check_deadline()


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic_loss():
    w = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(w)
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=100, weight_decay=0.0)
    params = w
    for _ in range(60):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(cfg, g, opt, compute_dtype=jnp.float32)
    assert float(jnp.sum(params["w"] ** 2)) < 0.2


def test_adamw_grad_clip():
    w = {"w": jnp.ones(4) * 100}
    opt = adamw_init(w)
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10, grad_clip=1.0)
    g = {"w": jnp.ones(4) * 1e6}
    _, _, m = adamw_update(cfg, g, opt)
    assert float(m["grad_norm"]) > 1.0  # raw norm reported


def test_cosine_schedule_shape():
    lr0 = cosine_schedule(jnp.asarray(0), peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr_peak = cosine_schedule(jnp.asarray(10), peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr_end = cosine_schedule(jnp.asarray(100), peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr0) == 0.0
    assert abs(float(lr_peak) - 1.0) < 1e-6
    assert abs(float(lr_end) - 0.1) < 1e-6


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_batches_deterministic():
    cfg = get_smoke_config("olmo-1b")
    sc = SyntheticConfig(cfg.vocab_size, 32, 4, seed=3)
    a = synthetic_batch(cfg, sc, 17)
    b = synthetic_batch(cfg, sc, 17)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = synthetic_batch(cfg, sc, 18)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_prefetch_loader_order_and_restart():
    cfg = get_smoke_config("olmo-1b")
    sc = SyntheticConfig(cfg.vocab_size, 16, 2, seed=0)
    loader = PrefetchLoader(lambda s: synthetic_batch(cfg, sc, s), distance=2)
    b3 = loader(3)
    b4 = loader(4)
    # restart back at step 3: identical batch
    loader2 = PrefetchLoader(lambda s: synthetic_batch(cfg, sc, s), distance=2)
    b3r = loader2(3)
    np.testing.assert_array_equal(np.asarray(b3["tokens"]), np.asarray(b3r["tokens"]))


# ---------------------------------------------------------------------------
# gradient compression (property: error feedback closes the loop)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(hst.integers(0, 2 ** 31 - 1))
def test_int8_error_feedback_identity(seed):
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (16,)) * 10.0}
    q, s, err = gc.compress_int8(g)
    back = gc.decompress_int8(q, s)
    np.testing.assert_allclose(
        np.asarray(back["w"] + err["w"]), np.asarray(g["w"]), rtol=1e-5, atol=1e-5
    )


def test_bf16_compress_roundtrip_close():
    g = {"w": jnp.linspace(-2, 2, 64)}
    back = gc.decompress_bf16(gc.compress_bf16(g))
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(g["w"]), atol=2e-2)


def test_int8_requantize_identity_and_no_clip():
    """q*s == q'*t + extra_error exactly (f32), for t = the cross-pod max
    scale; nothing clips because |q*s| <= 127 s <= 127 t."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (64,)) * 1e-3}
    q, s, _ = gc.compress_int8(g)
    t = jax.tree.map(lambda x: x * 1000.0, s)  # a much-larger shared scale
    q2, extra = gc.requantize_int8(q, s, t)
    lhs = np.asarray(q["w"], np.float32) * float(s["w"])
    rhs = np.asarray(q2["w"], np.float32) * float(t["w"]) + np.asarray(extra["w"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-6, atol=1e-12)
    assert np.abs(np.asarray(q2["w"], np.int32)).max() <= 127


_POD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
from functools import partial
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.jaxcompat import make_mesh
from repro.optim import grad_compress as gc

mesh = make_mesh((2,), ("pod",))
# two pods with VERY different gradient magnitudes: the seed bug psummed
# raw int8 quantized under per-pod scales, inflating pod 0's contribution
# by pmax/scale0 ~ 1e4
g0 = np.linspace(-1e-3, 1e-3, 32, dtype=np.float32)
g1 = np.linspace(-10.0, 10.0, 32, dtype=np.float32)
stacked = jnp.stack([g0, g1])
err0 = jnp.zeros((2, 32), jnp.float32)

@partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
         out_specs=(P("pod"), P("pod")))
def reduce_fn(g, e):
    out, err = gc.pod_allreduce_int8({"w": g[0]}, "pod", {"w": e[0]})
    return out["w"][None], err["w"][None]

out, err = jax.jit(reduce_fn)(stacked, err0)
out, err = np.asarray(out), np.asarray(err)
true_mean = (g0 + g1) / 2
pmax = max(np.abs(g0).max(), np.abs(g1).max()) / 127.0
assert np.allclose(out[0], out[1]), "allreduce must agree across pods"
# shared-scale quantization error is O(pmax) per contribution; the seed
# bug's inflation error was ~ |g0| * pmax/s0 / 2 ~ 5.0 >> pmax
worst = np.abs(out[0] - true_mean).max()
assert worst <= pmax + 1e-6, (worst, pmax)
# error feedback closes the loop exactly: contribution(=g-err) sums to out
c0, c1 = g0 - err[0], g1 - err[1]
np.testing.assert_allclose((c0 + c1) / 2, out[0], rtol=1e-5, atol=1e-7)
print("POD_ALLREDUCE_OK")
"""


@pytest.mark.slow
def test_pod_allreduce_int8_shared_scale_2pods():
    """shard_map pin for the cross-pod scale bug: pods with gradients of
    very different magnitude must agree on one scale before the psum."""
    proc = subprocess.run(
        [sys.executable, "-c", _POD_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "POD_ALLREDUCE_OK" in proc.stdout

"""Benchmark aggregator: one harness per paper table/figure + kernel study.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig3 table2  # subset
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI gate: the modeled
      LinkModel suites (engine, disk) at reduced size — deterministic on
      shared runners, still asserts the coalescing + overlap gates
"""
from __future__ import annotations

import os
import sys
import time

SUITES = {
    "fig3": ("benchmarks.offload_modes", "paper Fig 3: eager/on-demand/prefetch (small images)"),
    "fig4": ("benchmarks.offload_modes_full", "paper Fig 4: full-size images"),
    "table1": ("benchmarks.power_model", "paper Table 1: throughput/power"),
    "table2": ("benchmarks.transfer_stall", "paper Table 2: stall vs transfer size"),
    "kernels": ("benchmarks.kernel_streaming", "kernel-level DMA schedule study"),
    "engine": ("benchmarks.engine_compare", "coalesced transfer engine vs seed per-leaf schedule"),
    "disk": ("benchmarks.disk_tier", "DiskHost three-level streaming (modeled disk link)"),
    "serve": ("benchmarks.serve_paged", "paged KV-cache serving vs per-step placement"),
    "serve_slo": ("benchmarks.serve_slo", "SLO load-generator serving: goodput under SLO + COW prefix sharing A/B"),
    "shard": ("benchmarks.shard_stream", "sharding-aware coalescing vs per-leaf fallback (2-device mesh)"),
    "weights": ("benchmarks.weight_stream", "streamed model parameters under a device budget (modeled link)"),
    "recovery": ("benchmarks.recovery", "self-healing runtime: retry overhead, fault bitwise-equality, CRC recovery, restart latency"),
}

#: the suites driven purely by the deterministic LinkModel emulation —
#: meaningful on a noisy CI runner, unlike the wall-clock studies
SMOKE_SUITES = ["engine", "disk", "serve", "serve_slo", "shard", "weights", "recovery"]


def main() -> int:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    if smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        args = [a for a in args if a != "--smoke"]
    names = [a for a in args if a in SUITES] or (
        SMOKE_SUITES if smoke else list(SUITES)
    )
    failures = []
    for name in names:
        mod_name, desc = SUITES[name]
        print(f"\n########## {name}: {desc} ##########")
        t0 = time.time()
        mod = __import__(mod_name, fromlist=["main"])
        rc = mod.main()
        print(f"[{name}] rc={rc} ({time.time()-t0:.1f}s)")
        if rc:
            failures.append(name)
    if failures:
        print(f"\nFAILED suites: {failures}")
        return 1
    print(f"\nall {len(names)} benchmark suites passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Streamed-parameters tests (ISSUE 5 tentpole).

Pins the weight-streaming contract at unit and integration level:
  * plan partition invariants (layer coverage, byte model, budget guards),
  * streamed train step bitwise-equal to the device-resident run for
    pinned_host and disk_host homes,
  * streamed prefill/decode bitwise-equal to the monolithic executables,
  * checkpoint round trip of host- AND disk-homed states (memmap leaves
    saved by reference, restore template via eval_shape),
  * exactly one coalesced H2D request per fetched (device, group) and the
    device-budget cap on the engine's prefetch window.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.engine import EngineConfig, TransferEngine
from repro.core.refspec import PrefetchSpec
from repro.core.spillstore import SpillStore, is_disk_leaf
from proptest import given, settings
from proptest import strategies as hst
from repro.core.weightstream import (
    WeightStreamPlan,
    weight_stream_support,
    weight_stream_supported,
)
from repro.data.synthetic import SyntheticConfig, synthetic_batch
from repro.optim.adamw import AdamWConfig
from repro.train import steps as st


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_smoke_config("smollm-360m"), n_layers=4)


@pytest.fixture(scope="module")
def plan(cfg):
    return WeightStreamPlan(cfg, st.abstract_params(cfg), layers_per_group=2)


@pytest.fixture(scope="module")
def opt_cfg():
    return AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=32)


def _batch(cfg, step=0):
    return synthetic_batch(cfg, SyntheticConfig(cfg.vocab_size, 16, 2, seed=0), step)


# ---------------------------------------------------------------------------
# plan invariants
# ---------------------------------------------------------------------------


def test_plan_partitions_every_layer_exactly_once(cfg, plan):
    covered = []
    for g in plan.layer_groups:
        covered.extend(range(g.lo, g.hi))
    assert covered == list(range(cfg.n_layers))
    assert plan.groups[0].kind == "embed" and plan.groups[-1].kind == "head"
    # tied embeddings: the head fetch group re-reads the embed table
    assert plan.head_reads_embed
    params, _ = st.init_train_state(jax.random.PRNGKey(0), cfg)
    fetch = plan.fetch_group(plan.init_home(params), plan.groups[-1])
    assert "embed" in fetch and "ln_f" in fetch


def test_plan_byte_model_and_budget_guards(cfg):
    abs_p = st.abstract_params(cfg)
    plan = WeightStreamPlan(cfg, abs_p, layers_per_group=1)
    # peak is monotone in distance and bounded by the full fetch sequence
    peaks = [plan.peak_device_bytes(d) for d in range(0, 6)]
    assert peaks == sorted(peaks)
    assert peaks[-1] <= sum(plan.fetch_sequence_bytes())
    # a budget below the distance-1 peak is rejected outright
    with pytest.raises(ValueError, match="cannot hold"):
        WeightStreamPlan(cfg, abs_p, layers_per_group=1, device_budget_mb=1e-6)
    # the window cap keeps the modeled peak under the budget
    budget_mb = plan.peak_device_bytes(2) / 1e6
    capped = WeightStreamPlan(
        cfg, abs_p, layers_per_group=1, device_budget_mb=budget_mb
    )
    d = capped.max_distance_for_budget()
    assert capped.peak_device_bytes(d) <= capped.device_budget_bytes


def test_support_report_is_reasoned_per_layout():
    """weight_stream_support replaces the old boolean: every layout gets a
    train verdict AND a serve verdict with a surfaceable reason."""
    uni = weight_stream_support(get_smoke_config("smollm-360m"))
    assert uni and uni.layout == "uniform" and uni.serve_supported

    rg = get_smoke_config("recurrentgemma-2b")
    rep = weight_stream_support(rg)
    assert rep and weight_stream_supported(rg)  # train-side streams now
    assert rep.layout == "unrolled"
    assert not rep.serve_supported and "uniform" in rep.serve_reason

    rep6 = weight_stream_support(dataclasses.replace(rg, n_layers=6))
    assert rep6 and rep6.layout == "period" and not rep6.serve_supported

    bad_cfg = dataclasses.replace(rg, n_layers=0)
    bad = weight_stream_support(bad_cfg)
    assert not bad and not bad.serve_supported
    assert "n_layers" in bad.reason
    # the plan constructor surfaces the report's reason verbatim
    with pytest.raises(ValueError, match="at least one block layer"):
        WeightStreamPlan(bad_cfg, {})


def test_expert_stream_plan_guards():
    dense = get_smoke_config("smollm-360m")
    with pytest.raises(ValueError, match="MoE config"):
        WeightStreamPlan(
            dense, st.abstract_params(dense), expert_stream=True
        )
    rg = get_smoke_config("recurrentgemma-2b")
    with pytest.raises(ValueError, match="uniform layout"):
        WeightStreamPlan(rg, st.abstract_params(rg), expert_stream=True)


def test_tree_bytes_rejects_dtypeless_leaf():
    """Satellite bugfix pin: an unknown-dtype leaf once silently counted as
    float32, corrupting every budget decision downstream — now it fails
    loudly, naming the leaf."""
    cfg = get_smoke_config("smollm-360m")
    abs_p = st.abstract_params(cfg)
    abs_p["blocks"] = dict(abs_p["blocks"], rogue=object())
    with pytest.raises(TypeError, match="byte accounting"):
        WeightStreamPlan(cfg, abs_p)


def test_home_assemble_roundtrip(cfg, plan):
    params, _ = st.init_train_state(jax.random.PRNGKey(0), cfg)
    home = plan.init_home(params)
    back = plan.assemble(home)
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_flatten_with_path(back)[0],
    ):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# streamed train step: bitwise vs the device-resident run
# ---------------------------------------------------------------------------


def _device_state(plan, state):
    return {
        "params": plan.device_home(state["params"]),
        "opt": {
            "groups": jax.device_put(state["opt"]["groups"]),
            "step": state["opt"]["step"],
        },
    }


def _run_steps(cfg, opt_cfg, plan, kind, n=2, store=None, distance="auto"):
    step = st.make_weight_streamed_train_step(
        cfg, opt_cfg, plan=plan, param_kind=kind, spill_store=store,
        prefetch=PrefetchSpec(buffer_size=plan.n_groups + 2, distance=distance),
    )
    state = st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    if kind == "device":
        state = _device_state(plan, state)
    elif kind == "disk_host":
        state = st.spill_weight_streamed_state(plan, state, store)
    losses = []
    try:
        for k in range(n):
            state, m = step(state, _batch(cfg, k))
            losses.append(float(m["loss"]))
    finally:
        stats = step.param_stats
        step.close()
    return losses, state, stats


def test_streamed_train_bitwise_vs_device(cfg, opt_cfg, plan):
    ref_losses, ref_state, _ = _run_steps(cfg, opt_cfg, plan, "device")
    losses, state, stats = _run_steps(cfg, opt_cfg, plan, "pinned_host")
    assert losses == ref_losses
    for key in ref_state["params"]["groups"]:
        for a, b in zip(
            jax.tree.leaves(state["params"]["groups"][key]),
            jax.tree.leaves(ref_state["params"]["groups"][key]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # exactly one coalesced H2D request per FETCHED (device, group); the
    # residency cache (unbounded here — no budget) makes every non-first
    # visit a resident pass-through, so total link traffic is well below
    # one request per consumed group
    h2d = stats.per_tier()["h2d"]
    assert h2d["requests_per_fetched_device_group"] == 1.0
    assert stats.h2d_requests == stats.unique_group_fetches > 0
    assert stats.cache_hits > 0
    assert stats.h2d_requests < stats.n_groups


def test_streamed_train_disk_home_bitwise_and_writes_back(cfg, opt_cfg, plan):
    ref_losses, _, _ = _run_steps(cfg, opt_cfg, plan, "device")
    with tempfile.TemporaryDirectory() as d:
        store = SpillStore(d, ephemeral=True)
        losses, state, stats = _run_steps(
            cfg, opt_cfg, plan, "disk_host", store=store
        )
        assert losses == ref_losses
        # updated params and moments went back to their disk home
        assert plan.is_spilled(state["params"])
        assert all(
            is_disk_leaf(v)
            for v in jax.tree.leaves(state["opt"]["groups"])
        )
        # one spill chunk per fetch -> one disk request per non-head group,
        # two for the tied head fetch (head home + embed table chunks)
        assert stats.disk_requests > 0
        store.close()


@pytest.mark.parametrize("distance", [0, 1])
def test_streamed_train_static_distances_bitwise(cfg, opt_cfg, plan, distance):
    ref_losses, _, _ = _run_steps(cfg, opt_cfg, plan, "device")
    losses, _, _ = _run_steps(
        cfg, opt_cfg, plan, "pinned_host", distance=distance
    )
    assert losses == ref_losses


# ---------------------------------------------------------------------------
# streamed prefill / decode vs the monolithic executables
# ---------------------------------------------------------------------------


def test_streamed_prefill_decode_match_monolithic(cfg, plan):
    params, _ = st.init_train_state(jax.random.PRNGKey(0), cfg)
    home = plan.init_home(params)
    tokens = jnp.asarray(
        np.pad(np.arange(1, 9, dtype=np.int32)[None, :], ((0, 0), (0, 8)))
    )
    with TransferEngine() as eng:
        prefill = st.make_weight_streamed_prefill_step(
            cfg, plan, 1, 16, engine=eng
        )
        decode = st.make_weight_streamed_decode_step(
            cfg, plan, engine=eng, paged=False
        )
        logits, caches = prefill(home, {"tokens": tokens})
        ref_prefill = jax.jit(st.make_prefill_step(cfg, 1, 16))
        rl, rc = ref_prefill(params, {"tokens": tokens})
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(rl))
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(rc)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        step_tok = {"tokens": jnp.asarray([[5]], jnp.int32)}
        pos = jnp.asarray([8], jnp.int32)
        l1, c1 = decode(home, caches, step_tok, pos)
        ref_decode = jax.jit(st.make_decode_step(cfg))
        l2, c2 = ref_decode(params, rc, step_tok, pos)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# checkpointing host- and disk-homed states
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_host_home(cfg, opt_cfg, plan, tmp_path):
    _, state, _ = _run_steps(cfg, opt_cfg, plan, "pinned_host", n=1)
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(0, state, blocking=True)
    template = jax.eval_shape(
        lambda: st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    )
    step, restored = mgr.restore(template)
    assert step == 0
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip_disk_home_memmap_leaves(cfg, opt_cfg, plan, tmp_path):
    """Disk-homed states checkpoint without materializing the tree: the
    memmap leaves are snapshotted by reference and serialized leaf-wise;
    restore hands back plain host arrays that re-spill bitwise."""
    with tempfile.TemporaryDirectory() as d:
        store = SpillStore(d, ephemeral=True)
        _, state, _ = _run_steps(cfg, opt_cfg, plan, "disk_host", n=1, store=store)
        assert any(is_disk_leaf(x) for x in jax.tree.leaves(state["params"]))
        mgr = CheckpointManager(tmp_path, keep=2)
        mgr.save(3, state, blocking=True)
        template = jax.eval_shape(
            lambda: st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
        )
        step, restored = mgr.restore(template)
        assert step == 3
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the trainer's restore path: re-impose the disk home bitwise
        respilled = st.spill_weight_streamed_state(plan, restored, store)
        assert plan.is_spilled(respilled["params"])
        for a, b in zip(jax.tree.leaves(respilled), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        store.close()


def test_engine_window_capped_by_budget(cfg, opt_cfg):
    """The adaptive controller can never stream past the device budget:
    the engine's max_distance comes from the plan's byte model."""
    abs_p = st.abstract_params(cfg)
    free = WeightStreamPlan(cfg, abs_p, layers_per_group=1)
    budget_mb = free.peak_device_bytes(1) / 1e6
    plan = WeightStreamPlan(
        cfg, abs_p, layers_per_group=1, device_budget_mb=budget_mb
    )
    cap = plan.max_distance_for_budget()
    assert cap < 8  # the budget actually bites
    engine = TransferEngine(EngineConfig(max_distance=cap))
    step = st.make_weight_streamed_train_step(
        cfg, opt_cfg, plan=plan, param_kind="pinned_host", engine=engine,
    )
    state = st.init_weight_streamed_state(jax.random.PRNGKey(0), cfg, plan)
    try:
        for k in range(2):
            state, _ = step(state, _batch(cfg, k))
        assert step.param_stats.peak_inflight_bytes <= plan.device_budget_bytes
        if step.param_stats.distance_trace:
            assert max(step.param_stats.distance_trace) <= cap
    finally:
        step.close()
        engine.close()


# ---------------------------------------------------------------------------
# review-fix pins
# ---------------------------------------------------------------------------


def test_auto_group_sizing_uses_real_window_peak(cfg):
    """Bugfix pin: the auto fit once modeled the peak as big + 2*lpg*layer
    and could pick a layers_per_group whose true distance-1 sliding window
    (3 consecutive layer groups) blew the budget, making the constructor
    raise 'raise the budget' even though a smaller group size fit."""
    big_cfg = dataclasses.replace(cfg, n_layers=12)
    abs_p = st.abstract_params(big_cfg)
    free = WeightStreamPlan(big_cfg, abs_p, layers_per_group=1)
    budget_mb = (
        max(free.embed_bytes, free.head_fetch_bytes) + 8 * free.per_layer_bytes
    ) / 1e6
    plan = WeightStreamPlan(big_cfg, abs_p, device_budget_mb=budget_mb)
    assert plan.peak_device_bytes(1) <= plan.device_budget_bytes


def test_groupwise_init_matches_monolithic_init(cfg, plan):
    """Group-wise init (one transfer group device-resident at a time) must
    be bitwise-identical to homing init_train_state: same per-layer keys,
    same cast — and the AdamW masters keep the full f32 init values."""
    params_f32 = st.transformer.init_model(jax.random.PRNGKey(3), cfg)
    params = jax.tree.map(lambda p: p.astype(cfg.compute_dtype), params_f32)
    ref_home = plan.init_home(params)
    state = st.init_weight_streamed_state(jax.random.PRNGKey(3), cfg, plan)
    for g in plan.groups:
        for a, b in zip(
            jax.tree.leaves(state["params"]["groups"][g.key]),
            jax.tree.leaves(ref_home["groups"][g.key]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # masters are the f32 init values, not a round trip through bf16
        ref_f32 = plan.home_group(params_f32, g)
        flat_ref = jax.tree.leaves(ref_f32)
        flat_opt = jax.tree.flatten(
            state["opt"]["groups"][g.key],
            is_leaf=lambda x: isinstance(x, dict) and "master" in x,
        )[0]
        for r, o in zip(flat_ref, flat_opt):
            np.testing.assert_array_equal(np.asarray(o["master"]), np.asarray(r))
            assert o["master"].dtype == np.float32


# ---------------------------------------------------------------------------
# group-program partition invariants (property-based, every layout)
# ---------------------------------------------------------------------------

_PROP_CASES = {
    "uniform": ("smollm-360m", {}, False),
    "moe": ("mixtral-8x7b", {}, False),
    "moe-experts": ("mixtral-8x7b", {}, True),
    "unrolled": ("recurrentgemma-2b", {}, False),
    "unrolled-xlstm": ("xlstm-1.3b", {}, False),
    "period": ("recurrentgemma-2b", {"n_layers": 6}, False),
}
_PROP_INIT: dict = {}


def _prop_case(name):
    arch, over, es = _PROP_CASES[name]
    if name not in _PROP_INIT:
        c = get_smoke_config(arch)
        if over:
            c = dataclasses.replace(c, **over)
        _PROP_INIT[name] = (c, st.init_train_state(jax.random.PRNGKey(1), c)[0])
    return _PROP_INIT[name] + (es,)


@settings(max_examples=30, deadline=None)
@given(
    hst.sampled_from(sorted(_PROP_CASES)),
    hst.integers(min_value=1, max_value=4),
)
def test_partition_invariants_every_layout(name, lpg):
    """For every layout x layers_per_group: the fetch program's groups
    disjointly cover the param tree, its byte model sums exactly, and its
    spill-key namespace has no collisions."""
    cfg_, params, es = _prop_case(name)
    plan_ = WeightStreamPlan(
        cfg_, st.abstract_params(cfg_), layers_per_group=lpg, expert_stream=es
    )
    # 1. home groups disjointly cover the tree: assemble(init_home) gives
    #    back the exact structure and bytes
    back = plan_.assemble(plan_.init_home(params))
    ref = jax.tree_util.tree_flatten_with_path(params)[0]
    got = jax.tree_util.tree_flatten_with_path(back)[0]
    assert [p for p, _ in ref] == [p for p, _ in got]
    for (_, a), (_, b) in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # 2. non-expert middle groups cover every layer exactly once; expert
    #    groups enumerate every (moe layer, expert) exactly once
    mids = [g for g in plan_.groups[1:-1] if g.kind != "expert"]
    assert [l for g in mids for l in range(g.lo, g.hi)] == list(
        range(cfg_.n_layers)
    )
    if es:
        assert {(g.lo, g.expert) for g in plan_.expert_groups} == {
            (l, e)
            for l in range(cfg_.n_layers)
            for e in range(cfg_.n_experts)
        }
    # 3. fetch-sequence bytes sum to the tree bytes, plus the tied embed
    #    table the head stage re-reads (link traffic, not home bytes)
    extra = plan_.embed_table_bytes if plan_.head_reads_embed else 0
    assert sum(plan_.fetch_sequence_bytes()) == plan_.total_param_bytes + extra
    # 4. spill/group key namespaces are collision-free
    spill_keys = [plan_.spill_key(g) for g in plan_.groups]
    assert len(set(spill_keys)) == len(spill_keys)
    assert len({g.key for g in plan_.groups}) == plan_.n_groups


# ---------------------------------------------------------------------------
# heterogeneous / period / expert-split group programs: streamed train
# ---------------------------------------------------------------------------


def _budgeted_plan(cfg_, lpg):
    abs_p = st.abstract_params(cfg_)
    free = WeightStreamPlan(cfg_, abs_p, layers_per_group=lpg)
    budget_mb = free.peak_device_bytes(2) / 1e6
    plan_ = WeightStreamPlan(
        cfg_, abs_p, layers_per_group=lpg, device_budget_mb=budget_mb
    )
    assert plan_.device_budget_bytes is not None
    return plan_


@pytest.mark.parametrize(
    "arch,over,lpg,disk",
    [
        ("recurrentgemma-2b", {}, 1, True),
        ("recurrentgemma-2b", {"n_layers": 6}, 3, False),  # period layout
        ("xlstm-1.3b", {}, 2, False),
    ],
)
def test_hetero_streamed_train_bitwise_under_budget(arch, over, lpg, disk, opt_cfg):
    """Unrolled and period-scanned archs now stream under
    --device-budget-mb: same program topology, bitwise-equal losses across
    every home kind."""
    cfg_ = get_smoke_config(arch)
    if over:
        cfg_ = dataclasses.replace(cfg_, **over)
    plan_ = _budgeted_plan(cfg_, lpg)
    assert plan_.layout in ("unrolled", "period")
    ref_losses, _, _ = _run_steps(cfg_, opt_cfg, plan_, "device")
    losses, _, _ = _run_steps(cfg_, opt_cfg, plan_, "pinned_host")
    assert losses == ref_losses
    if disk:
        with tempfile.TemporaryDirectory() as d:
            store = SpillStore(d, ephemeral=True)
            dlosses, _, _ = _run_steps(
                cfg_, opt_cfg, plan_, "disk_host", store=store
            )
            store.close()
        assert dlosses == ref_losses


def test_expert_stream_train_bitwise_across_kinds(opt_cfg):
    """Expert-split group programs train bitwise-identically wherever the
    experts are homed, and close to the unsplit program (same math,
    differently compiled)."""
    cfg_ = get_smoke_config("mixtral-8x7b")
    abs_p = st.abstract_params(cfg_)
    plan_ = WeightStreamPlan(cfg_, abs_p, expert_stream=True)
    assert plan_.expert_groups and plan_.layers_per_group == 1
    ref_losses, ref_state, _ = _run_steps(cfg_, opt_cfg, plan_, "device")
    losses, state, stats = _run_steps(cfg_, opt_cfg, plan_, "pinned_host")
    assert losses == ref_losses
    for key in ref_state["params"]["groups"]:
        for a, b in zip(
            jax.tree.leaves(state["params"]["groups"][key]),
            jax.tree.leaves(ref_state["params"]["groups"][key]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats.per_tier()["h2d"]["requests_per_fetched_device_group"] == 1.0
    unsplit = WeightStreamPlan(cfg_, abs_p, layers_per_group=1)
    u_losses, _, _ = _run_steps(cfg_, opt_cfg, unsplit, "device")
    np.testing.assert_allclose(losses, u_losses, rtol=2e-4)


# ---------------------------------------------------------------------------
# route-aware expert streaming: serve decode
# ---------------------------------------------------------------------------


def test_routed_decode_bitwise_and_cheaper_than_all_expert():
    """Router-first decode fetches only the routed experts' groups: tokens
    stay bitwise-equal to the device-resident run, expert link bytes drop
    vs the all-expert baseline, and a warm expert LRU drops them further."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import serve

    cfg_ = get_smoke_config("mixtral-8x7b")
    mesh = make_local_mesh()
    kw = dict(batch=2, prompt_len=8, gen=5, kv_page_len=0, warmup=False)
    base = serve(cfg_, mesh, **kw)
    routed = serve(
        cfg_, mesh, **kw,
        param_kind="pinned_host", expert_stream=True, param_cache_mb=0,
    )
    alle = serve(
        cfg_, mesh, **kw,
        param_kind="pinned_host", expert_stream=True, route_experts=False,
        param_cache_mb=0,
    )
    np.testing.assert_array_equal(routed["generated"], base["generated"])
    np.testing.assert_array_equal(alle["generated"], base["generated"])
    assert 0 < routed["expert_decode_bytes"] < alle["expert_decode_bytes"]
    es = routed["expert_stats"]
    assert es.per_tier()["h2d"]["requests_per_fetched_device_group"] == 1.0
    # expert-granular LRU: an uncapped cache turns steady-state refetches
    # into resident hits at zero link bytes
    cached = serve(
        cfg_, mesh, **kw, param_kind="pinned_host", expert_stream=True
    )
    np.testing.assert_array_equal(cached["generated"], base["generated"])
    assert cached["expert_stats"].cache_hits > 0
    assert cached["expert_decode_bytes"] < routed["expert_decode_bytes"]


def test_serve_surfaces_streamed_param_rejection_reason():
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import serve

    rg = get_smoke_config("recurrentgemma-2b")
    mesh = make_local_mesh()
    with pytest.raises(ValueError, match="not group-pageable"):
        serve(
            rg, mesh, batch=1, prompt_len=4, gen=2, kv_page_len=0,
            param_kind="pinned_host",
        )


def test_loose_external_engine_rejected_under_budget(cfg, opt_cfg):
    abs_p = st.abstract_params(cfg)
    free = WeightStreamPlan(cfg, abs_p, layers_per_group=1)
    budget_mb = free.peak_device_bytes(1) / 1e6
    plan = WeightStreamPlan(
        cfg, abs_p, layers_per_group=1, device_budget_mb=budget_mb
    )
    loose = TransferEngine(EngineConfig(max_distance=8))
    try:
        with pytest.raises(ValueError, match="window cap"):
            st.make_weight_streamed_train_step(
                cfg, opt_cfg, plan=plan, param_kind="pinned_host", engine=loose
            )
    finally:
        loose.close()

"""Stub modality frontends (per assignment: ``[audio]``/``[vlm]`` specify the
transformer BACKBONE only; the modality frontend is a STUB whose outputs —
precomputed frame/patch embeddings — arrive via ``input_specs()``).

MusicGen: 4 EnCodec codebooks, each vocab 2048.  The *real* frontend
(EnCodec) is stubbed; the token interface is faithful: per-step input
embedding = sum of the K codebook embeddings; output = K parallel lm-heads.

Qwen2-VL: vision tokens arrive as precomputed patch embeddings (B, S_img, D)
from the stub ViT; a linear merger projects them into the LM embedding space
and they are prepended to the text sequence.  M-RoPE 3-D position ids arrive
alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params


# -- audio (MusicGen) --------------------------------------------------------

def init_audio_embed(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "codebooks": layers.trunc_normal(
            ks[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model), 0.02
        ),
        "heads": layers.fan_in_init(
            ks[1], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size), cfg.d_model
        ),
    }


def audio_embed_apply(p: Params, codes: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """codes: (B, K, S) int32 -> (B, S, D): sum over codebook embeddings."""
    emb = p["codebooks"].astype(dtype)  # (K, V, D)

    # vmap over codebooks: emb[k][codes[:, k]] -> (B, S, D), summed over k
    def one(k_emb, k_codes):
        return k_emb[k_codes]

    per_cb = jax.vmap(one, in_axes=(0, 1), out_axes=0)(emb, codes)  # (K,B,S,D)
    return jnp.sum(per_cb, axis=0)


def audio_heads_apply(p: Params, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, K, S, V)."""
    return jnp.einsum("bsd,kdv->bksv", x, p["heads"].astype(x.dtype))


# -- vision (Qwen2-VL) ---------------------------------------------------------

def init_vision_merger(key: jax.Array, cfg: ModelConfig) -> Params:
    return {"proj": layers.fan_in_init(key, (cfg.d_model, cfg.d_model), cfg.d_model)}


def vision_merge_apply(p: Params, patch_embeds: jax.Array) -> jax.Array:
    """(B, S_img, D) stub-ViT outputs -> LM space."""
    return jnp.einsum("bsd,de->bse", patch_embeds, p["proj"].astype(patch_embeds.dtype))

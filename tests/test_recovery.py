"""Self-healing streamed runtime (ISSUE 6): elastic re-mesh recovery,
transient-fault retry in the transfer engine, and spill-store integrity.

Pins the fault model end to end:
  * transient H2D / disk-stage faults retry with backoff and complete
    **bitwise-equal** (retry counters == injected fault count); permanent
    faults surface on the waiter after exactly ``max_attempts``,
  * spill chunks carry per-leaf CRC32s — a flipped byte is detected on
    fetch, recovered once from the durable home, or surfaced with the
    chunk key/offset (never silently consumed),
  * ``close()`` detects wedged worker threads instead of silently
    abandoning them,
  * the driver's restart budget resets after ``checkpoint_every``
    consecutive healthy steps; straggler events widen the engine's
    prefetch window,
  * chaos: a kill at every pipeline phase (forward fetch, D2H drain,
    checkpoint commit) of a disk-homed streamed train recovers to a
    bitwise-equal loss series,
  * elastic: a 2-device streamed run resumed on 1 device (and 1 on 2)
    re-partitions the grouped checkpoint by streaming and continues with
    a loss series bitwise-equal to an unresharded resume.
"""
import dataclasses
import json
import shutil
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.engine import EngineConfig, TransferEngine
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.spillstore import SpillCorruptionError, SpillStore
from repro.core.weightstream import WeightStreamPlan
from repro.runtime import elastic as el
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.runtime.straggler import StragglerMonitor
from repro.train import steps as st

TIMEOUT_S = 60.0


def run_with_timeout(fn, timeout_s: float = TIMEOUT_S):
    box: dict = {}

    def target():
        try:
            box["value"] = fn()
        except BaseException as e:  # noqa: BLE001 — re-raised on the caller
            box["error"] = e

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(timeout_s)
    if t.is_alive():
        pytest.fail(f"timed out after {timeout_s}s (possible deadlock)")
    if "error" in box:
        raise box["error"]
    return box.get("value")


def _groups(n=4, shape=(4, 4)):
    rng = np.random.default_rng(0)
    return [{"x": rng.standard_normal(shape).astype(np.float32)} for _ in range(n)]


# ---------------------------------------------------------------------------
# transient-fault retry in the transfer engine
# ---------------------------------------------------------------------------


def test_transient_h2d_fault_retries_bitwise(monkeypatch):
    """One injected H2D fault with ``max_attempts=3``: the run completes,
    values are bitwise-equal to the host source, and the retry counter
    equals the injected fault count."""
    real_put = jax.device_put
    faults = {"n": 0}

    def flaky_put(x, *a, **kw):
        if faults["n"] == 0:
            faults["n"] += 1
            raise RuntimeError("injected transient H2D fault")
        return real_put(x, *a, **kw)

    groups = _groups(4)
    st_ = StreamStats()

    def body():
        cfg = EngineConfig(max_attempts=3, retry_backoff_s=1e-4)
        with HostStreamExecutor(
            lambda c, g: (c, g["x"] * 2.0), writeback=True, engine_config=cfg
        ) as ex:
            monkeypatch.setattr(jax, "device_put", flaky_put)
            _, outs = ex.run(jnp.zeros(()), groups, mode="prefetch", stats=st_)
            for i, o in enumerate(outs):
                np.testing.assert_array_equal(np.asarray(o), groups[i]["x"] * 2.0)

    run_with_timeout(body)
    assert faults["n"] == 1
    assert st_.retries == 1
    assert st_.give_ups == 0


def test_permanent_fault_surfaces_after_max_attempts(monkeypatch):
    """A fault that never clears surfaces on the waiter after exactly
    ``max_attempts`` tries and counts as a give-up."""
    real_put = jax.device_put
    calls = {"n": 0}

    def dead_put(x, *a, **kw):
        calls["n"] += 1
        raise RuntimeError("injected permanent H2D fault")

    st_ = StreamStats()

    def body():
        cfg = EngineConfig(max_attempts=3, retry_backoff_s=1e-4)
        with HostStreamExecutor(lambda c, g: c, engine_config=cfg) as ex:
            monkeypatch.setattr(jax, "device_put", dead_put)
            with pytest.raises(RuntimeError, match="permanent H2D fault"):
                ex.run(jnp.zeros(()), _groups(2), mode="on_demand", stats=st_)

    run_with_timeout(body)
    assert calls["n"] == 3  # exactly max_attempts tries
    assert st_.give_ups == 1
    assert st_.retries == 2  # attempts - 1 transparent retries before giving up


def test_transient_disk_stage_fault_retries_bitwise(tmp_path, monkeypatch):
    """One injected disk-staging fault: the group re-fetches from the
    intact cold home and the stream completes bitwise-equal."""
    store = SpillStore(tmp_path / "spill")
    host = _groups(4)
    disk = []
    for i, g in enumerate(host):
        store.put(f"g{i}", g)
        disk.append(store.get(f"g{i}"))

    real = TransferEngine._acquire_disk_staging
    faults = {"n": 0}

    def flaky_acquire(self, dsig, layout):
        if faults["n"] == 0:
            faults["n"] += 1
            raise RuntimeError("injected disk staging fault")
        return real(self, dsig, layout)

    st_ = StreamStats()

    def body():
        monkeypatch.setattr(TransferEngine, "_acquire_disk_staging", flaky_acquire)
        cfg = EngineConfig(max_attempts=3, retry_backoff_s=1e-4)
        with HostStreamExecutor(
            lambda c, g: (c, g["x"] + 1.0), writeback=True, engine_config=cfg
        ) as ex:
            _, outs = ex.run(jnp.zeros(()), disk, mode="prefetch", stats=st_)
            for i, o in enumerate(outs):
                np.testing.assert_array_equal(np.asarray(o), host[i]["x"] + 1.0)

    run_with_timeout(body)
    store.close()
    assert faults["n"] == 1
    assert st_.retries == 1
    assert st_.give_ups == 0


def test_legacy_fail_fast_default():
    """``max_attempts`` defaults to 1: a single fault surfaces immediately
    (the pre-retry contract every existing fault test pins)."""
    assert EngineConfig().max_attempts == 1


# ---------------------------------------------------------------------------
# spill-store integrity (CRC32)
# ---------------------------------------------------------------------------


def _corrupt_chunk(store, key, byte=10):
    entry = store._entry(key)
    path = store.dir / entry["file"]
    raw = bytearray(path.read_bytes())
    raw[byte] ^= 0xFF
    path.write_bytes(bytes(raw))


def test_crc_detects_flipped_byte(tmp_path):
    store = SpillStore(tmp_path / "spill")
    g = _groups(1)[0]
    store.put("k", g)
    store.verify_chunk("k")  # intact: no raise
    _corrupt_chunk(store, "k")
    with pytest.raises(SpillCorruptionError) as ei:
        store.verify_chunk("k")
    err = ei.value
    assert err.key == "k"
    assert err.offset is not None and err.nbytes > 0
    assert "crc32" in str(err) and "k" in str(err)
    assert store.crc_failures >= 1
    store.close()


def test_crc_fetch_recovers_from_durable_home(tmp_path):
    """A corrupt chunk consumed through the engine is re-fetched once via
    the recovery callback (the durable home) and the values are bitwise
    the originals — never the corrupted bytes."""
    store = SpillStore(tmp_path / "spill")
    host = _groups(2)
    for i, g in enumerate(host):
        store.put(f"g{i}", g)
    disk = [store.get(f"g{i}") for i in range(2)]
    store.set_recovery(lambda key: host[int(key[1:])])
    _corrupt_chunk(store, "g1")

    def body():
        with HostStreamExecutor(
            lambda c, g: (c, g["x"] * 3.0), writeback=True
        ) as ex:
            _, outs = ex.run(jnp.zeros(()), disk, mode="prefetch")
            for i, o in enumerate(outs):
                np.testing.assert_array_equal(np.asarray(o), host[i]["x"] * 3.0)

    run_with_timeout(body)
    assert store.crc_failures >= 1
    assert store.recoveries == 1
    store.verify_chunk("g1")  # the rewritten chunk is intact
    store.close()


def test_crc_without_recovery_surfaces_rich_error(tmp_path):
    """No durable home to recover from: the corruption surfaces on the
    engine waiter as a SpillCorruptionError naming the chunk — the stream
    never silently consumes corrupt bytes."""
    store = SpillStore(tmp_path / "spill")
    g = _groups(1)[0]
    store.put("k", g)
    disk = store.get("k")
    _corrupt_chunk(store, "k")

    def body():
        cfg = EngineConfig(max_attempts=3, retry_backoff_s=1e-4)
        with HostStreamExecutor(lambda c, g: c, engine_config=cfg) as ex:
            with pytest.raises(SpillCorruptionError, match="'k'"):
                ex.run(jnp.zeros(()), [disk], mode="on_demand")

    run_with_timeout(body)
    store.close()


# ---------------------------------------------------------------------------
# close() leak detection
# ---------------------------------------------------------------------------


def test_close_detects_wedged_worker(monkeypatch):
    """A worker stuck in a transfer past ``close_timeout_s`` is reported
    via ``leaked_threads`` (loud failure), and a later successful close
    clears the flag."""
    gate = threading.Event()
    real_put = jax.device_put

    def stuck_put(x, *a, **kw):
        gate.wait(TIMEOUT_S)
        return real_put(x, *a, **kw)

    def body():
        eng = TransferEngine(EngineConfig(close_timeout_s=0.2))
        monkeypatch.setattr(jax, "device_put", stuck_put)
        fut = eng.submit_group(0, _groups(1)[0])
        eng.close()
        assert eng.leaked_threads is True
        gate.set()  # un-wedge; the worker finishes its drain
        fut.wait()
        eng.close()
        assert eng.leaked_threads is False

    run_with_timeout(body)


# ---------------------------------------------------------------------------
# driver: restart-budget decay + straggler -> widen
# ---------------------------------------------------------------------------


def _cheap_driver(tmp_path, *, steps=12, every=2, max_restarts=1, fail_at=None,
                  engine=None, always_fail_from=None):
    def step_fn(state, batch):
        if always_fail_from is not None and batch >= always_fail_from:
            raise RuntimeError(f"persistent fault at step {batch}")
        x = state["x"] + 1.0
        return {"x": x}, {"loss": float(np.sum(x))}

    dcfg = DriverConfig(
        total_steps=steps, checkpoint_every=every, checkpoint_dir=str(tmp_path),
        log_every=0, max_restarts=max_restarts,
    )
    return TrainDriver(
        dcfg, step_fn, lambda i: i, lambda: {"x": np.zeros(4, np.float32)},
        fail_at=fail_at, engine=engine,
    )


def test_restart_budget_resets_after_healthy_steps(tmp_path):
    """Two isolated faults separated by >= checkpoint_every healthy steps
    survive a budget of 1; ``restarts`` stays cumulative for observability."""
    d = _cheap_driver(tmp_path / "a", max_restarts=1, fail_at={4, 9})
    d.run()
    assert d.restarts == 2  # cumulative, never decays
    steps = [h["step"] for h in d.history]
    assert steps[-1] == 11  # ran to completion


def test_restart_budget_still_trips_on_crash_loop(tmp_path):
    """A *persistent* fault (every attempt dies at the same step, never a
    healthy checkpoint-interval between) still exhausts the budget — the
    decay must not mask genuine crash loops."""
    d = _cheap_driver(tmp_path / "b", max_restarts=1, always_fail_from=4)
    with pytest.raises(RuntimeError, match="persistent fault"):
        run_with_timeout(d.run)


def test_straggler_event_widens_engine_prefetch():
    """The driver wires StragglerMonitor events into the engine: a flagged
    step boosts every registered AdaptiveDistance and the disk window."""
    eng = TransferEngine(EngineConfig(disk_slots=1, disk_max_slots=4))
    try:
        from repro.core.engine import AdaptiveDistance

        ctrl = AdaptiveDistance(initial=1, max_distance=8)
        eng.register_controller(ctrl)
        d = _cheap_driver("/tmp/unused-straggler", engine=eng)
        mon = d.monitor
        for _ in range(10):  # warm the window with fast steps
            mon.start_step(0)
            mon.end_step()
        before = ctrl.distance
        mon.start_step(1)
        time.sleep(0.15)  # >> z_threshold robust z-scores above the median
        ev = mon.end_step()
        assert ev is not None
        assert ctrl.distance > before
    finally:
        eng.close()


def test_straggler_monitor_on_event_callback():
    seen = []
    mon = StragglerMonitor(window=16, z_threshold=6.0, on_event=seen.append)
    for _ in range(10):
        mon.start_step(0)
        mon.end_step()
    mon.start_step(1)
    time.sleep(0.15)
    mon.end_step()
    assert len(seen) == 1 and seen[0].step == 1


# ---------------------------------------------------------------------------
# elastic: unit coverage
# ---------------------------------------------------------------------------


def test_parse_group_key():
    assert el.parse_group_key("g000_embed")["kind"] == "embed"
    assert el.parse_group_key("g004_head")["kind"] == "head"
    g = el.parse_group_key("g002_layers_002_004")
    assert (g["kind"], g["lo"], g["hi"]) == ("layers", 2, 4)
    assert el.parse_group_key("step") is None
    assert el.parse_group_key("leaves") is None


def test_check_restart_mesh_raises_on_device_count_change():
    fp = el.mesh_fingerprint(el.elastic_local_mesh(model=1))
    el.check_restart_mesh(fp)  # same count: no raise
    with pytest.raises(el.RemeshRequired, match="relaunch"):
        el.check_restart_mesh(
            {"n_devices": fp["n_devices"] + 1, "shape": [fp["n_devices"] + 1],
             "axes": ["data"]}
        )


def test_elastic_local_mesh_degrades_model_axis():
    n = len(jax.devices())
    mesh = el.elastic_local_mesh(model=n + 1)  # cannot fit: degrades
    assert mesh.devices.size == n
    assert mesh.axis_names[-1] == "model"


def test_prune_stale_spill(tmp_path):
    cfg = dataclasses.replace(get_smoke_config("smollm-360m"), n_layers=4)
    plan = WeightStreamPlan(cfg, st.abstract_params(cfg), layers_per_group=2)
    store = SpillStore(tmp_path / "spill")
    g = _groups(1)[0]
    for key in ("wp/g001_layers_000_001", "wopt/g001_layers_000_001",  # stale
                plan.spill_key(plan.groups[0]), "other/unrelated"):
        store.put(key, g)
    removed = el.prune_stale_spill(store, plan)
    assert removed == 2
    keys = set(store.keys())
    assert plan.spill_key(plan.groups[0]) in keys
    assert "other/unrelated" in keys  # non-weight chunks untouched
    store.close()


@pytest.mark.slow
def test_reshard_grouped_checkpoint_bitwise(tmp_path):
    """Stream-repartitioning a grouped checkpoint (lpg=1 -> lpg=3, an
    uneven split needing both slicing and concatenation) preserves every
    assembled param and moment bitwise."""
    cfg = dataclasses.replace(get_smoke_config("smollm-360m"), n_layers=4)
    abs_p = st.abstract_params(cfg)
    plan_a = WeightStreamPlan(cfg, abs_p, layers_per_group=1)
    plan_b = WeightStreamPlan(cfg, abs_p, layers_per_group=3)
    key = jax.random.PRNGKey(0)
    state = st.init_weight_streamed_state(key, cfg, plan_a)

    ck = CheckpointManager(tmp_path, keep=2)
    ck.save(7, state)
    ck.wait()
    assert not el.reshard_grouped_checkpoint(CheckpointManager(tmp_path, keep=0), plan_a)
    assert el.reshard_grouped_checkpoint(CheckpointManager(tmp_path, keep=0), plan_b)

    tmpl = jax.eval_shape(lambda: st.init_weight_streamed_state(key, cfg, plan_b))
    step, restored = CheckpointManager(tmp_path, keep=2).restore(tmpl)
    assert step == 7

    ref = st.init_weight_streamed_state(key, cfg, plan_a)
    pa = plan_a.assemble(ref["params"])
    pb = plan_b.assemble(restored["params"])
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert int(restored["opt"]["step"]) == int(ref["opt"]["step"])

    def field_home(groups, field):
        return {"groups": {
            k: jax.tree.map(
                lambda t: t[field], v,
                is_leaf=lambda t: isinstance(t, dict) and field in t,
            )
            for k, v in groups.items()
        }}

    for field in ("master", "m", "v"):
        fa = plan_a.assemble(field_home(ref["opt"]["groups"], field))
        fb = plan_b.assemble(field_home(restored["opt"]["groups"], field))
        for x, y in zip(jax.tree.leaves(fa), jax.tree.leaves(fb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# chaos: kill a disk-homed streamed train at every pipeline phase
# ---------------------------------------------------------------------------


def _ws_driver(tmp_path, *, steps=6, every=2, fail_at=None):
    from repro.launch.train import build_trainer
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.elastic import elastic_local_mesh

    cfg = dataclasses.replace(get_smoke_config("smollm-360m"), n_layers=2)
    mesh = elastic_local_mesh(model=1)
    return build_trainer(
        cfg,
        mesh,
        global_batch=2,
        seq_len=16,
        opt_cfg=AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=steps),
        driver_cfg=DriverConfig(
            total_steps=steps, checkpoint_every=every,
            checkpoint_dir=str(tmp_path), log_every=0, max_restarts=3,
        ),
        fail_at=fail_at,
        param_kind="disk_host",
        param_layers_per_group=1,
        transfer_retries=1,
    )


@pytest.mark.slow
@pytest.mark.parametrize("sanitize", [False, True], ids=["plain", "sanitized"])
@pytest.mark.parametrize("phase", ["forward_fetch", "d2h_drain", "ckpt_commit"])
def test_chaos_phase_kill_recovers_bitwise(tmp_path, monkeypatch, phase,
                                           sanitize):
    """Kill a disk-homed streamed train mid-step at a specific pipeline
    phase; the restarted run's loss series must be bitwise-equal to the
    unfailed reference.

    The sanitized variant reruns the same kills under ``REPRO_SANITIZE=1``:
    a kill mid-drain leaves D2H tickets pending, and the restart path must
    discard them before re-fetching the same groups — a hazard report here
    means recovery re-fetched through an in-flight writeback."""
    if sanitize:
        monkeypatch.setenv("REPRO_SANITIZE", "1")
    ref = _ws_driver(tmp_path / "ref")
    ref.run()
    ref_losses = {h["step"]: h["loss"] for h in ref.history}

    armed = {"at": 5, "n": 0}  # 5th step_fn entry = step 4 (not a ckpt step)

    if phase == "forward_fetch":
        real = HostStreamExecutor.run

        def chaos(self, *a, **kw):
            if armed["at"] is not None:
                armed["n"] += 1
                if armed["n"] == armed["at"]:
                    armed["at"] = None
                    raise RuntimeError("injected forward-fetch kill")
            return real(self, *a, **kw)

        monkeypatch.setattr(HostStreamExecutor, "run", chaos)
    elif phase == "d2h_drain":
        real = TransferEngine.drain_writebacks

        def chaos(self, *a, **kw):
            if armed["at"] is not None:
                armed["n"] += 1
                if armed["n"] == armed["at"]:
                    armed["at"] = None
                    raise RuntimeError("injected D2H-drain kill")
            return real(self, *a, **kw)

        monkeypatch.setattr(TransferEngine, "drain_writebacks", chaos)
    else:  # ckpt_commit
        real = CheckpointManager.save

        def chaos(self, *a, **kw):
            if armed["at"] is not None:
                armed["n"] += 1
                if armed["n"] == 2:  # second periodic save (after step 3)
                    armed["at"] = None
                    raise RuntimeError("injected checkpoint-commit kill")
            return real(self, *a, **kw)

        monkeypatch.setattr(CheckpointManager, "save", chaos)

    d = _ws_driver(tmp_path / "chaos")
    d.run()
    assert armed["at"] is None, "chaos fault never fired"
    assert d.restarts == 1
    got = {}
    for h in d.history:  # later entries overwrite replayed steps
        got[h["step"]] = h["loss"]
    assert set(ref_losses) == set(got)
    for s in ref_losses:
        assert ref_losses[s] == got[s], (s, ref_losses[s], got[s])


# ---------------------------------------------------------------------------
# elastic re-mesh: forced 2<->1 device subprocess resume, bitwise
# ---------------------------------------------------------------------------

_ENV = {
    "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
    "HOME": "/root",
    # the re-mesh resumes run fully sanitized: kill + reshard + replay must
    # produce zero transfer-hazard reports, not just bitwise losses
    "REPRO_SANITIZE": "1",
}


def _train_cli(ckpt_dir, *, devices, steps, lpg, model_parallel, hist=None,
               param_kind="pinned_host", extra=()):
    env = dict(_ENV)
    if devices > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    cmd = [
        sys.executable, "-m", "repro.launch.train", "--arch", "smollm-360m",
        "--smoke", "--steps", str(steps), "--batch", "2", "--seq", "16",
        "--checkpoint-dir", str(ckpt_dir), "--checkpoint-every", "2",
        "--model-parallel", str(model_parallel), "--param-kind", param_kind,
        "--param-layers-per-group", str(lpg), *extra,
    ]
    if hist is not None:
        cmd += ["--history-out", str(hist)]
    proc = subprocess.run(
        cmd, capture_output=True, text=True, timeout=600, env=env
    )
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-3000:])
    return proc


def _losses(hist_path):
    return {int(h["step"]): h["loss"] for h in json.loads(hist_path.read_text())}


@pytest.mark.slow
def test_remesh_2_to_1_device_resumes_bitwise(tmp_path):
    """A 2-device disk-homed streamed run killed mid-train and resumed on
    1 device with a different grouping re-shards by streaming and replays
    a loss series bitwise-equal to an unresharded resume."""
    ckpt = tmp_path / "ckpt"
    spill = tmp_path / "spill"
    # phase 1: 2 devices, lpg=1, killed once mid-train (recovers in-process)
    _train_cli(ckpt, devices=2, steps=4, lpg=1, model_parallel=2,
               param_kind="disk_host",
               extra=("--spill-dir", str(spill), "--fail-at", "2"))
    ref_dir = tmp_path / "ckpt-ref"
    shutil.copytree(ckpt, ref_dir)

    # elastic resume: 1 device (model axis degrades), lpg=2 -> reshard
    _train_cli(ckpt, devices=1, steps=8, lpg=2, model_parallel=2,
               param_kind="disk_host", hist=tmp_path / "el.json",
               extra=("--spill-dir", str(spill)))
    # reference resume: same 1-device mesh, unchanged lpg=1 -> no reshard
    _train_cli(ref_dir, devices=1, steps=8, lpg=1, model_parallel=2,
               param_kind="disk_host", hist=tmp_path / "ref.json",
               extra=("--spill-dir", str(tmp_path / "spill-ref")))

    got, ref = _losses(tmp_path / "el.json"), _losses(tmp_path / "ref.json")
    assert got and set(got) == set(ref)
    for s in sorted(ref):
        assert got[s] == ref[s], (s, got[s], ref[s])


@pytest.mark.slow
def test_remesh_1_to_2_device_resumes_bitwise(tmp_path):
    """The mirror direction: a 1-device run resumed on 2 devices with a
    re-derived grouping."""
    ckpt = tmp_path / "ckpt"
    _train_cli(ckpt, devices=1, steps=4, lpg=2, model_parallel=1)
    ref_dir = tmp_path / "ckpt-ref"
    shutil.copytree(ckpt, ref_dir)

    # elastic resume: 2 devices, lpg=1 -> reshard
    _train_cli(ckpt, devices=2, steps=8, lpg=1, model_parallel=1,
               hist=tmp_path / "el.json")
    # reference resume: same 2-device mesh, unchanged lpg=2 -> no reshard
    _train_cli(ref_dir, devices=2, steps=8, lpg=2, model_parallel=1,
               hist=tmp_path / "ref.json")

    got, ref = _losses(tmp_path / "el.json"), _losses(tmp_path / "ref.json")
    assert got and set(got) == set(ref)
    for s in sorted(ref):
        assert got[s] == ref[s], (s, got[s], ref[s])

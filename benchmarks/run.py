"""Benchmark aggregator: one harness per paper table/figure + kernel study.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig3 table2  # subset
"""
from __future__ import annotations

import sys
import time

SUITES = {
    "fig3": ("benchmarks.offload_modes", "paper Fig 3: eager/on-demand/prefetch (small images)"),
    "fig4": ("benchmarks.offload_modes_full", "paper Fig 4: full-size images"),
    "table1": ("benchmarks.power_model", "paper Table 1: throughput/power"),
    "table2": ("benchmarks.transfer_stall", "paper Table 2: stall vs transfer size"),
    "kernels": ("benchmarks.kernel_streaming", "kernel-level DMA schedule study"),
    "engine": ("benchmarks.engine_compare", "coalesced transfer engine vs seed per-leaf schedule"),
}


def main() -> int:
    names = [a for a in sys.argv[1:] if a in SUITES] or list(SUITES)
    failures = []
    for name in names:
        mod_name, desc = SUITES[name]
        print(f"\n########## {name}: {desc} ##########")
        t0 = time.time()
        mod = __import__(mod_name, fromlist=["main"])
        rc = mod.main()
        print(f"[{name}] rc={rc} ({time.time()-t0:.1f}s)")
        if rc:
            failures.append(name)
    if failures:
        print(f"\nFAILED suites: {failures}")
        return 1
    print(f"\nall {len(names)} benchmark suites passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

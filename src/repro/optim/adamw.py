"""Sharded AdamW with f32 master weights and memkind-placeable moments.

State layout per parameter leaf: ``{"master": f32, "m": f32, "v": f32}`` plus
a global ``{"step": int32}``.  Every moment leaf shares its parameter's
PartitionSpec, so under FSDP the optimizer state is fully sharded (ZeRO).
The *memory kind* of the state (device vs pinned host) is chosen by the
``PlacementPolicy`` — the paper's one-line placement change applied to the
largest state group of large-model training.

Params are stored/computed in ``cfg.dtype`` (bf16); the update happens in
f32 against the master copy and is cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    min_lr_ratio: float = 0.1


def adamw_init(params: Pytree) -> Pytree:
    """Optimizer state matching ``params`` (f32 master + moments)."""
    def leaf(p):
        return {
            "master": p.astype(jnp.float32),
            "m": jnp.zeros(p.shape, jnp.float32),
            "v": jnp.zeros(p.shape, jnp.float32),
        }

    return {"leaves": jax.tree.map(leaf, params), "step": jnp.zeros((), jnp.int32)}


def opt_state_bytes(params: Pytree) -> int:
    """Bytes the AdamW state for ``params`` occupies (3 f32 copies per leaf)
    — what a host-RAM budget compares against when deciding how much of the
    state spills to the ``DiskHost`` tier."""
    return sum(3 * 4 * int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_globals(cfg: AdamWConfig, grads: Pytree, step) -> dict:
    """Scalar quantities shared by every leaf at (1-based) ``step``.

    Split out of :func:`adamw_update` so the streamed optimizer path
    (``repro.train.steps.make_streamed_opt_updater``, which applies
    :func:`adamw_leaf_update` group-wise while the moments stream through
    the transfer engine) computes the *identical* numbers once up front.
    """
    return adamw_globals_from_norm(cfg, global_norm(grads), step)


def adamw_globals_from_norm(cfg: AdamWConfig, grad_norm, step) -> dict:
    """:func:`adamw_globals` with the global gradient norm already reduced.

    The weight-streamed trainer accumulates per-leaf squared sums while the
    gradients stream back to the host during the backward pass, so the full
    gradient tree never co-resides anywhere to hand to :func:`global_norm`.
    """
    from repro.optim.schedule import cosine_schedule

    step = jnp.asarray(step)
    lr = cosine_schedule(
        step,
        peak_lr=cfg.peak_lr,
        warmup_steps=cfg.warmup_steps,
        total_steps=cfg.total_steps,
        min_ratio=cfg.min_lr_ratio,
    )
    gnorm = jnp.asarray(grad_norm, jnp.float32)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    fstep = step.astype(jnp.float32)
    return {
        "lr": lr,
        "grad_norm": gnorm,
        "scale": scale,
        "bc1": 1.0 - cfg.b1 ** fstep,
        "bc2": 1.0 - cfg.b2 ** fstep,
    }


def adamw_leaf_update(cfg: AdamWConfig, glob: dict, g, s) -> tuple:
    """One parameter leaf's AdamW update given the step globals.

    Returns ``(new_master_f32, new_state_leaf)``.
    """
    g = g.astype(jnp.float32) * glob["scale"]
    m = cfg.b1 * s["m"] + (1 - cfg.b1) * g
    v = cfg.b2 * s["v"] + (1 - cfg.b2) * g * g
    upd = (m / glob["bc1"]) / (jnp.sqrt(v / glob["bc2"]) + cfg.eps)
    master = s["master"] * (1.0 - glob["lr"] * cfg.weight_decay) - glob["lr"] * upd
    return master, {"master": master, "m": m, "v": v}


def adamw_update(
    cfg: AdamWConfig,
    grads: Pytree,
    opt_state: Pytree,
    *,
    compute_dtype=jnp.bfloat16,
) -> tuple[Pytree, Pytree, dict]:
    """One AdamW step. Returns ``(new_params, new_state, metrics)``.

    ``new_params`` leaves are cast to ``compute_dtype`` (the master stays
    f32 inside the state).
    """
    step = opt_state["step"] + 1
    glob = adamw_globals(cfg, grads, step)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(opt_state["leaves"])
    out = [adamw_leaf_update(cfg, glob, g, s) for g, s in zip(flat_g, flat_s)]
    new_params = treedef.unflatten([p.astype(compute_dtype) for p, _ in out])
    new_leaves = treedef.unflatten([s for _, s in out])
    metrics = {"grad_norm": glob["grad_norm"], "lr": glob["lr"]}
    return new_params, {"leaves": new_leaves, "step": step}, metrics

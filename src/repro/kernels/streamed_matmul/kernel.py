"""Streamed matmul: weights passed by reference (HBM), tiles prefetched to VMEM.

This kernel is the paper's §3.1 mechanism rendered in the TPU memory
hierarchy.  The weight matrix is **not** staged into fast memory up front
(the paper's "eager copy"); instead the kernel receives a *reference*
(``pl.ANY`` memory space = compiler leaves the operand in HBM) and an explicit
DMA engine moves ``(bk, bn)`` tiles into a VMEM ring buffer:

  ring depth  = ``PrefetchSpec.buffer_size``   (paper: elements reserved on-core)
  tile shape  = ``elements_per_fetch``          (paper: elements per transfer)
  lookahead   = ``PrefetchSpec.distance``       (paper: when transfer is issued)

``distance=0`` reproduces the paper's *on-demand* mode — the copy for tile
``k`` starts only when tile ``k`` is needed and the MXU stalls on the DMA
semaphore, exactly the "block until the transfer has completed" behaviour.
``distance=d>=1`` issues the copy for tile ``k+d`` before computing tile
``k``; with ``buffer_size >= d+1`` the DMA of the next weights overlaps the
current tile's matmul, which is the paper's 21-25x fix.

Grid: ``(M/bm, N/bn)``; the K dimension is an in-kernel pipelined loop, since
that is the axis being streamed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jaxcompat import tpu_compiler_params

from repro.core.engine import static_auto_distance
from repro.core.refspec import PrefetchSpec


def _streamed_matmul_kernel(
    x_ref,  # (bm, K)   VMEM — activations (already at the fast tier)
    w_hbm,  # (K, N)    ANY  — weights, by reference
    o_ref,  # (bm, bn)  VMEM
    acc_ref,  # (bm, bn) f32 VMEM scratch
    ring,  # (slots, bk, bn) VMEM scratch — the prefetch ring buffer
    sems,  # (slots,) DMA semaphores
    *,
    block_k: int,
    n_k: int,
    distance: int,
    slots: int,
):
    j = pl.program_id(1)
    bn = o_ref.shape[1]

    def tile_copy(k_idx, slot):
        """DMA one (bk, bn) weight tile HBM -> ring[slot]."""
        return pltpu.make_async_copy(
            w_hbm.at[pl.ds(k_idx * block_k, block_k), pl.ds(j * bn, bn)],
            ring.at[slot],
            sems.at[slot],
        )

    acc_ref[...] = jnp.zeros_like(acc_ref)

    if distance > 0:
        # warm-up: issue the first `distance` tile fetches ahead of compute
        for t in range(min(distance, n_k)):
            tile_copy(t, t % slots).start()

    def body(k, _):
        slot = jax.lax.rem(k, slots)
        if distance == 0:
            # on-demand: fetch in the critical path, stall until it lands
            tile_copy(k, slot).start()
            tile_copy(k, slot).wait()
        else:
            nxt = k + distance
            @pl.when(nxt < n_k)
            def _():
                tile_copy(nxt, jax.lax.rem(nxt, slots)).start()
            tile_copy(k, slot).wait()
        x_blk = x_ref[:, pl.dslice(k * block_k, block_k)]
        acc_ref[...] += jnp.dot(
            x_blk, ring[slot], preferred_element_type=jnp.float32
        )
        return ()

    jax.lax.fori_loop(0, n_k, body, (), unroll=False)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def streamed_matmul_p(
    x: jax.Array,  # (M, K)
    w: jax.Array,  # (K, N)
    *,
    spec: PrefetchSpec,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call (shapes must already be block-aligned)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"unpadded shapes {x.shape} x {w.shape} vs blocks "
        f"({block_m},{block_n},{block_k})"
    )
    n_k = k // block_k
    # the VMEM ring is static: "auto" resolves to a fixed head start here,
    # exactly like the compiled graph engine (prefetch.streamed_scan)
    distance = spec.numeric_distance(static_auto_distance(n_k))
    # ring must hold the in-use tile + `distance` in flight
    slots = max(spec.buffer_size, distance + 1, 1)

    kernel = functools.partial(
        _streamed_matmul_kernel,
        block_k=block_k,
        n_k=n_k,
        distance=distance,
        slots=slots,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),  # x: row-block in VMEM
            pl.BlockSpec(memory_space=pl.ANY),  # w: by reference, stays in HBM
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),  # accumulator
            pltpu.VMEM((slots, block_k, block_n), w.dtype),  # prefetch ring
            pltpu.SemaphoreType.DMA((slots,)),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
    )(x, w)
